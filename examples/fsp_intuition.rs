//! The PS-vs-FSP intuition of the paper's Fig. 1 and Fig. 2 (§2.1),
//! rendered as slot timelines from real simulation runs.
//!
//! ```bash
//! cargo run --release --example fsp_intuition
//! ```

use hfsp::prelude::*;
use hfsp::workload::synthetic::{fig1_workload, fig2_workload};

fn main() {
    hfsp::util::logging::init_from_env();
    let slots = 4;
    let cfg = SimConfig {
        cluster: ClusterConfig {
            nodes: 1,
            map_slots: slots,
            reduce_slots: 1,
            heartbeat_s: 0.5,
            ..Default::default()
        },
        record_timelines: true,
        ..Default::default()
    };
    for (label, wl) in [
        (
            "Fig.1 — three full-width jobs (30/10/10 s at t=0/10/15)",
            fig1_workload(slots, 6),
        ),
        (
            "Fig.2 — jobs needing 100%/55%/35% of the cluster",
            fig2_workload(slots, 6),
        ),
    ] {
        println!("=== {label} ===");
        for kind in [
            SchedulerKind::Fair(Default::default()),
            SchedulerKind::SizeBased(HfspConfig::default()),
        ] {
            let o = Simulation::new(cfg.clone())
                .scheduler(kind)
                .workload(wl.as_source())
                .run();
            println!(
                "--- {} (mean sojourn {:.1} s; completion order by finish time) ---",
                o.scheduler,
                o.sojourn.mean()
            );
            print!("{}", o.timelines.ascii_chart(0.0, o.makespan, 72));
        }
        println!();
    }
    println!("FAIR approximates processor sharing (slots split among jobs);");
    println!("HFSP runs jobs to completion in their projected PS finish order,");
    println!("which shortens mean sojourn without mistreating any job.");
}
