//! Quickstart: simulate a small FB-like workload under HFSP and print
//! sojourn statistics, using the `Simulation` session builder.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use hfsp::prelude::*;

fn main() {
    hfsp::util::logging::init_from_env();

    // A 20-node cluster (4 map + 2 reduce slots each, the paper's
    // per-node shape) and a half-scale FB-dataset workload.
    let cfg = SimConfig {
        cluster: ClusterConfig {
            nodes: 20,
            ..Default::default()
        },
        seed: 7,
        ..Default::default()
    };
    let workload = FbWorkload::scaled(0.5).generate(&mut Pcg64::seed_from_u64(7));
    println!(
        "workload: {} jobs, {} tasks, {:.0} s serialized work",
        workload.len(),
        workload.total_tasks(),
        workload.total_work()
    );

    for kind in [
        SchedulerKind::Fifo,
        SchedulerKind::Fair(Default::default()),
        SchedulerKind::SizeBased(HfspConfig::default()),
    ] {
        // One session per scheduler: same config, same workload stream.
        let outcome = Simulation::new(cfg.clone())
            .scheduler(kind)
            .workload(workload.as_source())
            .run();
        println!(
            "{:<5} mean sojourn {:>8.1} s | locality {:>5.1}% | makespan {:>7.0} s | {:>6} events in {:>5.0} ms",
            outcome.scheduler,
            outcome.sojourn.mean(),
            outcome.locality.fraction_local() * 100.0,
            outcome.makespan,
            outcome.events_processed,
            outcome.wall_ms
        );
        for class in [JobClass::Small, JobClass::Medium, JobClass::Large] {
            let m = outcome.sojourn.mean_class(class);
            if !m.is_nan() {
                println!("        {:<7} {:>8.1} s", class.name(), m);
            }
        }
    }
    println!("\nHFSP focuses the cluster on the job that would finish first under");
    println!("processor sharing — small jobs stay interactive, and medium/large");
    println!("jobs finish earlier than under fair sharing.");
}
