//! Scratch diagnostics for HFSP scheduling behaviour (not part of the
//! documented example set; kept because it is a handy tracing harness).

use hfsp::prelude::*;

fn main() {
    hfsp::util::logging::init_from_env();
    let cfg = SimConfig {
        cluster: ClusterConfig {
            nodes: 100,
            ..Default::default()
        },
        ..Default::default()
    };
    let wl = FbWorkload::default().generate(&mut Pcg64::seed_from_u64(42));
    let run = |kind: SchedulerKind| {
        Simulation::new(cfg.clone())
            .scheduler(kind)
            .workload(wl.as_source())
            .run()
    };
    let fair = run(SchedulerKind::Fair(Default::default()));
    let hfsp = run(SchedulerKind::SizeBased(HfspConfig::default()));
    println!(
        "FAIR mean {:.1}  HFSP mean {:.1}; hfsp counters: suspends {} resumes {} swap-ins {} stale {}",
        fair.sojourn.mean(),
        hfsp.sojourn.mean(),
        hfsp.counters.suspends,
        hfsp.counters.resumes,
        hfsp.counters.swap_ins,
        hfsp.counters.stale_completions,
    );
    let f = fair.sojourn.by_job();
    let h = hfsp.sojourn.by_job();
    let mut diffs: Vec<(i64, u64)> = Vec::new();
    for (&id, &hs) in &h {
        diffs.push(((hs - f[&id]) as i64, id));
    }
    diffs.sort();
    println!("worst 12 jobs for HFSP (hfsp_sojourn - fair_sojourn, positive = HFSP worse):");
    for &(d, id) in diffs.iter().rev().take(12) {
        let spec = wl.jobs.iter().find(|j| j.id == id).unwrap();
        println!(
            "  job {id:>3} {:<7} maps {:>4} reduces {:>4} submit {:>6.0}  diff {d:>6}s (hfsp {:.0} fair {:.0})",
            spec.class.name(),
            spec.n_maps(),
            spec.n_reduces(),
            spec.submit_time,
            h[&id],
            f[&id]
        );
    }
    println!("best 8 jobs for HFSP:");
    for &(d, id) in diffs.iter().take(8) {
        let spec = wl.jobs.iter().find(|j| j.id == id).unwrap();
        println!(
            "  job {id:>3} {:<7} maps {:>4} reduces {:>4} submit {:>6.0}  diff {d:>6}s (hfsp {:.0} fair {:.0})",
            spec.class.name(),
            spec.n_maps(),
            spec.n_reduces(),
            spec.submit_time,
            h[&id],
            f[&id]
        );
    }
}
