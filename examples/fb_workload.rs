//! End-to-end driver: the full three-layer system on the paper's
//! macro-benchmark workload.
//!
//! This is the integration proof for the whole stack: the FB-dataset
//! (SWIM-like synthesis of the Facebook trace statistics, §4.1) runs on
//! the simulated 100-node cluster under FIFO, FAIR and HFSP — with
//! HFSP's job-size estimator and max-min allocator executing the
//! **AOT-compiled JAX/Pallas artifacts through PJRT** (L1+L2), driven by
//! the rust coordinator (L3). Requires `make artifacts`.
//!
//! ```bash
//! make artifacts && cargo run --release --example fb_workload
//! ```
//!
//! The run is recorded in EXPERIMENTS.md ("End-to-end validation").

use hfsp::prelude::*;
use hfsp::report::table;
use hfsp::scheduler::core::{EstimatorKind, MaxMinKind};
use std::path::PathBuf;

fn main() {
    hfsp::util::logging::init_from_env();
    let artifact_dir = hfsp::runtime::default_artifact_dir();
    let have_artifacts = artifact_dir.join("manifest.json").exists();
    if !have_artifacts {
        eprintln!(
            "WARNING: {} not found — run `make artifacts`. Falling back to the \
             native estimator (the run still works, but skips the XLA layers).",
            artifact_dir.join("manifest.json").display()
        );
    }

    let cfg = SimConfig::default(); // 100 nodes, paper's slot shape
    let wl = FbWorkload::default().generate(&mut Pcg64::seed_from_u64(42));
    println!(
        "FB-dataset: {} jobs / {} tasks / {:.0} s serialized work over a {:.0} s submission window\n",
        wl.len(),
        wl.total_tasks(),
        wl.total_work(),
        wl.span()
    );

    let hfsp_cfg = if have_artifacts {
        HfspConfig {
            estimator: EstimatorKind::Xla {
                artifact_dir: PathBuf::from(&artifact_dir),
            },
            maxmin: MaxMinKind::Xla {
                artifact_dir: PathBuf::from(&artifact_dir),
            },
            ..Default::default()
        }
    } else {
        HfspConfig::default()
    };

    let kinds = [
        ("FIFO", SchedulerKind::Fifo),
        ("FAIR", SchedulerKind::Fair(Default::default())),
        ("HFSP", SchedulerKind::SizeBased(hfsp_cfg)),
    ];
    let mut rows = Vec::new();
    let mut hfsp_mean = f64::NAN;
    let mut fifo_mean = f64::NAN;
    for (label, kind) in kinds {
        let o = Simulation::new(cfg.clone())
            .scheduler(kind)
            .workload(wl.as_source())
            .run();
        if label == "HFSP" {
            hfsp_mean = o.sojourn.mean();
        }
        if label == "FIFO" {
            fifo_mean = o.sojourn.mean();
        }
        rows.push(vec![
            format!(
                "{label}{}",
                if label == "HFSP" && have_artifacts {
                    " (xla estimator+maxmin)"
                } else {
                    ""
                }
            ),
            format!("{:.0}", o.sojourn.mean()),
            format!("{:.0}", o.sojourn.mean_class(JobClass::Small)),
            format!("{:.0}", o.sojourn.mean_class(JobClass::Medium)),
            format!("{:.0}", o.sojourn.mean_class(JobClass::Large)),
            format!("{:.1}%", o.locality.fraction_local() * 100.0),
            format!("{:.0} ms", o.wall_ms),
        ]);
    }
    println!(
        "{}",
        table(
            &[
                "scheduler",
                "mean sojourn (s)",
                "small",
                "medium",
                "large",
                "locality",
                "sim wall"
            ],
            &rows
        )
    );
    println!(
        "headline: FIFO/HFSP mean-sojourn ratio = {:.1}x (paper: ~5x on their loaded testbed)",
        fifo_mean / hfsp_mean
    );
}
