//! Preemption-discipline study (the paper's Fig. 7 scenario, §4.3).
//!
//! Five reduce-only jobs on a 4-node × 2-reduce-slot cluster: a long job
//! j1, then four short jobs ten seconds later. Compares eager
//! SUSPEND/RESUME against WAIT and KILL, printing the per-job slot
//! allocation timelines.
//!
//! ```bash
//! cargo run --release --example preemption_study
//! ```

use hfsp::prelude::*;
use hfsp::workload::synthetic::fig7_workload;

fn main() {
    hfsp::util::logging::init_from_env();
    let cfg = SimConfig {
        cluster: ClusterConfig {
            nodes: 4,
            map_slots: 1,
            reduce_slots: 2,
            ..Default::default()
        },
        record_timelines: true,
        ..Default::default()
    };
    let wl = fig7_workload();
    println!("workload: j1 = 11 x 500 s reduce tasks @t=140 s; j2..j5 = 5 x 60 s tasks @t=150 s");
    println!("cluster:  4 nodes x 2 reduce slots = 8 slots\n");

    for prim in [
        PreemptionPrimitive::Suspend,
        PreemptionPrimitive::Wait,
        PreemptionPrimitive::Kill,
    ] {
        let o = Simulation::new(cfg.clone())
            .scheduler(SchedulerKind::SizeBased(HfspConfig {
                preemption: prim,
                ..Default::default()
            }))
            .workload(wl.as_source())
            .run();
        println!(
            "=== {} — mean sojourn {:.1} min ===",
            prim.name(),
            o.sojourn.mean() / 60.0
        );
        print!("{}", o.timelines.ascii_chart(120.0, o.makespan, 90));
        println!(
            "suspends {}, resumes {}, kills {}, j1 finish {:.0} s\n",
            o.counters.suspends,
            o.counters.resumes,
            o.counters.kills,
            o.sojourn.by_job()[&1] + 140.0
        );
    }
    println!("paper shape: eager preemption suspends only the tasks j2..j5 need,");
    println!("cutting the average sojourn by ~40% vs WAIT; KILL wastes j1's work.");
}
