//! Fig. 6 — robustness of HFSP to job-size estimation errors.
//!
//! MAP-only version of the FB-dataset (as in the paper, to avoid error
//! propagation across phases). A "wrong" estimate is uniform in
//! [θ(1−α), θ(1+α)] for α ∈ [0.1, 1.0]; each α is repeated over several
//! seeds. References: error-free HFSP and FAIR (independent of errors).
//!
//! Paper shape: mean sojourn is essentially flat in α and stays below
//! FAIR — wrong estimates only reorder jobs within a class.

use hfsp::cluster::driver::{run_simulation, SimConfig};
use hfsp::report::{ascii_chart, table, write_csv, Series};
use hfsp::scheduler::core::HfspConfig;
use hfsp::scheduler::SchedulerKind;
use hfsp::util::rng::{Pcg64, SeedableRng};
use hfsp::util::stats::Moments;
use hfsp::workload::swim::FbWorkload;
use std::path::Path;

fn main() {
    hfsp::util::logging::init_from_env();
    let cfg = SimConfig::default();
    let wl = FbWorkload::default()
        .generate(&mut Pcg64::seed_from_u64(42))
        .map_only();
    let repeats: u64 = std::env::var("HFSP_FIG6_REPEATS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(10);

    let fair = run_simulation(&cfg, SchedulerKind::Fair(Default::default()), &wl);
    let exact = run_simulation(&cfg, SchedulerKind::SizeBased(Default::default()), &wl);
    println!(
        "references: FAIR mean {:.1} s | error-free HFSP mean {:.1} s | {} repeats/alpha",
        fair.sojourn.mean(),
        exact.sojourn.mean(),
        repeats
    );

    let mut pts = Vec::new();
    let mut rows = Vec::new();
    for step in 1..=10 {
        let alpha = step as f64 / 10.0;
        let mut m = Moments::new();
        for rep in 0..repeats {
            let hcfg = HfspConfig {
                error_alpha: alpha,
                error_seed: 1000 + rep,
                ..Default::default()
            };
            let o = run_simulation(&cfg, SchedulerKind::SizeBased(hcfg), &wl);
            m.push(o.sojourn.mean());
        }
        pts.push((alpha, m.mean()));
        rows.push(vec![
            format!("{alpha:.1}"),
            format!("{:.1}", m.mean()),
            format!("{:.1}", m.std()),
            format!("{:.2}", m.mean() / exact.sojourn.mean()),
        ]);
    }
    let series = vec![
        Series::new("HFSP(alpha)", pts.clone()),
        Series::new("FAIR", vec![(0.1, fair.sojourn.mean()), (1.0, fair.sojourn.mean())]),
        Series::new(
            "HFSP exact",
            vec![(0.1, exact.sojourn.mean()), (1.0, exact.sojourn.mean())],
        ),
    ];
    println!(
        "{}",
        ascii_chart(
            "Fig 6 — mean sojourn (s) vs injected estimation error alpha",
            &series,
            72,
            14,
            false
        )
    );
    println!(
        "{}",
        table(
            &["alpha", "mean sojourn (s)", "std", "vs error-free"],
            &rows
        )
    );
    write_csv(Path::new("reports/fig6_estimation_error.csv"), &series).expect("write csv");

    let worst = pts.iter().map(|&(_, y)| y).fold(f64::MIN, f64::max);
    println!(
        "worst-alpha degradation vs error-free: {:.1}% (paper: slight, only at extreme errors)",
        (worst / exact.sojourn.mean() - 1.0) * 100.0
    );
    println!("\nCSV written to reports/fig6_estimation_error.csv");
}
