//! §Perf — hot-path microbenchmarks and end-to-end throughput.
//!
//! Run with `cargo bench --bench perf_hot_paths`. Measures (wall clock,
//! custom harness — criterion is unavailable offline):
//!
//! * water-filling allocation at several job counts (the per-event cost
//!   of the virtual cluster's aging step);
//! * projected-finish-order fluid simulation at several job counts;
//! * full FB-dataset macro runs per scheduler (events/second);
//! * PJRT artifact execution latency (when artifacts are built).

use hfsp::bench::Bench;
use hfsp::cluster::driver::{run_simulation, SimConfig};
use hfsp::runtime::{ArtifactSet, EstimatorExec, MaxMinExec};
use hfsp::scheduler::core::virtual_cluster::{maxmin_waterfill, VirtualCluster};
use hfsp::scheduler::SchedulerKind;
use hfsp::util::rng::{Pcg64, Rng, SeedableRng};
use hfsp::workload::swim::FbWorkload;
use std::path::Path;
use std::rc::Rc;

fn main() {
    hfsp::util::logging::init_from_env();
    let mut b = Bench::new().with_samples(2, 10);

    // -- water-filling ------------------------------------------------
    let mut rng = Pcg64::seed_from_u64(1);
    for n in [8usize, 64, 256] {
        let demands: Vec<f64> = (0..n).map(|_| rng.gen_range_f64(0.0, 100.0)).collect();
        b.run(&format!("maxmin_waterfill n={n}"), || {
            maxmin_waterfill(&demands, 400.0)
        });
    }

    // -- fluid projection ----------------------------------------------
    for n in [10usize, 40, 100] {
        let mut vc = VirtualCluster::new(400);
        let mut rng = Pcg64::seed_from_u64(2);
        for id in 0..n as u64 {
            let tasks = 1 + rng.gen_index(500);
            vc.add_job(id, tasks as f64 * rng.gen_range_f64(10.0, 60.0), tasks, 0.0);
        }
        b.run(&format!("fluid projected_finish_order jobs={n}"), || {
            vc.age_to(0.0); // invalidate nothing; cache...
            vc.set_total(0, 1000.0, 0.0); // force recompute
            vc.projected_finish_order().len()
        });
    }

    // -- end-to-end macro runs ------------------------------------------
    let wl = FbWorkload::default().generate(&mut Pcg64::seed_from_u64(42));
    let cfg = SimConfig::default();
    let mut evts = Vec::new();
    for kind in [
        SchedulerKind::Fifo,
        SchedulerKind::Fair(Default::default()),
        SchedulerKind::SizeBased(Default::default()),
    ] {
        let label = kind.label();
        let events = std::cell::Cell::new(0u64);
        let m = b.run(&format!("fb-dataset 100-node macro run [{label}]"), || {
            let o = run_simulation(&cfg, kind.clone(), &wl);
            events.set(o.events_processed);
            o.events_processed
        });
        evts.push((label, events.get(), m.mean_ns()));
    }

    // -- PJRT artifact latency ------------------------------------------
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        let set = Rc::new(ArtifactSet::load(&dir).expect("artifacts load"));
        let est = EstimatorExec::new(set.clone());
        let mm = MaxMinExec::new(set);
        let samples = [35.0f64, 36.0, 34.5, 35.5, 35.2];
        b.run("pjrt estimator execute (1 job)", || {
            est.estimate_one(&samples, 300).unwrap()
        });
        let batch: Vec<(&[f64], usize)> = (0..est.batch()).map(|_| (&samples[..], 300)).collect();
        b.run(&format!("pjrt estimator execute (batch={})", est.batch()), || {
            est.estimate_batch(&batch).unwrap().len()
        });
        let demands: Vec<f64> = (0..64).map(|i| (i % 13) as f64).collect();
        b.run("pjrt maxmin execute (64 jobs)", || {
            mm.allocate(&demands, 400.0).unwrap().len()
        });
    } else {
        eprintln!("artifacts not built; skipping PJRT latency benches");
    }

    println!();
    b.print_table();
    println!();
    for (label, events, ns) in evts {
        println!(
            "{label}: {events} events, {:.2} M events/s",
            events as f64 / (ns / 1e9) / 1e6
        );
    }
    std::fs::create_dir_all("reports").ok();
    std::fs::write("reports/perf_hot_paths.json", b.to_json().to_string_pretty())
        .expect("write perf json");
    println!("\nJSON written to reports/perf_hot_paths.json");
}
