//! Fig. 7 — resource allocation under the preemption primitives.
//!
//! The paper's micro-benchmark: 4 machines × 2 reduce slots; j1 (11
//! reduce tasks × ~500 s) arrives at 2:20, then j2..j5 (5 small reduce
//! tasks) at 2:30. With **eager preemption** the small jobs suspend just
//! enough of j1's tasks and the average sojourn is ~9 min; with **WAIT**
//! they queue behind j1's 500 s tasks and the average is ~15 min (~40 %
//! worse); **KILL** additionally wastes j1's work.
//!
//! Thin declaration over the sweep engine: one labelled HFSP scheduler
//! per preemption primitive; this file only renders the timelines.

use hfsp::cluster::driver::SimConfig;
use hfsp::cluster::ClusterConfig;
use hfsp::report::table;
use hfsp::scheduler::core::{HfspConfig, PreemptionPrimitive};
use hfsp::scheduler::SchedulerKind;
use hfsp::sweep::{run_grid, ExperimentGrid, WorkloadSpec};

fn main() {
    hfsp::util::logging::init_from_env();
    let base = SimConfig {
        cluster: ClusterConfig {
            nodes: 4,
            map_slots: 1,
            reduce_slots: 2,
            ..Default::default()
        },
        record_timelines: true,
        ..Default::default()
    };
    let primitives = [
        PreemptionPrimitive::Suspend,
        PreemptionPrimitive::Wait,
        PreemptionPrimitive::Kill,
    ];
    let mut grid = ExperimentGrid::new("fig7")
        .base_config(base)
        .workload(WorkloadSpec::Fig7)
        .nodes(&[4])
        .seeds(&[42]);
    for prim in primitives {
        grid = grid.scheduler_labeled(
            prim.name(),
            SchedulerKind::SizeBased(HfspConfig {
                preemption: prim,
                ..Default::default()
            }),
        );
    }
    let results = run_grid(&grid);

    let mut rows = Vec::new();
    let mut sojourns = Vec::new();
    for prim in primitives {
        let o = results.outcome(prim.name(), 4, 42).expect("cell ran");
        println!(
            "--- HFSP with {} (mean sojourn {:.1} s = {:.1} min) ---",
            prim.name(),
            o.sojourn.mean(),
            o.sojourn.mean() / 60.0
        );
        print!("{}", o.timelines.ascii_chart(120.0, o.makespan, 90));
        println!(
            "    suspends {} resumes {} kills {} | j1 sojourn {:.0} s\n",
            o.counters.suspends,
            o.counters.resumes,
            o.counters.kills,
            o.sojourn.by_job()[&1]
        );
        rows.push(vec![
            prim.name().to_string(),
            format!("{:.1}", o.sojourn.mean() / 60.0),
            format!("{:.0}", o.sojourn.by_job()[&1]),
            o.counters.suspends.to_string(),
            o.counters.kills.to_string(),
        ]);
        sojourns.push((prim, o.sojourn.mean()));
    }
    println!(
        "{}",
        table(
            &["primitive", "mean sojourn (min)", "j1 sojourn (s)", "suspends", "kills"],
            &rows
        )
    );
    let eager = sojourns[0].1;
    let wait = sojourns[1].1;
    println!(
        "WAIT / eager mean-sojourn ratio = {:.2} (paper: 15 min vs 9 min ≈ 1.67, \"roughly 40% larger\")",
        wait / eager
    );
}
