//! §4.3 "Impact of data locality" — fraction of MAP tasks reading local
//! data, FAIR vs HFSP, aggregated over the macro-benchmark runs.
//!
//! Paper: FAIR 98 %, HFSP 100 % over >14 000 map tasks — both use delay
//! scheduling; HFSP benefits further from focusing whole jobs.

use hfsp::cluster::driver::{run_simulation, SimConfig};
use hfsp::metrics::LocalityStats;
use hfsp::report::table;
use hfsp::scheduler::SchedulerKind;
use hfsp::util::rng::{Pcg64, SeedableRng};
use hfsp::workload::swim::FbWorkload;

fn main() {
    hfsp::util::logging::init_from_env();
    let mut fair_total = LocalityStats::default();
    let mut hfsp_total = LocalityStats::default();
    let mut fifo_total = LocalityStats::default();
    for seed in [42u64, 7, 1234] {
        let wl = FbWorkload::default().generate(&mut Pcg64::seed_from_u64(seed));
        let cfg = SimConfig {
            seed,
            ..Default::default()
        };
        fifo_total.merge(&run_simulation(&cfg, SchedulerKind::Fifo, &wl).locality);
        fair_total.merge(
            &run_simulation(&cfg, SchedulerKind::Fair(Default::default()), &wl).locality,
        );
        hfsp_total.merge(
            &run_simulation(&cfg, SchedulerKind::SizeBased(Default::default()), &wl).locality,
        );
    }
    let rows = vec![
        vec![
            "FIFO".into(),
            fifo_total.total().to_string(),
            format!("{:.2}%", fifo_total.fraction_local() * 100.0),
        ],
        vec![
            "FAIR".into(),
            fair_total.total().to_string(),
            format!("{:.2}%", fair_total.fraction_local() * 100.0),
        ],
        vec![
            "HFSP".into(),
            hfsp_total.total().to_string(),
            format!("{:.2}%", hfsp_total.fraction_local() * 100.0),
        ],
    ];
    println!("=== §4.3 — map-task data locality (3 seeds, 100 nodes) ===\n");
    println!(
        "{}",
        table(&["scheduler", "map tasks", "local fraction"], &rows)
    );
    println!("paper: FAIR 98%, HFSP 100% over >14,000 tasks (FIFO not reported).");
    assert!(
        hfsp_total.fraction_local() >= fair_total.fraction_local() - 0.01,
        "HFSP locality should not trail FAIR"
    );
}
