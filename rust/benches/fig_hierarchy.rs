//! Hierarchical fair-share convergence under a Zipf tenant population —
//! the repo-specific hierarchy figure (no direct paper counterpart; the
//! scenario is the multi-tenant deployment §5 of the paper gestures at).
//!
//! One saturated session per scheduler: a 3-pool tree with weights
//! 3/2/1 (every leaf running HFSP) versus the flat HFSP scheduler, both
//! fed by the same Zipf(0.5) population of 10k users hashed across 100
//! pool ids (routed onto the 3 leaves by `pool % 3`). [`TenantProbe`]
//! measures what each pool actually received.
//!
//! Expected shape: the hierarchy's measured slot-shares track the
//! configured 1/2 : 1/3 : 1/6 split within a few percent; the flat
//! scheduler ignores pools entirely, so its shares track the demand mix
//! instead and its share-vs-weight error is large.

use hfsp::prelude::*;
use hfsp::report::table;
use hfsp::scheduler::hierarchy::PoolDecl;

fn topology_321() -> Topology {
    let decl = |name: &str, weight: f64| PoolDecl {
        name: name.into(),
        parent: None,
        weight,
        discipline: Some(DisciplineKind::Fsp),
    };
    Topology::from_pools(vec![
        decl("gold", 3.0),
        decl("silver", 2.0),
        decl("bronze", 1.0),
    ])
    .expect("static 3-pool topology is valid")
}

fn main() {
    hfsp::util::logging::init_from_env();
    let jobs: u64 = std::env::var("HFSP_FIG_HIERARCHY_JOBS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(5_000);
    let nodes = 20;
    let cfg = SimConfig {
        cluster: ClusterConfig {
            nodes,
            ..Default::default()
        },
        seed: 42,
        ..Default::default()
    };
    // Offered load ≈ 1.2 on the map slots: the cluster stays saturated
    // until the bounded population drains, so measured slot-shares are
    // steady-state shares.
    let slots = (nodes * cfg.cluster.map_slots) as f64;
    let rate = 1.2 * slots / (2.0 * 8.0);
    let population = || {
        TenantPopulation::new(10_000, 100, rate, f64::INFINITY, 42)
            .mix(JobMix::Uniform { maps: 2, task_s: 8.0 })
            .max_jobs(jobs)
    };

    let weights = [("gold", 3.0), ("silver", 2.0), ("bronze", 1.0)];
    let wsum: f64 = weights.iter().map(|(_, w)| w).sum();

    let mut rows = Vec::new();
    for (label, kind) in [
        (
            "HIER 3/2/1",
            SchedulerKind::Hierarchical(HierarchyConfig::with_topology(topology_321())),
        ),
        ("flat HFSP", SchedulerKind::hfsp()),
    ] {
        let mut probe = TenantProbe::new();
        let outcome = Simulation::new(cfg.clone())
            .scheduler(kind)
            .workload(population())
            .probe(&mut probe)
            .run();
        // Fold the 100 hashed pool ids onto the 3 leaves the tree
        // routes them to (pool % 3), mirroring the scheduler's routing.
        let mut leaf_slot_s = [0.0f64; 3];
        let mut leaf_sojourn = [(0.0f64, 0usize); 3];
        for (&pool, usage) in probe.pools() {
            let leaf = pool as usize % 3;
            leaf_slot_s[leaf] += usage.slot_seconds;
            leaf_sojourn[leaf].0 += usage.sojourn_sum_s;
            leaf_sojourn[leaf].1 += usage.jobs_done;
        }
        let total: f64 = leaf_slot_s.iter().sum();
        for (leaf, (name, w)) in weights.iter().enumerate() {
            let share = if total > 0.0 { leaf_slot_s[leaf] / total } else { 0.0 };
            let want = w / wsum;
            let mean_sojourn = if leaf_sojourn[leaf].1 > 0 {
                leaf_sojourn[leaf].0 / leaf_sojourn[leaf].1 as f64
            } else {
                0.0
            };
            rows.push(vec![
                label.to_string(),
                (*name).to_string(),
                format!("{want:.3}"),
                format!("{share:.3}"),
                format!("{:+.1}%", (share - want) / want * 100.0),
                format!("{mean_sojourn:.0}"),
            ]);
        }
        println!(
            "{label}: {} jobs in {:.0} s makespan, jain(slot-seconds over hashed pools) = {:.3}",
            outcome.sojourn.len(),
            outcome.makespan,
            probe.jain_slot_seconds()
        );
    }
    println!(
        "{}",
        table(
            &[
                "scheduler",
                "pool",
                "weight share",
                "slot share",
                "error",
                "mean sojourn (s)"
            ],
            &rows
        )
    );
}
