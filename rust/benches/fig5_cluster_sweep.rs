//! Fig. 5 — impact of cluster size (hence load) on scheduling
//! performance: mean job sojourn time for FAIR and HFSP as the cluster
//! shrinks from 100 to 10 nodes (same workload ⇒ higher load per node).
//!
//! Paper shape: HFSP's advantage grows as resources become scarce; "for
//! equivalent sojourn times, the workload requires a smaller cluster
//! when HFSP is used".
//!
//! Thin declaration over the sweep engine: FAIR and HFSP × ten cluster
//! sizes is a 20-cell grid run across the thread pool; this file only
//! renders the series and the scarcity-ratio table.

use hfsp::report::{ascii_chart, table, write_csv, Series};
use hfsp::scheduler::SchedulerKind;
use hfsp::sweep::{run_grid, ExperimentGrid, WorkloadSpec};
use hfsp::workload::swim::FbWorkload;
use std::path::Path;

fn main() {
    hfsp::util::logging::init_from_env();
    let sizes = [10usize, 20, 30, 40, 50, 60, 70, 80, 90, 100];
    let grid = ExperimentGrid::new("fig5")
        .scheduler(SchedulerKind::Fair(Default::default()))
        .scheduler(SchedulerKind::SizeBased(Default::default()))
        .workload(WorkloadSpec::Fb(FbWorkload::default()))
        .nodes(&sizes)
        .seeds(&[42]);
    let results = run_grid(&grid);

    let mean_of = |label: &str, nodes: usize| {
        results
            .outcome(label, nodes, 42)
            .expect("cell ran")
            .sojourn
            .mean()
    };
    let mut fair_pts = Vec::new();
    let mut hfsp_pts = Vec::new();
    let mut rows = Vec::new();
    for &nodes in &sizes {
        let fair = mean_of("FAIR", nodes);
        let hfsp = mean_of("HFSP", nodes);
        fair_pts.push((nodes as f64, fair));
        hfsp_pts.push((nodes as f64, hfsp));
        rows.push(vec![
            nodes.to_string(),
            format!("{fair:.0}"),
            format!("{hfsp:.0}"),
            format!("{:.2}", fair / hfsp),
        ]);
    }
    let series = vec![
        Series::new("FAIR", fair_pts.clone()),
        Series::new("HFSP", hfsp_pts.clone()),
    ];
    println!(
        "{}",
        ascii_chart(
            "Fig 5 — mean sojourn (s) vs cluster size (nodes)",
            &series,
            72,
            16,
            false
        )
    );
    println!(
        "{}",
        table(
            &["nodes", "FAIR mean (s)", "HFSP mean (s)", "FAIR/HFSP"],
            &rows
        )
    );
    write_csv(Path::new("reports/fig5_cluster_sweep.csv"), &series).expect("write csv");

    // Shape check: the advantage must grow under scarcity.
    let ratio_small_cluster = fair_pts[0].1 / hfsp_pts[0].1;
    let ratio_big_cluster = fair_pts.last().unwrap().1 / hfsp_pts.last().unwrap().1;
    println!(
        "FAIR/HFSP ratio: {ratio_small_cluster:.2} at {} nodes vs {ratio_big_cluster:.2} at {} nodes (paper: grows under scarcity)",
        sizes[0],
        sizes.last().unwrap()
    );
    println!("\nCSV written to reports/fig5_cluster_sweep.csv");
}
