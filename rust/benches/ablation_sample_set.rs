//! §3.2.1 ablation — sample-set size.
//!
//! The paper: "we have empirically observed that ... a sample set equal
//! to five MAP tasks provides sufficiently high accuracy". We sweep the
//! sample-set size and report mean sojourn + the estimator kind ablation
//! (LSQ quantile fit vs plain mean).

use hfsp::cluster::driver::{run_simulation, SimConfig};
use hfsp::report::table;
use hfsp::scheduler::core::{EstimatorKind, HfspConfig};
use hfsp::scheduler::SchedulerKind;
use hfsp::util::rng::{Pcg64, SeedableRng};
use hfsp::workload::swim::FbWorkload;

fn main() {
    hfsp::util::logging::init_from_env();
    let cfg = SimConfig::default();
    let wl = FbWorkload::default().generate(&mut Pcg64::seed_from_u64(42));

    let mut rows = Vec::new();
    for sample_set in [1usize, 2, 5, 10, 20] {
        for (est_name, est) in [
            ("native-lsq", EstimatorKind::Native),
            ("mean", EstimatorKind::Mean),
        ] {
            let hcfg = HfspConfig {
                sample_set,
                estimator: est,
                ..Default::default()
            };
            let o = run_simulation(&cfg, SchedulerKind::SizeBased(hcfg), &wl);
            rows.push(vec![
                sample_set.to_string(),
                est_name.to_string(),
                format!("{:.1}", o.sojourn.mean()),
                o.counters.suspends.to_string(),
            ]);
        }
    }
    println!("=== §3.2.1 ablation — sample-set size and estimator kind ===\n");
    println!(
        "{}",
        table(
            &["sample set", "estimator", "mean sojourn (s)", "suspends"],
            &rows
        )
    );
    println!("paper: 5 samples suffice; more buys little (trade-off vs training time).");
    println!("resource allocation matters more than estimate accuracy (§3.2).");
}
