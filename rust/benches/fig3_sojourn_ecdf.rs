//! Fig. 3 — ECDFs of sojourn times for the FB-dataset, clustered by job
//! class (small / medium / large), FAIR vs HFSP (FIFO added for
//! reference).
//!
//! Paper shape to reproduce: HFSP ≈ FAIR for small jobs; sojourn times
//! significantly shorter under HFSP for medium and large jobs.
//!
//! Thin declaration over the sweep engine: the grid runs the three
//! schedulers (in parallel) on the same seed-42 FB-dataset; this file
//! only renders the per-class ECDF series.

use hfsp::job::JobClass;
use hfsp::report::{ascii_chart, write_csv, Series};
use hfsp::sweep::{run_grid, ExperimentGrid, WorkloadSpec};
use hfsp::workload::swim::FbWorkload;
use std::path::Path;

fn main() {
    hfsp::util::logging::init_from_env();
    let grid = ExperimentGrid::new("fig3")
        .workload(WorkloadSpec::Fb(FbWorkload::default()))
        .nodes(&[100])
        .seeds(&[42]);
    let results = run_grid(&grid);

    println!("=== Fig. 3: ECDFs of sojourn times (FB-dataset, 100 nodes) ===\n");
    for class in JobClass::ALL {
        let series: Vec<Series> = results
            .outcomes()
            .map(|o| {
                let ecdf = o.sojourn.ecdf(Some(class));
                Series::new(o.scheduler, ecdf.series(64))
            })
            .collect();
        println!(
            "{}",
            ascii_chart(
                &format!("Fig 3 ({}) — P(sojourn <= x)", class.name()),
                &series,
                72,
                14,
                true
            )
        );
        write_csv(
            Path::new(&format!("reports/fig3_{}.csv", class.name())),
            &series,
        )
        .expect("write csv");
        for o in results.outcomes() {
            println!(
                "  {:<5} mean sojourn ({:<6}) = {:>8.1} s",
                o.scheduler,
                class.name(),
                o.sojourn.mean_class(class)
            );
        }
        println!();
    }
    let fair = results.outcome("FAIR", 100, 42).expect("FAIR cell");
    let hfsp = results.outcome("HFSP", 100, 42).expect("HFSP cell");
    println!("paper-shape checks:");
    let small_ratio =
        hfsp.sojourn.mean_class(JobClass::Small) / fair.sojourn.mean_class(JobClass::Small);
    println!("  small-class HFSP/FAIR ratio = {small_ratio:.2} (paper: ~1.0)");
    for class in [JobClass::Medium, JobClass::Large] {
        let r = hfsp.sojourn.mean_class(class) / fair.sojourn.mean_class(class);
        println!(
            "  {}-class HFSP/FAIR ratio = {r:.2} (paper: < 1.0)",
            class.name()
        );
    }
    println!("\nCSV written to reports/fig3_*.csv");
}
