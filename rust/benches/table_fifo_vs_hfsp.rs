//! §4.2 headline numbers — FIFO vs FAIR vs HFSP mean sojourn times.
//!
//! The paper reports a FIFO mean sojourn of 2983 s, "about 5 times
//! bigger than that of HFSP", on the FB-dataset. We regenerate the
//! three-way comparison across seeds and cluster sizes and report the
//! ratios (shape, not absolute numbers: the testbed is a simulator).
//!
//! Thin declaration over the sweep engine: the full 3 schedulers ×
//! 3 cluster sizes × 3 seeds grid (27 simulations) runs across the
//! thread pool; this file only computes the per-seed ratios.

use hfsp::report::table;
use hfsp::sweep::{run_grid, ExperimentGrid, WorkloadSpec};
use hfsp::util::stats::Moments;
use hfsp::workload::swim::FbWorkload;

fn main() {
    hfsp::util::logging::init_from_env();
    let nodes = [100usize, 50, 30];
    let seeds = [42u64, 7, 1234];
    let grid = ExperimentGrid::new("table-fifo-vs-hfsp")
        .workload(WorkloadSpec::Fb(FbWorkload::default()))
        .nodes(&nodes)
        .seeds(&seeds);
    let results = run_grid(&grid);

    let mean_of = |label: &str, n: usize, seed: u64| {
        results
            .outcome(label, n, seed)
            .expect("cell ran")
            .sojourn
            .mean()
    };
    let mut rows = Vec::new();
    for &n in &nodes {
        let mut ratios_fifo = Moments::new();
        let mut ratios_fair = Moments::new();
        let mut hfsp_mean = Moments::new();
        let mut fifo_mean = Moments::new();
        for &seed in &seeds {
            let fifo = mean_of("FIFO", n, seed);
            let fair = mean_of("FAIR", n, seed);
            let hfsp = mean_of("HFSP", n, seed);
            ratios_fifo.push(fifo / hfsp);
            ratios_fair.push(fair / hfsp);
            hfsp_mean.push(hfsp);
            fifo_mean.push(fifo);
        }
        rows.push(vec![
            n.to_string(),
            format!("{:.0}", fifo_mean.mean()),
            format!("{:.0}", hfsp_mean.mean()),
            format!("{:.1}x", ratios_fifo.mean()),
            format!("{:.1}x", ratios_fair.mean()),
        ]);
    }
    println!("=== §4.2 — FIFO vs HFSP (3 seeds per row) ===\n");
    println!(
        "{}",
        table(
            &[
                "nodes",
                "FIFO mean (s)",
                "HFSP mean (s)",
                "FIFO/HFSP",
                "FAIR/HFSP"
            ],
            &rows
        )
    );
    println!("\n=== aggregated sweep report (across-seed CI) ===\n");
    println!("{}", results.aggregate().table());
    println!("paper: FIFO = 2983 s ≈ 5× HFSP on their 100-node EC2 testbed;");
    println!("the ratio is load-dependent — it crosses 5× as the cluster shrinks.");
}
