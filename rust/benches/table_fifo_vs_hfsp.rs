//! §4.2 headline numbers — FIFO vs FAIR vs HFSP mean sojourn times.
//!
//! The paper reports a FIFO mean sojourn of 2983 s, "about 5 times
//! bigger than that of HFSP", on the FB-dataset. We regenerate the
//! three-way comparison across seeds and cluster sizes and report the
//! ratios (shape, not absolute numbers: the testbed is a simulator).

use hfsp::cluster::driver::{run_simulation, SimConfig};
use hfsp::cluster::ClusterConfig;
use hfsp::report::table;
use hfsp::scheduler::SchedulerKind;
use hfsp::util::rng::{Pcg64, SeedableRng};
use hfsp::util::stats::Moments;
use hfsp::workload::swim::FbWorkload;

fn main() {
    hfsp::util::logging::init_from_env();
    let mut rows = Vec::new();
    for &nodes in &[100usize, 50, 30] {
        let mut ratios_fifo = Moments::new();
        let mut ratios_fair = Moments::new();
        let mut hfsp_mean = Moments::new();
        let mut fifo_mean = Moments::new();
        for seed in [42u64, 7, 1234] {
            let wl = FbWorkload::default().generate(&mut Pcg64::seed_from_u64(seed));
            let cfg = SimConfig {
                cluster: ClusterConfig {
                    nodes,
                    ..Default::default()
                },
                seed,
                ..Default::default()
            };
            let fifo = run_simulation(&cfg, SchedulerKind::Fifo, &wl);
            let fair = run_simulation(&cfg, SchedulerKind::Fair(Default::default()), &wl);
            let hfsp = run_simulation(&cfg, SchedulerKind::Hfsp(Default::default()), &wl);
            ratios_fifo.push(fifo.sojourn.mean() / hfsp.sojourn.mean());
            ratios_fair.push(fair.sojourn.mean() / hfsp.sojourn.mean());
            hfsp_mean.push(hfsp.sojourn.mean());
            fifo_mean.push(fifo.sojourn.mean());
        }
        rows.push(vec![
            nodes.to_string(),
            format!("{:.0}", fifo_mean.mean()),
            format!("{:.0}", hfsp_mean.mean()),
            format!("{:.1}x", ratios_fifo.mean()),
            format!("{:.1}x", ratios_fair.mean()),
        ]);
    }
    println!("=== §4.2 — FIFO vs HFSP (3 seeds per row) ===\n");
    println!(
        "{}",
        table(
            &[
                "nodes",
                "FIFO mean (s)",
                "HFSP mean (s)",
                "FIFO/HFSP",
                "FAIR/HFSP"
            ],
            &rows
        )
    );
    println!("paper: FIFO = 2983 s ≈ 5× HFSP on their 100-node EC2 testbed;");
    println!("the ratio is load-dependent — it crosses 5× as the cluster shrinks.");
}
