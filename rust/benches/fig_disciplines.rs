//! Discipline comparison under size-estimation error — the scenario
//! space of *PSBS: Practical Size-Based Scheduling* (arXiv 1410.6122)
//! and the sensitivity study of *Revisiting Size-Based Scheduling with
//! Estimated Job Sizes* (arXiv 1403.5996).
//!
//! Grid: {HFSP, SRPT, LAS, PSBS} × log-normal estimation-error σ ∈
//! {0 (baseline), 0.25, 0.5, 1.0, 2.0} × seeds, on the MAP-only
//! FB-dataset (as in Fig. 6: no cross-phase error propagation). The
//! headline output is **degradation vs σ** per discipline: mean sojourn
//! relative to that discipline's error-free baseline
//! (`sojourn_vs_fault_free` in the aggregate).
//!
//! Expected shape (arXiv 1403.5996): SRPT degrades fastest — an
//! under-estimated large job camps at the queue head; HFSP's fair-
//! sojourn aging and PSBS's late binding stay near-flat for moderate σ;
//! LAS is exactly flat — it never reads an estimate.

use hfsp::prelude::*;
use hfsp::report::{ascii_chart, table, Series};

fn main() {
    hfsp::util::logging::init_from_env();
    let scale: f64 = std::env::var("HFSP_FIG_DISCIPLINES_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.3);
    let sigmas = [0.25, 0.5, 1.0, 2.0];
    let mut scenarios = vec![FaultSpec::none()];
    for &sigma in &sigmas {
        scenarios.push(FaultSpec::new(
            format!("sigma-{sigma:.2}"),
            FaultConfig {
                enabled: true,
                size_error_sigma: sigma,
                ..FaultConfig::disabled()
            },
        ));
    }

    let mut grid = ExperimentGrid::new("fig-disciplines")
        .workload(WorkloadSpec::FbMapOnly(FbWorkload::scaled(scale)))
        .nodes(&[20])
        .seeds(&[1, 2, 3])
        .fault_scenarios(&scenarios);
    for kind in DisciplineKind::ALL {
        grid = grid.scheduler(SchedulerKind::size_based(kind));
    }
    let results = run_grid(&grid);
    let report = results.aggregate();
    println!("{}", report.table());

    // Degradation-vs-sigma per discipline (σ = 0 ⇒ 1.0 by definition).
    let mut series = Vec::new();
    let mut rows = Vec::new();
    for kind in DisciplineKind::ALL {
        let label = kind.label();
        let mut pts = vec![(0.0, 1.0)];
        let mut row = vec![label.to_string(), "1.00x".to_string()];
        for (i, &sigma) in sigmas.iter().enumerate() {
            let group = report.group_faulted(
                "fb-dataset-map-only",
                20,
                &scenarios[i + 1].label,
                label,
            );
            let degradation = group.and_then(|g| g.vs_fault_free);
            match degradation {
                Some(d) => {
                    pts.push((sigma, d));
                    row.push(format!("{d:.2}x"));
                }
                None => row.push("-".to_string()),
            }
        }
        series.push(Series::new(label, pts));
        rows.push(row);
    }
    println!(
        "{}",
        ascii_chart(
            "fig_disciplines — mean-sojourn degradation vs estimation-error sigma",
            &series,
            72,
            14,
            false
        )
    );
    let mut headers = vec!["discipline".to_string(), "sigma=0".to_string()];
    headers.extend(sigmas.iter().map(|s| format!("sigma={s}")));
    let header_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
    println!("{}", table(&header_refs, &rows));

    std::fs::create_dir_all("reports").expect("create reports dir");
    std::fs::write(
        "reports/fig_disciplines.json",
        report.to_json().to_string_pretty(),
    )
    .expect("write report");
    println!("\nwrote reports/fig_disciplines.json");
}
