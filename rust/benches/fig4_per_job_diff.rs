//! Fig. 4 — per-job difference between FAIR and HFSP sojourn times.
//!
//! Paper shape: almost every job does at least as well under HFSP; a
//! single tiny job was 9 s worse (attributed to slot-availability
//! asynchrony). We report the full sorted difference series and count
//! regressions — the experimental analogue of the FSP dominance theorem.

use hfsp::cluster::driver::{run_simulation, SimConfig};
use hfsp::report::{ascii_chart, write_csv, Series};
use hfsp::scheduler::SchedulerKind;
use hfsp::util::rng::{Pcg64, SeedableRng};
use hfsp::workload::swim::FbWorkload;
use std::path::Path;

fn main() {
    hfsp::util::logging::init_from_env();
    let cfg = SimConfig::default();
    let wl = FbWorkload::default().generate(&mut Pcg64::seed_from_u64(42));
    let fair = run_simulation(&cfg, SchedulerKind::Fair(Default::default()), &wl);
    let hfsp = run_simulation(&cfg, SchedulerKind::SizeBased(Default::default()), &wl);

    let f = fair.sojourn.by_job();
    let h = hfsp.sojourn.by_job();
    let mut diffs: Vec<f64> = f.iter().map(|(id, fs)| fs - h[id]).collect();
    diffs.sort_by(|a, b| a.total_cmp(b));

    let series = vec![Series::new(
        "FAIR - HFSP sojourn (s)",
        diffs
            .iter()
            .enumerate()
            .map(|(i, &d)| (i as f64, d))
            .collect(),
    )];
    println!(
        "{}",
        ascii_chart(
            "Fig 4 — per-job sojourn difference (FAIR − HFSP), sorted",
            &series,
            72,
            16,
            false
        )
    );
    write_csv(Path::new("reports/fig4_per_job_diff.csv"), &series).expect("write csv");

    let regressions: Vec<f64> = diffs.iter().copied().filter(|d| *d < -0.5).collect();
    let improved = diffs.iter().filter(|d| **d > 0.5).count();
    println!("jobs improved under HFSP: {improved} / {}", diffs.len());
    println!(
        "jobs regressed under HFSP: {} (worst {:.1} s; paper saw one job at -9 s)",
        regressions.len(),
        regressions.first().copied().unwrap_or(0.0)
    );
    println!(
        "mean improvement: {:.1} s; max improvement: {:.1} s",
        diffs.iter().sum::<f64>() / diffs.len() as f64,
        diffs.last().copied().unwrap_or(0.0)
    );
    println!("\nCSV written to reports/fig4_per_job_diff.csv");
}
