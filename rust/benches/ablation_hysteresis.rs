//! §3.3 ablation — suspension thresholds with hysteresis.
//!
//! The pathological workload the paper mentions ("a large number of jobs
//! arriving in decreasing size"): every arrival preempts its
//! predecessor; without a bound on suspended contexts, parked tasks pile
//! up. We compare tight vs effectively-disabled hysteresis thresholds.

use hfsp::cluster::driver::{run_simulation, SimConfig};
use hfsp::cluster::ClusterConfig;
use hfsp::report::table;
use hfsp::scheduler::core::HfspConfig;
use hfsp::scheduler::SchedulerKind;
use hfsp::workload::synthetic::decreasing_size_workload;

fn main() {
    hfsp::util::logging::init_from_env();
    let cfg = SimConfig {
        cluster: ClusterConfig {
            nodes: 4,
            map_slots: 1,
            reduce_slots: 2,
            ..Default::default()
        },
        ..Default::default()
    };
    // 12 jobs, each wanting all 8 reduce slots, sizes decreasing 0.7x.
    let wl = decreasing_size_workload(12, 8, 800.0);

    let mut rows = Vec::new();
    for (label, hi, lo) in [
        ("tight (hi=8, lo=4)", 8usize, 4usize),
        ("loose (hi=32, lo=16)", 32, 16),
        ("disabled (hi=10^6)", 1_000_000, 500_000),
    ] {
        let hcfg = HfspConfig {
            suspend_hi: hi,
            suspend_lo: lo,
            ..Default::default()
        };
        let o = run_simulation(&cfg, SchedulerKind::SizeBased(hcfg), &wl);
        rows.push(vec![
            label.to_string(),
            format!("{:.1}", o.sojourn.mean()),
            o.counters.suspends.to_string(),
            o.counters.swap_ins.to_string(),
            format!("{:.0}", o.makespan),
        ]);
    }
    println!("=== §3.3 ablation — suspension-threshold hysteresis ===");
    println!("(12 jobs in strictly decreasing size, each wanting the whole cluster)\n");
    println!(
        "{}",
        table(
            &["thresholds", "mean sojourn (s)", "suspends", "swap-ins", "makespan (s)"],
            &rows
        )
    );
    println!("paper: when too many tasks are suspended HFSP falls back to WAIT,");
    println!("bounding memory pressure at a small sojourn cost.");
}
