//! Robustness figure — scheduler performance under fault & perturbation
//! scenarios (the evaluation HFSP's "practical" claim rests on: size-based
//! scheduling must survive node churn, stragglers and estimation error).
//!
//! Grid: {FIFO, FAIR, HFSP} × {none, churn, stragglers, error, full} ×
//! seeds, on a scaled FB-dataset. The aggregate table carries the fault
//! columns (wasted work, re-executed tasks, speculative win rate, sojourn
//! degradation vs the fault-free baseline); the chart plots mean sojourn
//! per scenario.
//!
//! Expected shape: HFSP's mean sojourn stays well below FIFO's in every
//! scenario — faults degrade everyone, but size-based ordering keeps its
//! advantage because estimates only need to be *ordinally* right.

use hfsp::prelude::*;
use hfsp::report::{ascii_chart, Series};

fn main() {
    hfsp::util::logging::init_from_env();
    let scale: f64 = std::env::var("HFSP_FIG_FAULTS_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.5);
    let scenarios = FaultSpec::grid();
    let grid = ExperimentGrid::new("fig-faults")
        .scheduler(SchedulerKind::Fifo)
        .scheduler(SchedulerKind::Fair(Default::default()))
        .scheduler(SchedulerKind::SizeBased(HfspConfig::default()))
        .workload(WorkloadSpec::Fb(FbWorkload::scaled(scale)))
        .nodes(&[20])
        .seeds(&[1, 2, 3])
        .fault_scenarios(&scenarios);
    let results = run_grid(&grid);
    let report = results.aggregate();
    println!("{}", report.table());

    // Mean sojourn per scenario, one series per scheduler (scenario index
    // on x: 0=none, 1=churn, 2=stragglers, 3=error, 4=full).
    let mut series = Vec::new();
    for sched in ["FIFO", "FAIR", "HFSP"] {
        let pts: Vec<(f64, f64)> = scenarios
            .iter()
            .enumerate()
            .filter_map(|(i, sc)| {
                report
                    .group_faulted("fb-dataset", 20, &sc.label, sched)
                    .map(|g| (i as f64, g.mean_sojourn.mean()))
            })
            .collect();
        series.push(Series::new(sched, pts));
    }
    println!(
        "{}",
        ascii_chart(
            "fig_faults — mean sojourn (s) by scenario [0=none 1=churn 2=stragglers 3=error 4=full]",
            &series,
            72,
            14,
            false
        )
    );

    for sc in &scenarios[1..] {
        let hfsp = report.group_faulted("fb-dataset", 20, &sc.label, "HFSP");
        let fifo = report.group_faulted("fb-dataset", 20, &sc.label, "FIFO");
        if let (Some(h), Some(f)) = (hfsp, fifo) {
            println!(
                "{:<12} FIFO/HFSP sojourn ratio {:.2}x | HFSP degradation vs fault-free {}",
                sc.label,
                f.mean_sojourn.mean() / h.mean_sojourn.mean(),
                h.vs_fault_free
                    .map(|r| format!("{r:.2}x"))
                    .unwrap_or_else(|| "-".into()),
            );
        }
    }

    std::fs::create_dir_all("reports").expect("create reports dir");
    std::fs::write(
        "reports/fig_faults.json",
        report.to_json().to_string_pretty(),
    )
    .expect("write report");
    println!("\nwrote reports/fig_faults.json");
}
