//! Simulation sessions: the builder entry point of the simulator.
//!
//! A **session** wires four things together and runs the event loop:
//!
//! ```text
//!   SimConfig ──┐
//!   SchedulerKind ──┤
//!   WorkloadSource ─┼──▶ Simulation::run() ──▶ SimOutcome
//!   Probe* ────────┘         (run_session)
//! ```
//!
//! * the [`WorkloadSource`] supplies jobs *on pull* — a closed
//!   [`Workload`](crate::workload::Workload) vector, an open Poisson /
//!   diurnal generator ([`OpenArrivals`](crate::workload::OpenArrivals)),
//!   or a streaming JSONL trace
//!   ([`TraceSource`](crate::workload::trace::TraceSource));
//! * [`Probe`]s observe the run incrementally and can stop it early.
//!
//! ```no_run
//! use hfsp::prelude::*;
//!
//! // Closed replay, builder style:
//! let wl = FbWorkload::default().generate(&mut Pcg64::seed_from_u64(42));
//! let outcome = Simulation::new(SimConfig::default())
//!     .scheduler(SchedulerKind::hfsp())
//!     .workload(wl.into_source())
//!     .run();
//! println!("mean sojourn {:.1}s", outcome.sojourn.mean());
//!
//! // Open Poisson arrivals with an early-halt probe:
//! let mut halt = JobLimitProbe::new(10_000);
//! let outcome = Simulation::new(SimConfig::default())
//!     .scheduler(SchedulerKind::from_name("psbs").unwrap())
//!     .workload(OpenArrivals::poisson(0.08, 1e6))
//!     .probe(&mut halt)
//!     .run();
//! assert!(outcome.halted_by_probe || outcome.jobs_arrived <= 10_000);
//! ```

use crate::cluster::driver::{run_session, SimConfig, SimOutcome};
use crate::metrics::Probe;
use crate::scheduler::SchedulerKind;
use crate::workload::WorkloadSource;

/// Builder for one simulation session. See the [module docs](self).
pub struct Simulation<'a> {
    cfg: SimConfig,
    kind: SchedulerKind,
    source: Option<Box<dyn WorkloadSource + 'a>>,
    probes: Vec<&'a mut dyn Probe>,
}

impl<'a> Simulation<'a> {
    /// Start a session on the given configuration. The scheduler
    /// defaults to HFSP; a workload source must be supplied before
    /// [`run`](Simulation::run).
    pub fn new(cfg: SimConfig) -> Self {
        Self {
            cfg,
            kind: SchedulerKind::hfsp(),
            source: None,
            probes: Vec::new(),
        }
    }

    /// Select the scheduler.
    pub fn scheduler(mut self, kind: SchedulerKind) -> Self {
        self.kind = kind;
        self
    }

    /// Shard the simulation ([`ShardSpec`]): partition the cluster into
    /// `spec.count` shards merged deterministically (byte-identical to
    /// serial) or run on real threads under a conservative window
    /// barrier (`MergeMode::Fast`).
    ///
    /// [`ShardSpec`]: crate::sim::ShardSpec
    pub fn shards(mut self, spec: crate::sim::ShardSpec) -> Self {
        self.cfg.shards = spec;
        self
    }

    /// Attach the workload source (closed replay, open generator, or
    /// streaming trace).
    pub fn workload(mut self, source: impl WorkloadSource + 'a) -> Self {
        self.source = Some(Box::new(source));
        self
    }

    /// Attach a custom probe (may be called repeatedly). The probe is
    /// borrowed, so its final state is readable after the run:
    ///
    /// ```no_run
    /// # use hfsp::prelude::*;
    /// # let wl = FbWorkload::default().generate(&mut Pcg64::seed_from_u64(1));
    /// let mut limit = JobLimitProbe::new(50);
    /// let outcome = Simulation::new(SimConfig::default())
    ///     .workload(wl.into_source())
    ///     .probe(&mut limit)
    ///     .run();
    /// assert_eq!(limit.seen(), outcome.sojourn.len());
    /// ```
    pub fn probe(mut self, probe: &'a mut dyn Probe) -> Self {
        self.probes.push(probe);
        self
    }

    /// Run the session to completion (source drained and cluster empty,
    /// probe halt, or the event-limit guard).
    ///
    /// # Panics
    ///
    /// If no workload source was attached.
    pub fn run(self) -> SimOutcome {
        let mut source = self
            .source
            .expect("Simulation::run called without a workload source — call .workload(...)");
        run_session(&self.cfg, self.kind, source.as_mut(), self.probes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::JobLimitProbe;
    use crate::workload::synthetic;

    #[test]
    fn builder_runs_a_closed_session() {
        let wl = synthetic::uniform_batch(3, 2, 4.0);
        let mut cfg = SimConfig::default();
        cfg.cluster.nodes = 2;
        let outcome = Simulation::new(cfg)
            .scheduler(SchedulerKind::Fifo)
            .workload(wl.as_source())
            .run();
        assert_eq!(outcome.sojourn.len(), 3);
        assert_eq!(outcome.scheduler, "FIFO");
        assert_eq!(outcome.workload, "uniform-batch");
    }

    #[test]
    fn builder_matches_run_simulation_exactly() {
        let wl = synthetic::fig7_workload();
        let mut cfg = SimConfig::default();
        cfg.cluster.nodes = 4;
        cfg.cluster.map_slots = 1;
        cfg.cluster.reduce_slots = 2;
        let a = crate::cluster::driver::run_simulation(&cfg, SchedulerKind::hfsp(), &wl);
        let b = Simulation::new(cfg)
            .scheduler(SchedulerKind::hfsp())
            .workload(wl.as_source())
            .run();
        assert_eq!(a.events_processed, b.events_processed);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.sojourn.mean(), b.sojourn.mean());
        assert_eq!(a.counters.suspends, b.counters.suspends);
    }

    #[test]
    fn probe_state_is_readable_after_the_run() {
        let wl = synthetic::uniform_batch(5, 1, 2.0);
        let mut cfg = SimConfig::default();
        cfg.cluster.nodes = 2;
        let mut limit = JobLimitProbe::new(2);
        let outcome = Simulation::new(cfg)
            .scheduler(SchedulerKind::Fifo)
            .workload(wl.as_source())
            .probe(&mut limit)
            .run();
        assert!(outcome.halted_by_probe);
        assert_eq!(limit.seen(), 2);
        assert_eq!(outcome.sojourn.len(), 2, "stopped after the second job");
    }

    #[test]
    #[should_panic(expected = "without a workload source")]
    fn run_without_source_panics_with_guidance() {
        let _ = Simulation::new(SimConfig::default()).run();
    }

    #[test]
    fn deterministic_shards_match_serial() {
        let wl = synthetic::fig7_workload();
        let mut cfg = SimConfig::default();
        cfg.cluster.nodes = 4;
        cfg.cluster.map_slots = 1;
        let serial = Simulation::new(cfg.clone()).workload(wl.as_source()).run();
        let sharded = Simulation::new(cfg)
            .shards(crate::sim::ShardSpec {
                count: 2,
                ..Default::default()
            })
            .workload(wl.as_source())
            .run();
        assert_eq!(serial.events_processed, sharded.events_processed);
        assert_eq!(serial.makespan, sharded.makespan);
        assert_eq!(serial.sojourn.mean(), sharded.sojourn.mean());
        assert_eq!(serial.counters.launches, sharded.counters.launches);
    }
}
