//! Naive reference implementations retained for differential testing.
//!
//! [`NaiveVirtualCluster`] is the pre-optimization virtual cluster kept
//! alive as an executable specification: map-backed storage, no order
//! cache — every [`NaiveVirtualCluster::projected_finish_order`] call
//! re-runs the fluid-forward projection from scratch and re-sorts. The
//! incremental production implementation
//! ([`crate::scheduler::core::virtual_cluster::VirtualCluster`]) must
//! agree with it on every projected order and every virtual finish time
//! across the scenario matrix (`tests/integration_perf.rs`); any cache
//! invalidation bug shows up as a divergence here long before it would
//! corrupt a golden file.
//!
//! Deliberately simple, deliberately slow — do not "optimize" this
//! module; its value is being obviously correct.

use crate::job::JobId;
use crate::scheduler::core::virtual_cluster::maxmin_waterfill;
use crate::sim::Time;
use std::collections::BTreeMap;

#[derive(Clone, Debug)]
struct NaiveVJob {
    total: f64,
    aged: f64,
    tau: f64,
    width_cap: f64,
}

impl NaiveVJob {
    fn remaining(&self) -> f64 {
        (self.total - self.aged).max(0.0)
    }

    fn width(&self) -> f64 {
        if self.tau <= 0.0 {
            return 0.0;
        }
        (self.remaining() / self.tau).ceil().min(self.width_cap)
    }
}

/// The uncached, map-backed PS reference simulation (see module docs).
pub struct NaiveVirtualCluster {
    slots: f64,
    jobs: BTreeMap<JobId, NaiveVJob>,
    last_event: Time,
}

impl NaiveVirtualCluster {
    pub fn new(slots: usize) -> Self {
        assert!(slots > 0, "virtual cluster needs capacity");
        Self {
            slots: slots as f64,
            jobs: BTreeMap::new(),
            last_event: 0.0,
        }
    }

    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    pub fn contains(&self, id: JobId) -> bool {
        self.jobs.contains_key(&id)
    }

    pub fn remaining(&self, id: JobId) -> Option<f64> {
        self.jobs.get(&id).map(NaiveVJob::remaining)
    }

    pub fn total_remaining(&self) -> f64 {
        self.jobs.values().map(NaiveVJob::remaining).sum()
    }

    pub fn age_to(&mut self, now: Time) {
        let dt = now - self.last_event;
        if dt < 0.0 {
            return;
        }
        self.last_event = now;
        if dt == 0.0 || self.jobs.is_empty() {
            return;
        }
        // BTreeMap iteration = ascending job id, matching the production
        // implementation's sorted arrays so float accumulation order is
        // identical and the differential comparison can be tight.
        let ids: Vec<JobId> = self.jobs.keys().copied().collect();
        let demands: Vec<f64> = ids
            .iter()
            .map(|id| self.jobs[id].width().min(self.slots))
            .collect();
        let alloc = maxmin_waterfill(&demands, self.slots);
        for (id, a) in ids.iter().zip(alloc) {
            let j = self.jobs.get_mut(id).unwrap();
            j.aged = (j.aged + a * dt).min(j.total);
        }
    }

    pub fn add_job(&mut self, id: JobId, total: f64, n_tasks: usize, now: Time) {
        self.age_to(now);
        let total = total.clamp(0.0, f64::MAX);
        let width_cap = n_tasks.max(1) as f64;
        self.jobs.insert(
            id,
            NaiveVJob {
                total,
                aged: 0.0,
                tau: (total / width_cap).max(f64::MIN_POSITIVE),
                width_cap,
            },
        );
    }

    pub fn remove_job(&mut self, id: JobId, now: Time) {
        self.age_to(now);
        self.jobs.remove(&id);
    }

    pub fn set_total(&mut self, id: JobId, new_total: f64, now: Time) {
        self.age_to(now);
        if let Some(j) = self.jobs.get_mut(&id) {
            j.total = new_total.clamp(0.0, f64::MAX);
            j.tau = (j.total / j.width_cap).max(f64::MIN_POSITIVE);
        }
    }

    /// Projected PS finish order, recomputed from scratch on every call.
    pub fn projected_finish_order(&self) -> Vec<(JobId, Time)> {
        let mut live: Vec<(JobId, NaiveVJob)> =
            self.jobs.iter().map(|(&id, j)| (id, j.clone())).collect();
        let mut finished: Vec<(JobId, Time)> = Vec::with_capacity(live.len());
        let mut t = self.last_event;
        live.retain(|(id, j)| {
            if j.remaining() <= 0.0 {
                finished.push((*id, t));
                false
            } else {
                true
            }
        });
        let mut guard = 0usize;
        while !live.is_empty() {
            guard += 1;
            if guard > 100_000 {
                for (id, _) in &live {
                    finished.push((*id, f64::INFINITY));
                }
                break;
            }
            let demands: Vec<f64> =
                live.iter().map(|(_, j)| j.width().min(self.slots)).collect();
            let alloc = maxmin_waterfill(&demands, self.slots);
            let mut dt = f64::INFINITY;
            for ((_, j), &a) in live.iter().zip(&alloc) {
                if a <= 0.0 {
                    continue;
                }
                dt = dt.min(j.remaining() / a);
            }
            if !dt.is_finite() || dt <= 0.0 {
                for (id, _) in &live {
                    finished.push((*id, f64::INFINITY));
                }
                break;
            }
            t += dt;
            let mut next: Vec<(JobId, NaiveVJob)> = Vec::with_capacity(live.len());
            for ((id, mut j), &a) in live.into_iter().zip(&alloc) {
                j.aged = (j.aged + a * dt).min(j.total);
                if j.remaining() <= 1e-9 {
                    finished.push((id, t));
                } else {
                    next.push((id, j));
                }
            }
            live = next;
        }
        finished.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
        finished
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The naive reference reproduces the paper's Fig. 1 PS order —
    /// anchoring it to the same ground truth as the production impl.
    #[test]
    fn naive_reproduces_fig1() {
        let mut vc = NaiveVirtualCluster::new(1);
        vc.add_job(1, 30.0, 10, 0.0);
        vc.add_job(2, 10.0, 10, 10.0);
        vc.add_job(3, 10.0, 10, 15.0);
        let ids: Vec<JobId> = vc
            .projected_finish_order()
            .iter()
            .map(|&(id, _)| id)
            .collect();
        assert_eq!(ids, vec![2, 3, 1]);
        assert!((vc.remaining(1).unwrap() - 17.5).abs() < 1e-9);
    }
}
