//! Seeded scenario matrix for cross-discipline property tests.
//!
//! Every scheduling discipline must emit only **valid action sequences**
//! whatever the workload and fault environment: no launch on a full
//! slot, no suspend/kill of a non-running task, no resume off the node
//! holding the suspended context. The driver validates every action it
//! applies ([`crate::cluster::driver`]) and counts violations in
//! `counters.rejected_actions` (debug builds additionally
//! `debug_assert!`), so the harness reduces to: run the matrix, assert
//! zero rejections and full completion.
//!
//! Used by `tests/properties.rs` across every entry of
//! [`crate::scheduler::REGISTRY`].

use crate::cluster::driver::{SimConfig, SimOutcome};
use crate::cluster::ClusterConfig;
use crate::faults::{FaultConfig, SpeculationConfig};
use crate::sim::StopReason;
use crate::sweep::WorkloadSpec;
use crate::workload::swim::FbWorkload;
use crate::workload::Workload;

/// One fully specified simulation scenario (workload × faults × seed).
pub struct Scenario {
    /// Human-readable id, printed on failure.
    pub label: String,
    pub workload: Workload,
    pub cfg: SimConfig,
}

/// Fault environments of the matrix. Every scenario must be *completable*:
/// churn has no permanent losses (a permanently shrinking cluster can
/// legitimately strand work), and stragglers race speculative clones.
fn fault_axis() -> Vec<(&'static str, FaultConfig)> {
    vec![
        ("none", FaultConfig::disabled()),
        (
            "hot-churn",
            FaultConfig {
                enabled: true,
                mtbf_s: 600.0,
                repair_s: 60.0,
                permanent_fraction: 0.0,
                ..FaultConfig::disabled()
            },
        ),
        (
            "stragglers",
            FaultConfig {
                enabled: true,
                straggler_fraction: 0.3,
                speculation: SpeculationConfig {
                    enabled: true,
                    ..SpeculationConfig::default()
                },
                ..FaultConfig::disabled()
            },
        ),
        (
            "error",
            FaultConfig {
                enabled: true,
                size_error_sigma: 0.5,
                ..FaultConfig::disabled()
            },
        ),
    ]
}

/// Workload shapes of the matrix (kept tiny — the matrix is run for
/// every registered scheduler).
fn workload_axis() -> Vec<(&'static str, WorkloadSpec)> {
    vec![
        (
            "fb-small",
            WorkloadSpec::Fb(FbWorkload {
                n_small: 6,
                n_medium: 3,
                n_large: 0,
                ..Default::default()
            }),
        ),
        ("fig7", WorkloadSpec::Fig7),
        (
            "uniform",
            WorkloadSpec::UniformBatch {
                jobs: 5,
                maps_per_job: 4,
                task_s: 12.0,
            },
        ),
    ]
}

/// Expand the seeded scenario matrix: workload × fault environment ×
/// seed, on a small cluster.
pub fn matrix(seeds: &[u64]) -> Vec<Scenario> {
    let mut out = Vec::new();
    for (wname, wspec) in workload_axis() {
        for (fname, faults) in fault_axis() {
            for &seed in seeds {
                let workload = wspec.realize(seed);
                let cfg = SimConfig {
                    cluster: ClusterConfig {
                        nodes: 4,
                        ..Default::default()
                    },
                    seed,
                    faults: faults.clone(),
                    ..Default::default()
                };
                out.push(Scenario {
                    label: format!("{wname}/{fname}/seed{seed}"),
                    workload,
                    cfg,
                });
            }
        }
    }
    out
}

/// Assert the action-validity property on one scenario outcome:
/// no rejected actions, no truncation, every job finished.
pub fn assert_valid_outcome(outcome: &SimOutcome, expected_jobs: usize, label: &str) {
    assert_eq!(
        outcome.counters.rejected_actions, 0,
        "[{label}] {}: scheduler emitted invalid actions",
        outcome.scheduler
    );
    assert_ne!(
        outcome.stop,
        StopReason::EventLimit,
        "[{label}] {}: run truncated by the event guard",
        outcome.scheduler
    );
    assert_eq!(
        outcome.sojourn.len(),
        expected_jobs,
        "[{label}] {}: not every job finished",
        outcome.scheduler
    );
}
