//! Property-based testing mini-framework.
//!
//! The offline registry does not carry `proptest`, so this module
//! provides the slice of it the test suite needs: composable random
//! generators ([`Gen`]), a seeded runner that reports the failing seed,
//! and greedy input shrinking for a minimal counterexample.
//!
//! ```no_run
//! use hfsp::testkit::{self, Gen};
//! testkit::check("sum is commutative", 100, Gen::f64_range(-1e3, 1e3)
//!     .pair(Gen::f64_range(-1e3, 1e3)), |(a, b)| a + b == b + a);
//! ```

pub mod reference;
pub mod scenarios;

use crate::util::rng::{Pcg64, Rng, SeedableRng};

/// A random value generator with an attached shrinker.
pub struct Gen<T> {
    #[allow(clippy::type_complexity)]
    gen: Box<dyn Fn(&mut Pcg64) -> T>,
    #[allow(clippy::type_complexity)]
    shrink: Box<dyn Fn(&T) -> Vec<T>>,
}

impl<T: Clone + 'static> Gen<T> {
    pub fn new(
        gen: impl Fn(&mut Pcg64) -> T + 'static,
        shrink: impl Fn(&T) -> Vec<T> + 'static,
    ) -> Self {
        Self {
            gen: Box::new(gen),
            shrink: Box::new(shrink),
        }
    }

    pub fn sample(&self, rng: &mut Pcg64) -> T {
        (self.gen)(rng)
    }

    pub fn shrinks(&self, v: &T) -> Vec<T> {
        (self.shrink)(v)
    }

    /// Map the generated value (no shrinking through the map).
    pub fn map<U: Clone + 'static>(self, f: impl Fn(T) -> U + 'static) -> Gen<U> {
        Gen::new(move |rng| f(self.sample(rng)), |_| Vec::new())
    }

    /// Pair two generators; shrinks component-wise.
    pub fn pair<U: Clone + 'static>(self, other: Gen<U>) -> Gen<(T, U)> {
        let (g1, s1) = (self.gen, self.shrink);
        let (g2, s2) = (other.gen, other.shrink);
        Gen::new(
            move |rng| (g1(rng), g2(rng)),
            move |(a, b)| {
                let mut out: Vec<(T, U)> = Vec::new();
                for a2 in s1(a) {
                    out.push((a2, b.clone()));
                }
                for b2 in s2(b) {
                    out.push((a.clone(), b2));
                }
                out
            },
        )
    }
}

impl Gen<usize> {
    /// Uniform usize in `[lo, hi]`, shrinking toward `lo`.
    pub fn usize_range(lo: usize, hi: usize) -> Gen<usize> {
        assert!(lo <= hi);
        Gen::new(
            move |rng| lo + rng.gen_index(hi - lo + 1),
            move |&v| {
                let mut out = Vec::new();
                if v > lo {
                    out.push(lo);
                    out.push(lo + (v - lo) / 2);
                    out.push(v - 1);
                }
                out.sort_unstable();
                out.dedup();
                out.retain(|&x| x < v);
                out
            },
        )
    }
}

impl Gen<f64> {
    /// Uniform f64 in `[lo, hi)`, shrinking toward `lo` (then 0 if in
    /// range).
    pub fn f64_range(lo: f64, hi: f64) -> Gen<f64> {
        assert!(hi > lo);
        Gen::new(
            move |rng| rng.gen_range_f64(lo, hi),
            move |&v| {
                let mut out = Vec::new();
                if (lo..hi).contains(&0.0) && v != 0.0 {
                    out.push(0.0);
                }
                if v != lo {
                    out.push(lo);
                    out.push(lo + (v - lo) / 2.0);
                }
                out.retain(|x| (x - v).abs() > 1e-12);
                out
            },
        )
    }
}

/// Vector generator with length in `[0, max_len]`, shrinking by halving
/// length and shrinking elements.
pub fn vec_of<T: Clone + 'static>(elem: Gen<T>, max_len: usize) -> Gen<Vec<T>> {
    let elem = std::rc::Rc::new(elem);
    let elem2 = elem.clone();
    Gen::new(
        move |rng| {
            let len = rng.gen_index(max_len + 1);
            (0..len).map(|_| elem.sample(rng)).collect()
        },
        move |v: &Vec<T>| {
            let mut out: Vec<Vec<T>> = Vec::new();
            if !v.is_empty() {
                out.push(Vec::new());
                out.push(v[..v.len() / 2].to_vec());
                let mut minus_last = v.clone();
                minus_last.pop();
                out.push(minus_last);
                // Shrink one element at a time (first element only, to
                // bound the candidate set).
                for (i, x) in v.iter().enumerate().take(3) {
                    for x2 in elem2.shrinks(x) {
                        let mut v2 = v.clone();
                        v2[i] = x2;
                        out.push(v2);
                    }
                }
            }
            out.retain(|c| c.len() < v.len() || c.iter().zip(v).any(|(a, b)| !ptr_eq(a, b)));
            out
        },
    )
}

fn ptr_eq<T>(a: &T, b: &T) -> bool {
    std::ptr::eq(a, b)
}

/// Non-empty vector variant.
pub fn vec1_of<T: Clone + 'static>(elem: Gen<T>, max_len: usize) -> Gen<Vec<T>> {
    assert!(max_len >= 1);
    let inner = vec_of(elem, max_len - 1);
    let head = std::rc::Rc::new(inner);
    let head2 = head.clone();
    Gen::new(
        move |rng| {
            let mut v = head.sample(rng);
            if v.is_empty() {
                // Regenerate a singleton deterministically from the rng.
                v = loop {
                    let c = head.sample(rng);
                    if !c.is_empty() {
                        break c;
                    }
                    // Extremely unlikely to loop long; max_len >= 1 means
                    // p(empty) = 1/max_len.
                };
            }
            v
        },
        move |v| {
            head2
                .shrinks(v)
                .into_iter()
                .filter(|c| !c.is_empty())
                .collect()
        },
    )
}

/// Outcome of a property check.
#[derive(Debug)]
pub struct Failure<T> {
    pub seed: u64,
    pub case: u64,
    pub minimal: T,
    pub shrink_steps: usize,
}

/// Run `prop` on `cases` random inputs; on failure, shrink greedily and
/// panic with the minimal counterexample. The base seed comes from
/// `HFSP_PROPTEST_SEED` (default 0xC0FFEE) so failures are reproducible.
pub fn check<T: Clone + std::fmt::Debug + 'static>(
    name: &str,
    cases: u64,
    gen: Gen<T>,
    prop: impl Fn(T) -> bool,
) {
    if let Some(f) = check_quiet(cases, &gen, &prop) {
        panic!(
            "property {name:?} failed (seed={}, case={}, {} shrink steps)\n\
             minimal counterexample: {:#?}",
            f.seed, f.case, f.shrink_steps, f.minimal
        );
    }
}

/// Non-panicking runner (used by the framework's own tests).
pub fn check_quiet<T: Clone + 'static>(
    cases: u64,
    gen: &Gen<T>,
    prop: &impl Fn(T) -> bool,
) -> Option<Failure<T>> {
    let seed = std::env::var("HFSP_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE);
    let mut rng = Pcg64::seed_from_u64(seed);
    for case in 0..cases {
        let input = gen.sample(&mut rng);
        if prop(input.clone()) {
            continue;
        }
        // Shrink greedily: repeatedly take the first failing candidate.
        let mut minimal = input;
        let mut steps = 0;
        'outer: loop {
            for candidate in gen.shrinks(&minimal) {
                if !prop(candidate.clone()) {
                    minimal = candidate;
                    steps += 1;
                    if steps > 1000 {
                        break 'outer;
                    }
                    continue 'outer;
                }
            }
            break;
        }
        return Some(Failure {
            seed,
            case,
            minimal,
            shrink_steps: steps,
        });
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("abs is non-negative", 200, Gen::f64_range(-100.0, 100.0), |x| {
            x.abs() >= 0.0
        });
    }

    #[test]
    fn failing_property_shrinks() {
        // "all values < 50" fails; minimal counterexample should be close
        // to 50 after shrinking from the lo side.
        let gen = Gen::usize_range(0, 1000);
        let f = check_quiet(500, &gen, &|x| x < 50).expect("property must fail");
        assert!(f.minimal >= 50, "counterexample {}", f.minimal);
        // Greedy shrink drives it to a boundary-ish value.
        assert!(f.minimal <= 1000);
    }

    #[test]
    fn pair_generator_shrinks_componentwise() {
        let gen = Gen::usize_range(0, 100).pair(Gen::usize_range(0, 100));
        let f = check_quiet(500, &gen, &|(a, b)| a + b < 120).expect("must fail");
        assert!(f.minimal.0 + f.minimal.1 >= 120);
    }

    #[test]
    fn vec_generator_respects_bounds() {
        let gen = vec_of(Gen::f64_range(0.0, 1.0), 10);
        let mut rng = Pcg64::seed_from_u64(1);
        for _ in 0..100 {
            let v = gen.sample(&mut rng);
            assert!(v.len() <= 10);
            assert!(v.iter().all(|x| (0.0..1.0).contains(x)));
        }
    }

    #[test]
    fn vec1_never_empty() {
        let gen = vec1_of(Gen::usize_range(0, 5), 8);
        let mut rng = Pcg64::seed_from_u64(2);
        for _ in 0..200 {
            assert!(!gen.sample(&mut rng).is_empty());
        }
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn check_panics_with_context() {
        check("always false", 10, Gen::usize_range(0, 10), |_| false);
    }
}
