//! Wall-clock benchmark harness.
//!
//! `criterion` is not available offline, so `cargo bench` targets use this
//! harness: warmup, N timed samples, mean / p50 / p99 and a JSON record.
//! Figure-reproduction benches additionally print the paper-shaped series
//! through [`crate::report`].

use crate::util::json::Json;
use crate::util::stats::percentile;
use std::time::Instant;

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub samples_ns: Vec<f64>,
}

impl Measurement {
    pub fn mean_ns(&self) -> f64 {
        crate::util::stats::mean(&self.samples_ns)
    }

    pub fn p50_ns(&self) -> f64 {
        let mut s = self.samples_ns.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        percentile(&s, 50.0)
    }

    pub fn p99_ns(&self) -> f64 {
        let mut s = self.samples_ns.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        percentile(&s, 99.0)
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", self.name.as_str().into());
        o.set("samples", self.samples_ns.len().into());
        o.set("mean_ns", self.mean_ns().into());
        o.set("p50_ns", self.p50_ns().into());
        o.set("p99_ns", self.p99_ns().into());
        o
    }

    /// Human-readable single line.
    pub fn report_line(&self) -> String {
        format!(
            "{:<44} {:>12} {:>12} {:>12}  ({} samples)",
            self.name,
            fmt_ns(self.mean_ns()),
            fmt_ns(self.p50_ns()),
            fmt_ns(self.p99_ns()),
            self.samples_ns.len()
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner.
pub struct Bench {
    warmup: usize,
    samples: usize,
    results: Vec<Measurement>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        Self {
            warmup: 2,
            samples: 10,
            results: Vec::new(),
        }
    }

    pub fn with_samples(mut self, warmup: usize, samples: usize) -> Self {
        self.warmup = warmup;
        self.samples = samples.max(1);
        self
    }

    /// Time `f`, which should perform one full unit of work and return a
    /// value kept alive to prevent dead-code elimination.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Measurement {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        self.results.push(Measurement {
            name: name.to_string(),
            samples_ns: samples,
        });
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Print the classic header + one line per measurement.
    pub fn print_table(&self) {
        println!(
            "{:<44} {:>12} {:>12} {:>12}",
            "benchmark", "mean", "p50", "p99"
        );
        for m in &self.results {
            println!("{}", m.report_line());
        }
    }

    /// Dump all measurements as a JSON array (for regression tracking).
    pub fn to_json(&self) -> Json {
        Json::Arr(self.results.iter().map(Measurement::to_json).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_reports() {
        let mut b = Bench::new().with_samples(1, 5);
        b.run("noop", || 42);
        b.run("spin", || (0..1000).sum::<u64>());
        assert_eq!(b.results().len(), 2);
        let m = &b.results()[1];
        assert_eq!(m.samples_ns.len(), 5);
        assert!(m.mean_ns() >= 0.0);
        assert!(m.p99_ns() >= m.p50_ns() * 0.5);
        let j = b.to_json().to_string_compact();
        assert!(j.contains("\"spin\""));
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with(" s"));
    }
}
