//! Wall-clock benchmark harness + the simulator perf trajectory.
//!
//! `criterion` is not available offline, so `cargo bench` targets use this
//! harness: warmup, N timed samples, mean / p50 / p99 and a JSON record.
//! Figure-reproduction benches additionally print the paper-shaped series
//! through [`crate::report`].
//!
//! The second half of the module backs the `hfsp bench` subcommand:
//! [`ScenarioRecord`] is one row of the `BENCH_sim.json` trajectory file
//! (schema `hfsp-bench/v2`; every v1 field preserved, plus
//! `events_pushed` / `heap_peak` / `peak_rss_mb`), and
//! [`compare_trajectories`] computes the events/sec deltas behind
//! `hfsp bench --compare old.json` — the CI regression gate.

use crate::cluster::driver::SimOutcome;
use crate::util::json::Json;
use crate::util::stats::percentile;
use std::time::Instant;

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub samples_ns: Vec<f64>,
}

impl Measurement {
    pub fn mean_ns(&self) -> f64 {
        crate::util::stats::mean(&self.samples_ns)
    }

    pub fn p50_ns(&self) -> f64 {
        let mut s = self.samples_ns.clone();
        s.sort_by(|a, b| a.total_cmp(b));
        percentile(&s, 50.0)
    }

    pub fn p99_ns(&self) -> f64 {
        let mut s = self.samples_ns.clone();
        s.sort_by(|a, b| a.total_cmp(b));
        percentile(&s, 99.0)
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", self.name.as_str().into());
        o.set("samples", self.samples_ns.len().into());
        o.set("mean_ns", self.mean_ns().into());
        o.set("p50_ns", self.p50_ns().into());
        o.set("p99_ns", self.p99_ns().into());
        o
    }

    /// Human-readable single line.
    pub fn report_line(&self) -> String {
        format!(
            "{:<44} {:>12} {:>12} {:>12}  ({} samples)",
            self.name,
            fmt_ns(self.mean_ns()),
            fmt_ns(self.p50_ns()),
            fmt_ns(self.p99_ns()),
            self.samples_ns.len()
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner.
pub struct Bench {
    warmup: usize,
    samples: usize,
    results: Vec<Measurement>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        Self {
            warmup: 2,
            samples: 10,
            results: Vec::new(),
        }
    }

    pub fn with_samples(mut self, warmup: usize, samples: usize) -> Self {
        self.warmup = warmup;
        self.samples = samples.max(1);
        self
    }

    /// Time `f`, which should perform one full unit of work and return a
    /// value kept alive to prevent dead-code elimination.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &Measurement {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        self.results.push(Measurement {
            name: name.to_string(),
            samples_ns: samples,
        });
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Print the classic header + one line per measurement.
    pub fn print_table(&self) {
        println!(
            "{:<44} {:>12} {:>12} {:>12}",
            "benchmark", "mean", "p50", "p99"
        );
        for m in &self.results {
            println!("{}", m.report_line());
        }
    }

    /// Dump all measurements as a JSON array (for regression tracking).
    pub fn to_json(&self) -> Json {
        Json::Arr(self.results.iter().map(Measurement::to_json).collect())
    }
}

// -- the simulator perf trajectory (`hfsp bench` / BENCH_sim.json) ------

/// One scenario row of the perf-trajectory file. The v1 fields
/// (`scenario`, `scheduler`, `events`, `wall_ms`, `events_per_sec`,
/// `makespan_s`) are always written; the v2 fields are optional so v1
/// baselines still parse for `--compare`.
#[derive(Clone, Debug)]
pub struct ScenarioRecord {
    pub scenario: String,
    pub scheduler: String,
    pub events: u64,
    pub wall_ms: f64,
    pub events_per_sec: f64,
    pub makespan_s: f64,
    /// Total events scheduled (v2).
    pub events_pushed: Option<u64>,
    /// Pending-event heap high-water mark (v2).
    pub heap_peak: Option<u64>,
    /// Process peak RSS after the scenario, MiB — cumulative across
    /// scenarios within one bench run (v2; Linux only).
    pub peak_rss_mb: Option<f64>,
    /// Event-queue backend the row was measured under (v2; `"calendar"`
    /// or `"heap"`). Absent rows (v1 baselines, pre-backend v2 files)
    /// join any backend in `--compare` — see [`compare_trajectories`].
    pub queue: Option<String>,
}

impl ScenarioRecord {
    /// Snapshot a simulation outcome as a trajectory row, stamping the
    /// current process peak RSS.
    pub fn from_outcome(scenario: impl Into<String>, o: &SimOutcome) -> Self {
        Self {
            scenario: scenario.into(),
            scheduler: o.scheduler.to_string(),
            events: o.events_processed,
            wall_ms: o.wall_ms,
            events_per_sec: o.events_per_sec(),
            makespan_s: o.makespan,
            events_pushed: Some(o.events_pushed),
            heap_peak: Some(o.heap_peak as u64),
            peak_rss_mb: crate::util::rss::peak_rss_mb(),
            queue: None,
        }
    }

    /// Stamp the row with the queue backend it was measured under.
    pub fn with_queue(mut self, queue: &str) -> Self {
        self.queue = Some(queue.to_string());
        self
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("scenario", self.scenario.as_str().into());
        o.set("scheduler", self.scheduler.as_str().into());
        o.set("events", self.events.into());
        o.set("wall_ms", self.wall_ms.into());
        o.set("events_per_sec", self.events_per_sec.into());
        o.set("makespan_s", self.makespan_s.into());
        if let Some(p) = self.events_pushed {
            o.set("events_pushed", p.into());
        }
        if let Some(h) = self.heap_peak {
            o.set("heap_peak", h.into());
        }
        if let Some(r) = self.peak_rss_mb {
            o.set("peak_rss_mb", r.into());
        }
        if let Some(q) = &self.queue {
            o.set("queue", q.as_str().into());
        }
        o
    }

    pub fn from_json(j: &Json) -> Option<Self> {
        Some(Self {
            scenario: j.get("scenario")?.as_str()?.to_string(),
            scheduler: j.get("scheduler")?.as_str()?.to_string(),
            events: j.get("events")?.as_u64()?,
            wall_ms: j.get("wall_ms")?.as_f64()?,
            events_per_sec: j.get("events_per_sec")?.as_f64()?,
            makespan_s: j.get("makespan_s").and_then(Json::as_f64).unwrap_or(0.0),
            events_pushed: j.get("events_pushed").and_then(Json::as_u64),
            heap_peak: j.get("heap_peak").and_then(Json::as_u64),
            peak_rss_mb: j.get("peak_rss_mb").and_then(Json::as_f64),
            queue: j
                .get("queue")
                .and_then(Json::as_str)
                .map(|s| s.to_string()),
        })
    }
}

/// Serialize a trajectory (schema `hfsp-bench/v2`).
pub fn trajectory_to_json(records: &[ScenarioRecord]) -> Json {
    let mut j = Json::obj();
    j.set("schema", "hfsp-bench/v2".into());
    j.set(
        "runs",
        Json::Arr(records.iter().map(ScenarioRecord::to_json).collect()),
    );
    j
}

/// Parse a trajectory file — accepts both the v1 and v2 schemas (rows
/// missing the v2 fields parse with `None`s). Unparseable rows are
/// skipped: a baseline that predates a scenario must not block the gate.
pub fn parse_trajectory(j: &Json) -> Vec<ScenarioRecord> {
    j.get("runs")
        .and_then(Json::as_arr)
        .map(|rows| rows.iter().filter_map(ScenarioRecord::from_json).collect())
        .unwrap_or_default()
}

/// Parse a trajectory file from raw text: the parsed document (for the
/// config-stamp checks) plus its rows. Errors on malformed JSON — a
/// corrupt baseline must fail the gate loudly, not read as "no rows".
pub fn parse_trajectory_text(text: &str) -> Result<(Json, Vec<ScenarioRecord>), String> {
    let j = crate::util::json::parse(text).map_err(|e| format!("malformed trajectory: {e}"))?;
    let rows = parse_trajectory(&j);
    Ok((j, rows))
}

/// Check the baseline's top-level config stamps against the current
/// run's. Returns the first mismatch as `Some("key: baseline vs
/// current")`; keys the baseline never stamped are skipped (older
/// baselines must not block the gate on fields they predate).
pub fn baseline_config_mismatch(baseline: &Json, current: &[(&str, Json)]) -> Option<String> {
    for (key, want) in current {
        match baseline.get(key) {
            Some(have) if have != want => {
                return Some(format!(
                    "baseline {key}={} vs current {key}={}",
                    have.to_string_compact(),
                    want.to_string_compact()
                ));
            }
            _ => {}
        }
    }
    None
}

/// One `--compare` delta row: events/sec then vs now for a scenario
/// present in both trajectories.
#[derive(Clone, Debug)]
pub struct CompareRow {
    pub scenario: String,
    pub scheduler: String,
    pub old_events_per_sec: f64,
    pub new_events_per_sec: f64,
}

impl CompareRow {
    /// Fractional throughput change: +0.5 = 50 % faster, −0.3 = 30 %
    /// slower.
    pub fn delta(&self) -> f64 {
        if self.old_events_per_sec <= 0.0 {
            return 0.0;
        }
        self.new_events_per_sec / self.old_events_per_sec - 1.0
    }

    /// Fractional regression (positive = slower), for the gate.
    pub fn regression(&self) -> f64 {
        (-self.delta()).max(0.0)
    }
}

/// Join two trajectories on (scenario, scheduler), in `new` order. The
/// queue-backend stamp must match too when both sides carry one; a row
/// without the stamp (v1 baselines) joins any backend, so pre-backend
/// baselines keep gating.
pub fn compare_trajectories(old: &[ScenarioRecord], new: &[ScenarioRecord]) -> Vec<CompareRow> {
    new.iter()
        .filter_map(|n| {
            let o = old.iter().find(|o| {
                o.scenario == n.scenario
                    && o.scheduler == n.scheduler
                    && (o.queue.is_none() || n.queue.is_none() || o.queue == n.queue)
            })?;
            Some(CompareRow {
                scenario: n.scenario.clone(),
                scheduler: n.scheduler.clone(),
                old_events_per_sec: o.events_per_sec,
                new_events_per_sec: n.events_per_sec,
            })
        })
        .collect()
}

/// Largest fractional regression across the joined rows (0.0 when no
/// row regressed or nothing joined).
pub fn worst_regression(rows: &[CompareRow]) -> f64 {
    rows.iter().map(CompareRow::regression).fold(0.0, f64::max)
}

/// Fold CI-measured `artifact` rows into a `committed` trajectory
/// (`hfsp bench --merge-baseline`): rows join on (scenario, scheduler,
/// queue) with the queue stamp matched exactly — a provisional row is
/// replaced only by a measurement from the same backend. Matched
/// committed rows are replaced in place (file order preserved),
/// unmatched artifact rows are appended, and committed rows the
/// artifact never measured are kept. Returns `(replaced, appended)`.
pub fn merge_baselines(
    committed: &mut Vec<ScenarioRecord>,
    artifact: &[ScenarioRecord],
) -> (usize, usize) {
    let (mut replaced, mut appended) = (0, 0);
    for row in artifact {
        match committed.iter_mut().find(|c| {
            c.scenario == row.scenario && c.scheduler == row.scheduler && c.queue == row.queue
        }) {
            Some(slot) => {
                *slot = row.clone();
                replaced += 1;
            }
            None => {
                committed.push(row.clone());
                appended += 1;
            }
        }
    }
    (replaced, appended)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_reports() {
        let mut b = Bench::new().with_samples(1, 5);
        b.run("noop", || 42);
        b.run("spin", || (0..1000).sum::<u64>());
        assert_eq!(b.results().len(), 2);
        let m = &b.results()[1];
        assert_eq!(m.samples_ns.len(), 5);
        assert!(m.mean_ns() >= 0.0);
        assert!(m.p99_ns() >= m.p50_ns() * 0.5);
        let j = b.to_json().to_string_compact();
        assert!(j.contains("\"spin\""));
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5_000.0).ends_with("µs"));
        assert!(fmt_ns(5_000_000.0).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with(" s"));
    }

    fn record(scenario: &str, eps: f64) -> ScenarioRecord {
        ScenarioRecord {
            scenario: scenario.to_string(),
            scheduler: "HFSP".to_string(),
            events: 1000,
            wall_ms: 10.0,
            events_per_sec: eps,
            makespan_s: 5.0,
            events_pushed: Some(1200),
            heap_peak: Some(64),
            peak_rss_mb: Some(12.5),
            queue: None,
        }
    }

    #[test]
    fn trajectory_round_trips_through_json_with_v2_fields() {
        let records = vec![record("open-1e5", 50_000.0)];
        let j = trajectory_to_json(&records);
        assert_eq!(j.get("schema").and_then(Json::as_str), Some("hfsp-bench/v2"));
        let parsed = parse_trajectory(&j);
        assert_eq!(parsed.len(), 1);
        let r = &parsed[0];
        assert_eq!(r.scenario, "open-1e5");
        assert_eq!(r.events, 1000);
        assert_eq!(r.events_pushed, Some(1200));
        assert_eq!(r.heap_peak, Some(64));
        assert_eq!(r.peak_rss_mb, Some(12.5));
    }

    #[test]
    fn v1_rows_without_new_fields_still_parse() {
        let text = r#"{
            "schema": "hfsp-bench/v1",
            "runs": [{
                "scenario": "fb-0.3x20", "scheduler": "FIFO",
                "events": 42, "wall_ms": 1.0,
                "events_per_sec": 42000.0, "makespan_s": 9.0
            }]
        }"#;
        let j = crate::util::json::parse(text).unwrap();
        let parsed = parse_trajectory(&j);
        assert_eq!(parsed.len(), 1);
        assert_eq!(parsed[0].events_pushed, None);
        assert_eq!(parsed[0].heap_peak, None);
    }

    #[test]
    fn empty_baseline_joins_nothing_and_gates_nothing() {
        let j = crate::util::json::parse(r#"{"schema":"hfsp-bench/v2","runs":[]}"#).unwrap();
        let old = parse_trajectory(&j);
        let new = vec![record("open-1e5", 50_000.0)];
        let rows = compare_trajectories(&old, &new);
        assert!(rows.is_empty());
        assert_eq!(worst_regression(&rows), 0.0);
    }

    #[test]
    fn compare_flags_the_regressed_scenario() {
        let old = vec![record("a", 100_000.0), record("b", 100_000.0)];
        let new = vec![
            record("a", 250_000.0), // 2.5x faster
            record("b", 60_000.0),  // 40 % slower
            record("c", 10_000.0),  // new scenario: not gated
        ];
        let rows = compare_trajectories(&old, &new);
        assert_eq!(rows.len(), 2);
        assert!((rows[0].delta() - 1.5).abs() < 1e-12);
        assert!((rows[1].regression() - 0.4).abs() < 1e-12);
        assert!((worst_regression(&rows) - 0.4).abs() < 1e-12);
    }

    #[test]
    fn queue_stamp_round_trips_and_gates_the_join() {
        let stamped = record("a", 100_000.0).with_queue("calendar");
        let j = trajectory_to_json(&[stamped.clone()]);
        let parsed = parse_trajectory(&j);
        assert_eq!(parsed[0].queue.as_deref(), Some("calendar"));

        // Same backend on both sides: joins.
        let rows = compare_trajectories(&parsed, &[stamped.clone()]);
        assert_eq!(rows.len(), 1);
        // Different backend: filtered out.
        let heap = record("a", 100_000.0).with_queue("heap");
        assert!(compare_trajectories(&parsed, &[heap]).is_empty());
        // Unstamped baseline (v1): wildcard, still joins.
        let rows = compare_trajectories(&[record("a", 100_000.0)], &[stamped]);
        assert_eq!(rows.len(), 1);
    }

    #[test]
    fn merge_baselines_replaces_appends_and_preserves() {
        let mut committed = vec![
            record("a", 1_000.0).with_queue("calendar"),
            record("b", 1_000.0).with_queue("calendar"),
        ];
        let artifact = vec![
            record("a", 90_000.0).with_queue("calendar"), // replaces
            record("a", 80_000.0).with_queue("heap"),     // other backend: appends
            record("c", 70_000.0).with_queue("calendar"), // new scenario: appends
        ];
        let (replaced, appended) = merge_baselines(&mut committed, &artifact);
        assert_eq!((replaced, appended), (1, 2));
        assert_eq!(committed.len(), 4);
        // In-place replacement keeps file order; untouched rows survive.
        assert_eq!(committed[0].scenario, "a");
        assert_eq!(committed[0].events_per_sec, 90_000.0);
        assert_eq!(committed[1].scenario, "b");
        assert_eq!(committed[1].events_per_sec, 1_000.0);
        assert_eq!(committed[2].queue.as_deref(), Some("heap"));
        assert_eq!(committed[3].scenario, "c");
    }

    #[test]
    fn parse_trajectory_text_rejects_malformed_json() {
        assert!(parse_trajectory_text("{not json").is_err());
        let (j, rows) =
            parse_trajectory_text(r#"{"schema":"hfsp-bench/v2","runs":[]}"#).unwrap();
        assert_eq!(j.get("schema").and_then(Json::as_str), Some("hfsp-bench/v2"));
        assert!(rows.is_empty());
    }

    #[test]
    fn baseline_config_mismatch_skips_absent_keys_and_flags_diffs() {
        let j = crate::util::json::parse(r#"{"nodes": 8, "profile": "quick"}"#).unwrap();
        assert_eq!(
            baseline_config_mismatch(&j, &[("nodes", Json::from(8u64))]),
            None
        );
        assert_eq!(baseline_config_mismatch(&j, &[("scale", Json::from(0.1))]), None);
        let m = baseline_config_mismatch(&j, &[("nodes", Json::from(20u64))]);
        assert!(m.is_some(), "differing stamp must be flagged");
        assert!(m.unwrap().contains("nodes"));
    }
}
