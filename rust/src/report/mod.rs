//! Figure/table regeneration: CSV series + ASCII charts.
//!
//! Every bench target reproduces one paper figure or table; this module
//! renders the measured series in two forms — machine-readable CSV (saved
//! under `reports/`) and a terminal ASCII chart whose *shape* can be
//! compared against the paper at a glance.

use std::io::Write;
use std::path::Path;

/// A labelled (x, y) series.
#[derive(Clone, Debug)]
pub struct Series {
    pub label: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(label: impl Into<String>, points: Vec<(f64, f64)>) -> Self {
        Self {
            label: label.into(),
            points,
        }
    }
}

/// Render series as CSV: `x,label1,label2,...` — series are resampled on
/// the union of x values (missing points are left empty).
pub fn to_csv(series: &[Series]) -> String {
    let mut xs: Vec<f64> = series
        .iter()
        .flat_map(|s| s.points.iter().map(|&(x, _)| x))
        .collect();
    xs.sort_by(|a, b| a.total_cmp(b));
    xs.dedup_by(|a, b| (*a - *b).abs() < 1e-12);
    let mut out = String::from("x");
    for s in series {
        out.push(',');
        out.push_str(&s.label.replace(',', ";"));
    }
    out.push('\n');
    for &x in &xs {
        out.push_str(&format!("{x}"));
        for s in series {
            out.push(',');
            if let Some(&(_, y)) = s
                .points
                .iter()
                .find(|&&(px, _)| (px - x).abs() < 1e-12)
            {
                out.push_str(&format!("{y}"));
            }
        }
        out.push('\n');
    }
    out
}

/// Persist CSV under the given path, creating parent directories.
pub fn write_csv(path: &Path, series: &[Series]) -> anyhow::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(to_csv(series).as_bytes())?;
    Ok(())
}

/// ASCII line chart. One glyph per series ('A', 'B', ...). Optional log-x
/// (sojourn ECDFs span decades). Returns the rendered string.
pub fn ascii_chart(
    title: &str,
    series: &[Series],
    width: usize,
    height: usize,
    log_x: bool,
) -> String {
    assert!(width >= 16 && height >= 4);
    let tx = |x: f64| if log_x { x.max(1e-9).log10() } else { x };
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for s in series {
        for &(x, y) in &s.points {
            let x = tx(x);
            x0 = x0.min(x);
            x1 = x1.max(x);
            y0 = y0.min(y);
            y1 = y1.max(y);
        }
    }
    if !x0.is_finite() || x1 <= x0 {
        x0 = 0.0;
        x1 = 1.0;
    }
    if !y0.is_finite() || y1 <= y0 {
        y0 = 0.0;
        y1 = 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let glyph = (b'A' + (si % 26) as u8) as char;
        for &(x, y) in &s.points {
            let cx = ((tx(x) - x0) / (x1 - x0) * (width - 1) as f64).round() as usize;
            let cy = ((y - y0) / (y1 - y0) * (height - 1) as f64).round() as usize;
            let row = height - 1 - cy.min(height - 1);
            let col = cx.min(width - 1);
            grid[row][col] = glyph;
        }
    }
    let mut out = format!("== {title} ==\n");
    for (si, s) in series.iter().enumerate() {
        let glyph = (b'A' + (si % 26) as u8) as char;
        out.push_str(&format!("  [{glyph}] {}\n", s.label));
    }
    out.push_str(&format!("  y: [{y0:.3}, {y1:.3}]\n"));
    for row in grid {
        out.push_str("  |");
        out.extend(row);
        out.push('\n');
    }
    let xlabel = if log_x {
        format!("  x (log10): [{x0:.2}, {x1:.2}]")
    } else {
        format!("  x: [{x0:.2}, {x1:.2}]")
    };
    out.push_str(&xlabel);
    out.push('\n');
    out
}

/// Simple aligned table (paper-style "who wins by how much").
pub fn table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncols = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncols) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: Vec<String>, widths: &[usize]| -> String {
        let mut line = String::from("| ");
        for (i, c) in cells.iter().enumerate() {
            line.push_str(&format!("{:<w$} | ", c, w = widths[i]));
        }
        line.trim_end().to_string() + "\n"
    };
    out.push_str(&fmt_row(
        headers.iter().map(|s| s.to_string()).collect(),
        &widths,
    ));
    out.push_str(&fmt_row(
        widths.iter().map(|w| "-".repeat(*w)).collect(),
        &widths,
    ));
    for row in rows {
        out.push_str(&fmt_row(row.clone(), &widths));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_merges_x_values() {
        let s = vec![
            Series::new("a", vec![(1.0, 10.0), (2.0, 20.0)]),
            Series::new("b", vec![(2.0, 5.0), (3.0, 6.0)]),
        ];
        let csv = to_csv(&s);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "x,a,b");
        assert_eq!(lines.len(), 4);
        assert!(lines[2].starts_with("2,20,5"));
        assert!(lines[1].ends_with(',')); // b missing at x=1
    }

    #[test]
    fn ascii_chart_renders_all_series() {
        let s = vec![
            Series::new("hfsp", (0..20).map(|i| (i as f64, i as f64)).collect()),
            Series::new("fair", (0..20).map(|i| (i as f64, 2.0 * i as f64)).collect()),
        ];
        let chart = ascii_chart("test", &s, 40, 10, false);
        assert!(chart.contains("[A] hfsp"));
        assert!(chart.contains("[B] fair"));
        assert!(chart.contains('A'));
        assert!(chart.contains('B'));
    }

    #[test]
    fn ascii_chart_log_x() {
        let s = vec![Series::new(
            "e",
            vec![(1.0, 0.0), (10.0, 0.5), (100.0, 1.0)],
        )];
        let chart = ascii_chart("ecdf", &s, 30, 6, true);
        assert!(chart.contains("log10"));
    }

    #[test]
    fn table_alignment() {
        let t = table(
            &["scheduler", "mean sojourn"],
            &[
                vec!["HFSP".into(), "551".into()],
                vec!["FIFO".into(), "2983".into()],
            ],
        );
        assert!(t.contains("| HFSP"));
        assert!(t.contains("| 2983"));
        assert_eq!(t.lines().count(), 4);
    }

    #[test]
    fn csv_writes_to_disk() {
        let dir = std::env::temp_dir().join("hfsp-report-test");
        let path = dir.join("series.csv");
        write_csv(&path, &[Series::new("a", vec![(0.0, 1.0)])]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("x,a"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
