//! Source-file scanner underlying the `simlint` rules.
//!
//! The offline build vendors no parser crates (`syn` is unavailable), so
//! the rules work over a *masked* view of each file: every byte inside a
//! comment, string literal, char literal or raw string is replaced with a
//! space (newlines are preserved so byte offsets and line numbers stay
//! aligned with the raw text). Token searches over the masked text
//! therefore never match prose, doc examples or log strings.
//!
//! On top of the mask the scanner derives two per-line annotations the
//! runner uses to filter rule output:
//!
//! * **test regions** — the span of any item annotated `#[cfg(test)]`
//!   (brace-matched over the masked text, so braces inside strings or
//!   comments cannot derail it). The determinism contract governs
//!   shipped simulation code; tests may seed ad-hoc RNGs or compare
//!   floats directly.
//! * **waivers** — magic comments of the form
//!   `// simlint: allow(rule-id) -- reason`, the source-level analogue
//!   of `#[allow(simlint::rule_id)]`. A waiver applies to its own line
//!   and to the next line, so it can ride inline or sit on the line
//!   above the flagged expression.

/// A scanned source file: raw text, masked text and per-line metadata.
#[derive(Debug)]
pub struct SourceFile {
    /// Path relative to the scanned source root, with `/` separators.
    pub rel: String,
    /// The file exactly as read.
    pub raw: String,
    /// Same length as `raw`, with comment/string/char-literal bytes
    /// blanked to spaces (newlines kept).
    pub masked: String,
    /// Byte offset of the start of each line (line 1 first).
    line_starts: Vec<usize>,
    /// Per line (0-based): inside a `#[cfg(test)]` item span.
    test_line: Vec<bool>,
    /// Per line (0-based): rule ids waived on this line.
    waived: Vec<Vec<String>>,
}

impl SourceFile {
    pub fn parse(rel: &str, raw: &str) -> SourceFile {
        let masked = mask_source(raw);
        let line_starts = line_starts(raw);
        let n_lines = line_starts.len();
        let test_line = test_lines(&masked, &line_starts);
        let mut waived = vec![Vec::new(); n_lines];
        for (i, line) in raw.lines().enumerate() {
            for rule in parse_waivers(line) {
                waived[i].push(rule.clone());
                if i + 1 < n_lines {
                    waived[i + 1].push(rule);
                }
            }
        }
        SourceFile {
            rel: rel.to_string(),
            raw: raw.to_string(),
            masked,
            line_starts,
            test_line,
            waived,
        }
    }

    /// 1-based line number containing byte `offset`.
    pub fn line_of(&self, offset: usize) -> usize {
        match self.line_starts.binary_search(&offset) {
            Ok(i) => i + 1,
            Err(i) => i, // insertion point; line i-1 (0-based) => 1-based i
        }
    }

    /// Whether 1-based `line` lies inside a `#[cfg(test)]` item.
    pub fn is_test_line(&self, line: usize) -> bool {
        self.test_line.get(line.wrapping_sub(1)).copied().unwrap_or(false)
    }

    /// Whether `rule` is waived on 1-based `line` by a magic comment.
    pub fn is_waived(&self, line: usize, rule: &str) -> bool {
        self.waived
            .get(line.wrapping_sub(1))
            .map(|ids| ids.iter().any(|id| id == rule))
            .unwrap_or(false)
    }

    /// The raw text of 1-based `line` (empty when out of range).
    pub fn raw_line(&self, line: usize) -> &str {
        self.raw.lines().nth(line.wrapping_sub(1)).unwrap_or("")
    }
}

fn line_starts(text: &str) -> Vec<usize> {
    let mut starts = vec![0];
    for (i, b) in text.bytes().enumerate() {
        if b == b'\n' && i + 1 < text.len() {
            starts.push(i + 1);
        }
    }
    starts
}

/// Parse `simlint: allow(a, b)` out of one raw line.
fn parse_waivers(line: &str) -> Vec<String> {
    let Some(at) = line.find("simlint: allow(") else {
        return Vec::new();
    };
    let rest = &line[at + "simlint: allow(".len()..];
    let Some(close) = rest.find(')') else {
        return Vec::new();
    };
    rest[..close]
        .split(',')
        .map(|id| id.trim().to_string())
        .filter(|id| !id.is_empty())
        .collect()
}

/// Blank out comments, strings and char literals, preserving length and
/// newlines. Handles `//`, nested `/* */`, `"…"` with escapes, raw
/// strings `r"…"` / `r#"…"#` (and `br` variants), byte strings `b"…"`,
/// char literals `'x'` / `'\n'`, and leaves lifetimes (`'a`) intact.
pub fn mask_source(raw: &str) -> String {
    let bytes = raw.as_bytes();
    let mut out = bytes.to_vec();
    let n = bytes.len();
    let mut i = 0;
    let blank = |out: &mut [u8], from: usize, to: usize| {
        for b in &mut out[from..to.min(out.len())] {
            if *b != b'\n' {
                *b = b' ';
            }
        }
    };
    while i < n {
        let b = bytes[i];
        // Line comment.
        if b == b'/' && i + 1 < n && bytes[i + 1] == b'/' {
            let end = bytes[i..]
                .iter()
                .position(|&c| c == b'\n')
                .map(|p| i + p)
                .unwrap_or(n);
            blank(&mut out, i, end);
            i = end;
            continue;
        }
        // Block comment (nested, as in Rust).
        if b == b'/' && i + 1 < n && bytes[i + 1] == b'*' {
            let start = i;
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if bytes[i] == b'/' && i + 1 < n && bytes[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if bytes[i] == b'*' && i + 1 < n && bytes[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            blank(&mut out, start, i);
            continue;
        }
        let prev_ident = i > 0 && is_ident_byte(bytes[i - 1]);
        // Raw strings: r"…", r#"…"#, br"…", br#"…"#.
        if !prev_ident && (b == b'r' || (b == b'b' && i + 1 < n && bytes[i + 1] == b'r')) {
            let hash_start = if b == b'r' { i + 1 } else { i + 2 };
            let mut j = hash_start;
            while j < n && bytes[j] == b'#' {
                j += 1;
            }
            if j < n && bytes[j] == b'"' {
                let hashes = j - hash_start;
                let mut k = j + 1;
                let end = loop {
                    if k >= n {
                        break n;
                    }
                    if bytes[k] == b'"' && k + hashes < n + 1 {
                        let tail = &bytes[k + 1..(k + 1 + hashes).min(n)];
                        if tail.len() == hashes && tail.iter().all(|&c| c == b'#') {
                            break k + 1 + hashes;
                        }
                    }
                    k += 1;
                };
                blank(&mut out, i, end);
                i = end;
                continue;
            }
        }
        // Byte string b"…" or plain string "…".
        if b == b'"' || (!prev_ident && b == b'b' && i + 1 < n && bytes[i + 1] == b'"') {
            let start = i;
            let mut j = if b == b'"' { i + 1 } else { i + 2 };
            while j < n {
                match bytes[j] {
                    b'\\' => j += 2,
                    b'"' => {
                        j += 1;
                        break;
                    }
                    _ => j += 1,
                }
            }
            blank(&mut out, start, j.min(n));
            i = j.min(n);
            continue;
        }
        // Char literal vs lifetime.
        if b == b'\'' && i + 1 < n {
            if bytes[i + 1] == b'\\' {
                // '\n', '\'', '\u{…}' — scan to the closing quote.
                let mut j = i + 2;
                while j < n {
                    match bytes[j] {
                        b'\\' => j += 2,
                        b'\'' => {
                            j += 1;
                            break;
                        }
                        _ => j += 1,
                    }
                }
                blank(&mut out, i, j.min(n));
                i = j.min(n);
                continue;
            }
            // 'x' (one char, possibly multi-byte) then a closing quote.
            let ch_len = utf8_len(bytes[i + 1]);
            let close = i + 1 + ch_len;
            if close < n && bytes[close] == b'\'' {
                blank(&mut out, i, close + 1);
                i = close + 1;
                continue;
            }
            // Lifetime ('a) — leave untouched.
        }
        i += 1;
    }
    // Only masked bytes were rewritten (to ASCII spaces); every retained
    // byte is unchanged, so the result is still valid UTF-8.
    String::from_utf8(out).expect("masking preserves UTF-8")
}

fn utf8_len(first: u8) -> usize {
    if first < 0x80 {
        1
    } else if first >> 5 == 0b110 {
        2
    } else if first >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

pub fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Byte offsets of word-boundary occurrences of `token` in `haystack`
/// (intended for masked text).
pub fn find_token(haystack: &str, token: &str) -> Vec<usize> {
    let hay = haystack.as_bytes();
    let mut hits = Vec::new();
    let mut from = 0;
    while let Some(pos) = haystack[from..].find(token) {
        let at = from + pos;
        let before_ok = at == 0 || !is_ident_byte(hay[at - 1]);
        let end = at + token.len();
        let after_ok = end >= hay.len() || !is_ident_byte(hay[end]);
        if before_ok && after_ok {
            hits.push(at);
        }
        from = at + token.len().max(1);
    }
    hits
}

/// Byte offsets of plain substring occurrences (no boundary check).
pub fn find_substr(haystack: &str, needle: &str) -> Vec<usize> {
    let mut hits = Vec::new();
    let mut from = 0;
    while let Some(pos) = haystack[from..].find(needle) {
        hits.push(from + pos);
        from = from + pos + needle.len().max(1);
    }
    hits
}

/// Mark every line covered by a `#[cfg(test)]` item span.
fn test_lines(masked: &str, line_starts: &[usize]) -> Vec<bool> {
    let mut flags = vec![false; line_starts.len()];
    let bytes = masked.as_bytes();
    for start in find_substr(masked, "#[cfg(test)]") {
        let mut i = start + "#[cfg(test)]".len();
        // Skip whitespace and any further attributes (`#[…]`, bracket
        // matched) between the cfg attribute and the item itself.
        loop {
            while i < bytes.len() && bytes[i].is_ascii_whitespace() {
                i += 1;
            }
            if i + 1 < bytes.len() && bytes[i] == b'#' && bytes[i + 1] == b'[' {
                let mut depth = 0usize;
                while i < bytes.len() {
                    match bytes[i] {
                        b'[' => depth += 1,
                        b']' => {
                            depth -= 1;
                            if depth == 0 {
                                i += 1;
                                break;
                            }
                        }
                        _ => {}
                    }
                    i += 1;
                }
            } else {
                break;
            }
        }
        // The item extends to its matching closing brace, or to the
        // first `;` for brace-less items (`#[cfg(test)] use …;`).
        let mut end = i;
        let mut depth = 0usize;
        while end < bytes.len() {
            match bytes[end] {
                b'{' => depth += 1,
                // An unmatched `}` at depth 0 means the attribute sits on
                // a brace-less construct inside an enclosing block (e.g. a
                // match arm): clamp the span there instead of underflowing.
                b'}' if depth <= 1 => {
                    end += 1;
                    break;
                }
                b'}' => depth -= 1,
                b';' if depth == 0 => {
                    end += 1;
                    break;
                }
                _ => {}
            }
            end += 1;
        }
        let first = offset_line_idx(line_starts, start);
        let last = offset_line_idx(line_starts, end.saturating_sub(1).max(start));
        for flag in flags.iter_mut().take(last + 1).skip(first) {
            *flag = true;
        }
    }
    flags
}

/// 0-based line index containing byte `offset`.
fn offset_line_idx(line_starts: &[usize], offset: usize) -> usize {
    match line_starts.binary_search(&offset) {
        Ok(i) => i,
        Err(i) => i.saturating_sub(1),
    }
}
