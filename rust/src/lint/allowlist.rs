//! The committed `simlint.allow` allowlist.
//!
//! One entry per line: `rule-id path -- justification`. The path is
//! relative to the scanned source root (e.g. `util/fxmap.rs`); the
//! justification is mandatory — an allowlist entry is a standing waiver
//! and must say why the site is legitimate. `#` starts a comment.
//!
//! ```text
//! # wall-clock timing that only feeds the wall_ms report field
//! wall-clock cluster/driver.rs -- Instant::now only measures wall_ms
//! ```

use std::path::Path;

/// One `rule-id path -- reason` entry.
#[derive(Clone, Debug)]
pub struct Entry {
    pub rule: String,
    /// Path relative to the scanned source root, `/` separators.
    pub path: String,
    pub reason: String,
    /// 1-based line in the allowlist file (for diagnostics).
    pub line: usize,
}

/// A parsed allowlist; `permits` is the runner-facing query.
#[derive(Clone, Debug, Default)]
pub struct Allowlist {
    pub entries: Vec<Entry>,
}

impl Allowlist {
    pub fn empty() -> Allowlist {
        Allowlist::default()
    }

    pub fn parse(text: &str) -> anyhow::Result<Allowlist> {
        let mut entries = Vec::new();
        for (idx, raw_line) in text.lines().enumerate() {
            let line = raw_line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (head, reason) = match line.split_once("--") {
                Some((head, reason)) => (head.trim(), reason.trim()),
                None => anyhow::bail!(
                    "allowlist line {}: missing `-- justification` (waivers must say why): {raw_line:?}",
                    idx + 1
                ),
            };
            let mut parts = head.split_whitespace();
            let (Some(rule), Some(path), None) = (parts.next(), parts.next(), parts.next())
            else {
                anyhow::bail!(
                    "allowlist line {}: expected `rule-id path -- reason`, got {raw_line:?}",
                    idx + 1
                );
            };
            anyhow::ensure!(
                !reason.is_empty(),
                "allowlist line {}: empty justification",
                idx + 1
            );
            entries.push(Entry {
                rule: rule.to_string(),
                path: path.to_string(),
                reason: reason.to_string(),
                line: idx + 1,
            });
        }
        Ok(Allowlist { entries })
    }

    pub fn load(path: &Path) -> anyhow::Result<Allowlist> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading allowlist {}: {e}", path.display()))?;
        Allowlist::parse(&text)
    }

    /// Whether `rule` is waived for the whole file at `rel`.
    pub fn permits(&self, rule: &str, rel: &str) -> bool {
        self.entries
            .iter()
            .any(|e| e.rule == rule && e.path == rel)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}
