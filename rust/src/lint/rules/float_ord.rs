//! `float-ord`: no `partial_cmp`-based float ordering, no raw float
//! keys in ordered containers.
//!
//! `partial_cmp(...).unwrap()` panics on NaN and orders `-0.0 == 0.0`
//! arbitrarily relative to a later `total_cmp` pass — comparators in
//! `sort_by`/`binary_search_by`/`min_by` must use `f64::total_cmp`,
//! whose total order is the same on every platform. Raw `f64`/`f32`
//! keys in `BTreeMap`/`BTreeSet`/`BinaryHeap` don't even compile
//! without an ordering wrapper, but an `OrderedFloat`-style newtype
//! smuggled in by a future dependency would: flag the pattern anyway so
//! the intent is explicit.

use crate::lint::source::{find_token, SourceFile};
use crate::lint::{Diagnostic, Rule};

pub struct FloatOrd;

impl Rule for FloatOrd {
    fn id(&self) -> &'static str {
        "float-ord"
    }

    fn summary(&self) -> &'static str {
        "partial_cmp comparator or raw float key in an ordered container"
    }

    fn hint(&self) -> &'static str {
        "use f64::total_cmp (total order, NaN-safe) or a total-order key newtype"
    }

    fn applies(&self, _rel: &str) -> bool {
        true
    }

    fn check(&self, file: &SourceFile, out: &mut Vec<Diagnostic>) {
        for at in find_token(&file.masked, "partial_cmp") {
            // `fn partial_cmp(...)` is a `PartialOrd` impl definition,
            // not a call site.
            if file.masked[..at].trim_end().ends_with("fn") {
                continue;
            }
            out.push(Diagnostic {
                rule: self.id(),
                path: file.rel.clone(),
                line: file.line_of(at),
                message: "partial_cmp comparison (panics on NaN, not a total order)".to_string(),
                hint: self.hint(),
            });
        }
        for container in ["BTreeMap", "BTreeSet", "BinaryHeap"] {
            for at in find_token(&file.masked, container) {
                let rest = file.masked[at + container.len()..].trim_start();
                let Some(args) = rest.strip_prefix('<') else {
                    continue;
                };
                let args = args.trim_start();
                let floatish = ["f64", "f32"].iter().any(|f| {
                    args.strip_prefix(f).is_some_and(|tail| {
                        !tail
                            .bytes()
                            .next()
                            .is_some_and(crate::lint::source::is_ident_byte)
                    })
                });
                if floatish {
                    out.push(Diagnostic {
                        rule: self.id(),
                        path: file.rel.clone(),
                        line: file.line_of(at),
                        message: format!("raw float key in {container}"),
                        hint: self.hint(),
                    });
                }
            }
        }
    }
}
