//! `wall-clock`: no wall-clock or ambient-environment reads in
//! simulation code.
//!
//! Simulated time is the only clock the sim/scheduler/workload/faults
//! layers may observe: `Instant`/`SystemTime` values differ per run, and
//! `std::env` reads make outcomes depend on the invoking shell. Timing
//! for *reporting* (events/sec, peak RSS) belongs in `bench/` and
//! `util/rss.rs`, which this rule does not visit; the few in-scope
//! timer sites that only feed `wall_ms` report fields carry allowlist
//! entries.

use crate::lint::source::{find_substr, find_token, SourceFile};
use crate::lint::{Diagnostic, Rule};

/// Module prefixes whose outcomes must be a pure function of the seed.
const IN_SCOPE: &[&str] = &[
    "sim/", "scheduler/", "workload/", "faults/", "cluster/", "job/", "metrics/", "session/",
    "sweep/", "util/",
];

pub struct WallClock;

impl Rule for WallClock {
    fn id(&self) -> &'static str {
        "wall-clock"
    }

    fn summary(&self) -> &'static str {
        "wall-clock or environment read in outcome-affecting code"
    }

    fn hint(&self) -> &'static str {
        "derive everything from sim time and the seed; wall-clock I/O lives in bench/ and util/rss.rs"
    }

    fn applies(&self, rel: &str) -> bool {
        rel != "util/rss.rs" && IN_SCOPE.iter().any(|p| rel.starts_with(p))
    }

    fn check(&self, file: &SourceFile, out: &mut Vec<Diagnostic>) {
        for token in ["Instant", "SystemTime", "thread_rng"] {
            for at in find_token(&file.masked, token) {
                out.push(Diagnostic {
                    rule: self.id(),
                    path: file.rel.clone(),
                    line: file.line_of(at),
                    message: format!("{token} read in simulation code"),
                    hint: self.hint(),
                });
            }
        }
        // `env::var`, `env::var_os`, `env::vars…` — prefix match on the
        // call path so the variants stay covered.
        for at in find_substr(&file.masked, "env::var") {
            out.push(Diagnostic {
                rule: self.id(),
                path: file.rel.clone(),
                line: file.line_of(at),
                message: "environment read in simulation code".to_string(),
                hint: self.hint(),
            });
        }
    }
}
