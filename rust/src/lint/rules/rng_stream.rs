//! `rng-stream`: RNG construction flows through `RngStreams`/`StreamId`.
//!
//! The reproducibility format fixes a *tree* of named substreams
//! (Placement, Faults, Scheduler, Arrivals, Population) derived from the
//! master seed in `util/rng.rs`. A naked `Pcg64::seed_from_u64(...)`
//! outside that module creates an anonymous stream that can silently
//! alias an existing one — enabling a feature would then perturb draws
//! it must not touch. `util/rng.rs` (the derivation site itself) and
//! `testkit/` (ad-hoc property-test streams) are out of scope; the one
//! surviving call site, `faults/error_model.rs`, carries an allowlist
//! entry documenting its draw-compatibility contract.

use crate::lint::source::{find_token, SourceFile};
use crate::lint::{Diagnostic, Rule};

pub struct RngStream;

impl Rule for RngStream {
    fn id(&self) -> &'static str {
        "rng-stream"
    }

    fn summary(&self) -> &'static str {
        "naked RNG seeding outside the RngStreams substream discipline"
    }

    fn hint(&self) -> &'static str {
        "derive the generator via util::rng::RngStreams / StreamId"
    }

    fn applies(&self, rel: &str) -> bool {
        rel != "util/rng.rs" && !rel.starts_with("testkit/")
    }

    fn check(&self, file: &SourceFile, out: &mut Vec<Diagnostic>) {
        for at in find_token(&file.masked, "seed_from_u64") {
            out.push(Diagnostic {
                rule: self.id(),
                path: file.rel.clone(),
                line: file.line_of(at),
                message: "seed_from_u64 outside RngStreams (anonymous substream)".to_string(),
                hint: self.hint(),
            });
        }
    }
}
