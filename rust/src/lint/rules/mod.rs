//! The determinism-contract rule set.
//!
//! Each rule is a small [`Rule`](crate::lint::Rule) impl over the masked
//! source view; [`all`] is the registry the runner and the CLI iterate.

mod float_ord;
mod hash_container;
mod rng_stream;
mod unsafe_census;
mod wall_clock;

pub use float_ord::FloatOrd;
pub use hash_container::HashContainer;
pub use rng_stream::RngStream;
pub use unsafe_census::UnsafeCensus;
pub use wall_clock::WallClock;

use super::Rule;

/// Every shipped rule, in diagnostic-output order.
pub fn all() -> Vec<Box<dyn Rule>> {
    vec![
        Box::new(HashContainer),
        Box::new(FloatOrd),
        Box::new(WallClock),
        Box::new(RngStream),
        Box::new(UnsafeCensus),
    ]
}
