//! `hash-container`: no `std::collections::{HashMap, HashSet}` in
//! outcome-affecting code.
//!
//! Std hash containers use a randomized `SipHash` seed, so their
//! iteration order differs between processes — any outcome that touches
//! one risks losing byte-identical reproducibility. Simulation code must
//! use the deterministic `util::fxmap::FastMap`/`FastSet` (fixed-seed
//! FxHash) or an ordered `BTreeMap`/`BTreeSet`. The one legitimate site
//! — `util/fxmap.rs`, which *defines* the wrappers — carries an
//! allowlist entry.

use crate::lint::source::{find_token, SourceFile};
use crate::lint::{Diagnostic, Rule};

pub struct HashContainer;

impl Rule for HashContainer {
    fn id(&self) -> &'static str {
        "hash-container"
    }

    fn summary(&self) -> &'static str {
        "std HashMap/HashSet (randomized iteration order) in simulation code"
    }

    fn hint(&self) -> &'static str {
        "use util::fxmap::FastMap/FastSet (or BTreeMap/BTreeSet for ordered iteration)"
    }

    fn applies(&self, _rel: &str) -> bool {
        true
    }

    fn check(&self, file: &SourceFile, out: &mut Vec<Diagnostic>) {
        for token in ["HashMap", "HashSet"] {
            for at in find_token(&file.masked, token) {
                out.push(Diagnostic {
                    rule: self.id(),
                    path: file.rel.clone(),
                    line: file.line_of(at),
                    message: format!(
                        "std::collections::{token} has a process-random iteration order"
                    ),
                    hint: self.hint(),
                });
            }
        }
    }
}
