//! `unsafe-census`: every `unsafe` carries a `// SAFETY:` comment;
//! `static mut` is never acceptable.
//!
//! The crate is currently 100% safe code and the sharded driver's
//! concurrency runs entirely on channels — this rule keeps it that way
//! by making every future `unsafe` block justify itself at the use
//! site, and by banning `static mut` outright (it is both a data-race
//! hazard under the threaded fast-merge path and deprecated-in-spirit
//! upstream).

use crate::lint::source::{find_token, SourceFile};
use crate::lint::{Diagnostic, Rule};

/// How far above the `unsafe` keyword a `// SAFETY:` comment may sit.
const SAFETY_LOOKBACK_LINES: usize = 3;

pub struct UnsafeCensus;

impl Rule for UnsafeCensus {
    fn id(&self) -> &'static str {
        "unsafe-census"
    }

    fn summary(&self) -> &'static str {
        "unsafe without a SAFETY comment, or static mut"
    }

    fn hint(&self) -> &'static str {
        "justify the invariants in a `// SAFETY:` comment directly above (static mut: use channels or atomics)"
    }

    fn applies(&self, _rel: &str) -> bool {
        true
    }

    fn check(&self, file: &SourceFile, out: &mut Vec<Diagnostic>) {
        for at in find_token(&file.masked, "unsafe") {
            let line = file.line_of(at);
            // `static mut` handled below with its own message.
            let documented = (line.saturating_sub(SAFETY_LOOKBACK_LINES)..=line)
                .any(|l| l >= 1 && file.raw_line(l).contains("SAFETY:"));
            if !documented {
                out.push(Diagnostic {
                    rule: self.id(),
                    path: file.rel.clone(),
                    line,
                    message: "unsafe without a `// SAFETY:` comment".to_string(),
                    hint: self.hint(),
                });
            }
        }
        for at in find_token(&file.masked, "static") {
            let rest = file.masked[at + "static".len()..].trim_start();
            if rest.starts_with("mut")
                && !rest["mut".len()..]
                    .bytes()
                    .next()
                    .is_some_and(crate::lint::source::is_ident_byte)
            {
                out.push(Diagnostic {
                    rule: self.id(),
                    path: file.rel.clone(),
                    line: file.line_of(at),
                    message: "static mut (racy under the threaded fast-merge path)".to_string(),
                    hint: self.hint(),
                });
            }
        }
    }
}
