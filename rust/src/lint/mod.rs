//! # simlint — the determinism-contract static-analysis pass.
//!
//! Every headline number this crate produces rests on byte-identical
//! reproducibility: the same seed must yield the same `SimOutcome`
//! across thread counts, queue backends and shard counts. The
//! equivalence-test suites check that property *by example*; this pass
//! enforces the coding contract behind it *mechanically*, over every
//! file in `rust/src/**`:
//!
//! | rule id          | contract                                                        |
//! |------------------|-----------------------------------------------------------------|
//! | `hash-container` | no std `HashMap`/`HashSet` (use `FastMap`/`FastSet`/`BTreeMap`) |
//! | `float-ord`      | no `partial_cmp` comparators / raw float keys (use `total_cmp`) |
//! | `wall-clock`     | no `Instant`/`SystemTime`/`thread_rng`/env reads in sim code    |
//! | `rng-stream`     | RNG construction flows through `RngStreams`/`StreamId`          |
//! | `unsafe-census`  | every `unsafe` carries `// SAFETY:`; `static mut` is banned     |
//!
//! Escapes are explicit and audited: a file-scoped entry in the
//! committed `rust/simlint.allow` (`rule-id path -- justification`), or
//! an inline `// simlint: allow(rule-id) -- reason` magic comment on or
//! directly above the flagged line. `#[cfg(test)]` items are skipped —
//! the contract governs shipped simulation code.
//!
//! Run it as `hfsp lint [--deny] [--json]` or via the standalone
//! `simlint` binary CI uses as a gate. Diagnostics are span-accurate
//! (`path:line`, rule id, fix hint) and `--json` emits a
//! machine-readable report.

pub mod allowlist;
pub mod rules;
pub mod source;

pub use allowlist::Allowlist;
use source::SourceFile;
use std::path::{Path, PathBuf};

/// One violation: where, which rule, what to do instead.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub rule: &'static str,
    /// Path relative to the scanned source root, `/` separators.
    pub path: String,
    /// 1-based.
    pub line: usize,
    pub message: String,
    pub hint: &'static str,
}

impl std::fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {} (fix: {})",
            self.path, self.line, self.rule, self.message, self.hint
        )
    }
}

/// A determinism-contract rule over one scanned file.
pub trait Rule {
    /// Stable kebab-case id (`hash-container`, …) used in diagnostics,
    /// waivers and the allowlist.
    fn id(&self) -> &'static str;
    /// One-line description of the contract the rule enforces.
    fn summary(&self) -> &'static str;
    /// One-line fix hint attached to every diagnostic.
    fn hint(&self) -> &'static str;
    /// Whether the rule visits the file at `rel` at all (path scoping).
    fn applies(&self, rel: &str) -> bool;
    /// Emit raw candidate diagnostics; the runner filters test lines,
    /// inline waivers and allowlist entries afterwards.
    fn check(&self, file: &SourceFile, out: &mut Vec<Diagnostic>);
}

/// Lint one already-scanned file through every rule, applying the
/// test-region / waiver / allowlist filters.
pub fn lint_file(file: &SourceFile, allow: &Allowlist) -> Vec<Diagnostic> {
    let mut diags = Vec::new();
    for rule in rules::all() {
        if !rule.applies(&file.rel) || allow.permits(rule.id(), &file.rel) {
            continue;
        }
        let mut raw = Vec::new();
        rule.check(file, &mut raw);
        diags.extend(
            raw.into_iter()
                .filter(|d| !file.is_test_line(d.line) && !file.is_waived(d.line, d.rule)),
        );
    }
    diags.sort_by(|a, b| a.line.cmp(&b.line).then_with(|| a.rule.cmp(b.rule)));
    diags
}

/// Lint source text under a virtual relative path (fixture-test entry).
pub fn lint_text(rel: &str, text: &str, allow: &Allowlist) -> Vec<Diagnostic> {
    lint_file(&SourceFile::parse(rel, text), allow)
}

/// Recursively collect the `.rs` files under `root`, as sorted
/// root-relative `/`-separated paths (deterministic scan order).
pub fn collect_rs_files(root: &Path) -> anyhow::Result<Vec<PathBuf>> {
    fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
        for entry in std::fs::read_dir(dir)? {
            let path = entry?.path();
            if path.is_dir() {
                walk(&path, out)?;
            } else if path.extension().is_some_and(|e| e == "rs") {
                out.push(path);
            }
        }
        Ok(())
    }
    let mut files = Vec::new();
    walk(root, &mut files)
        .map_err(|e| anyhow::anyhow!("scanning {}: {e}", root.display()))?;
    files.sort();
    Ok(files)
}

/// Lint every `.rs` file under `src_root`. Returns diagnostics in
/// (path, line, rule) order.
pub fn lint_tree(src_root: &Path, allow: &Allowlist) -> anyhow::Result<Vec<Diagnostic>> {
    let mut diags = Vec::new();
    for path in collect_rs_files(src_root)? {
        let rel = path
            .strip_prefix(src_root)
            .unwrap_or(&path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        diags.extend(lint_file(&SourceFile::parse(&rel, &text), allow));
    }
    diags.sort_by(|a, b| {
        a.path
            .cmp(&b.path)
            .then_with(|| a.line.cmp(&b.line))
            .then_with(|| a.rule.cmp(b.rule))
    });
    Ok(diags)
}

/// Machine-readable report: `{"count": n, "diagnostics": [...]}`.
pub fn diagnostics_to_json(diags: &[Diagnostic]) -> crate::util::json::Json {
    use crate::util::json::Json;
    let rows = diags
        .iter()
        .map(|d| {
            let mut row = Json::obj();
            row.set("rule", Json::Str(d.rule.to_string()))
                .set("path", Json::Str(d.path.clone()))
                .set("line", Json::Num(d.line as f64))
                .set("message", Json::Str(d.message.clone()))
                .set("hint", Json::Str(d.hint.to_string()));
            row
        })
        .collect();
    let mut report = Json::obj();
    report
        .set("count", Json::Num(diags.len() as f64))
        .set("diagnostics", Json::Arr(rows));
    report
}

/// Locate the source root: an explicit `--src`, else `src/` when run
/// from `rust/`, else `rust/src/` when run from the repository root.
pub fn resolve_src_root(explicit: Option<&str>) -> anyhow::Result<PathBuf> {
    if let Some(src) = explicit {
        let path = PathBuf::from(src);
        anyhow::ensure!(path.is_dir(), "--src {}: not a directory", path.display());
        return Ok(path);
    }
    for candidate in ["src", "rust/src"] {
        let path = PathBuf::from(candidate);
        if path.join("lib.rs").is_file() {
            return Ok(path);
        }
    }
    anyhow::bail!("no src/lib.rs or rust/src/lib.rs below the working directory; pass --src")
}

/// The shared `hfsp lint` / `simlint` entry point. Returns the number
/// of diagnostics; with `deny` the caller turns a non-zero count into a
/// failing exit.
pub fn cli_main(
    src: Option<&str>,
    allow: Option<&str>,
    json: bool,
    deny: bool,
) -> anyhow::Result<usize> {
    let src_root = resolve_src_root(src)?;
    let allowlist = match allow {
        Some(path) => Allowlist::load(Path::new(path))?,
        None => {
            // The committed allowlist sits next to Cargo.toml, one level
            // above the source root.
            let default = src_root.join("..").join("simlint.allow");
            if default.is_file() {
                Allowlist::load(&default)?
            } else {
                Allowlist::empty()
            }
        }
    };
    let diags = lint_tree(&src_root, &allowlist)?;
    if json {
        println!("{}", diagnostics_to_json(&diags).to_string_pretty());
    } else {
        for d in &diags {
            println!("{d}");
        }
        println!(
            "simlint: {} file(s) scanned, {} violation(s), {} allowlist entr(ies)",
            collect_rs_files(&src_root)?.len(),
            diags.len(),
            allowlist.len()
        );
    }
    if deny && !diags.is_empty() {
        anyhow::bail!("simlint: {} determinism-contract violation(s)", diags.len());
    }
    Ok(diags.len())
}

#[cfg(test)]
mod tests {
    use super::source::{find_token, mask_source, SourceFile};
    use super::*;

    #[test]
    fn masking_blanks_comments_and_strings() {
        let raw = "let a = 1; // HashMap in a comment\nlet s = \"HashMap\"; /* HashMap */\n";
        let masked = mask_source(raw);
        assert_eq!(masked.len(), raw.len());
        assert!(find_token(&masked, "HashMap").is_empty());
        assert!(masked.contains("let a = 1;"));
    }

    #[test]
    fn masking_keeps_lifetimes_and_masks_chars() {
        let raw = "fn f<'a>(x: &'a str) { let c = 'h'; let e = '\\n'; }";
        let masked = mask_source(raw);
        assert!(masked.contains("<'a>"));
        assert!(!masked.contains("'h'"));
        assert!(!masked.contains("\\n"));
    }

    #[test]
    fn masking_handles_raw_strings() {
        let raw = "let r = r#\"Instant \" inside\"#; let i = 1;";
        let masked = mask_source(raw);
        assert!(find_token(&masked, "Instant").is_empty());
        assert!(masked.contains("let i = 1;"));
    }

    #[test]
    fn token_search_respects_word_boundaries() {
        let hay = "Instantiate Instant xInstant Instant_";
        assert_eq!(find_token(hay, "Instant").len(), 1);
    }

    #[test]
    fn cfg_test_regions_are_skipped() {
        let raw = "use std::collections::HashMap;\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                       use std::collections::HashMap;\n\
                   }\n";
        let file = SourceFile::parse("sim/x.rs", raw);
        assert!(!file.is_test_line(1));
        assert!(file.is_test_line(4));
        let diags = lint_file(&file, &Allowlist::empty());
        let hash: Vec<_> = diags.iter().filter(|d| d.rule == "hash-container").collect();
        assert_eq!(hash.len(), 1);
        assert_eq!(hash[0].line, 1);
    }

    #[test]
    fn inline_waivers_cover_their_line_and_the_next() {
        let raw = "// simlint: allow(hash-container) -- doc example\n\
                   use std::collections::HashMap;\n\
                   use std::collections::HashSet;\n";
        let diags = lint_text("sim/x.rs", raw, &Allowlist::empty());
        let hash: Vec<_> = diags.iter().filter(|d| d.rule == "hash-container").collect();
        assert_eq!(hash.len(), 1);
        assert_eq!(hash[0].line, 3);
    }

    #[test]
    fn allowlist_permits_whole_files_and_requires_reasons() {
        let allow = Allowlist::parse(
            "# comment\nhash-container sim/x.rs -- the one legit wrapper\n",
        )
        .unwrap();
        assert!(allow.permits("hash-container", "sim/x.rs"));
        assert!(!allow.permits("hash-container", "sim/y.rs"));
        assert!(!allow.permits("float-ord", "sim/x.rs"));
        assert!(Allowlist::parse("hash-container sim/x.rs\n").is_err());
        let diags = lint_text("sim/x.rs", "use std::collections::HashMap;\n", &allow);
        assert!(diags.iter().all(|d| d.rule != "hash-container"));
    }

    #[test]
    fn partial_cmp_definition_is_exempt_call_site_is_not() {
        let raw = "impl PartialOrd for X {\n\
                       fn partial_cmp(&self, o: &X) -> Option<Ordering> { None }\n\
                   }\n\
                   fn f(v: &mut [f64]) { v.sort_by(|a, b| a.partial_cmp(b).unwrap()); }\n";
        let diags = lint_text("sim/x.rs", raw, &Allowlist::empty());
        let ord: Vec<_> = diags.iter().filter(|d| d.rule == "float-ord").collect();
        assert_eq!(ord.len(), 1);
        assert_eq!(ord[0].line, 4);
    }

    #[test]
    fn wall_clock_scoping_follows_the_contract() {
        let raw = "use std::time::Instant;\n";
        assert_eq!(lint_text("sim/engine.rs", raw, &Allowlist::empty()).len(), 1);
        assert!(lint_text("bench/mod.rs", raw, &Allowlist::empty()).is_empty());
        assert!(lint_text("util/rss.rs", raw, &Allowlist::empty()).is_empty());
    }

    #[test]
    fn unsafe_requires_safety_comment() {
        let bad = "fn f() { unsafe { g() } }\n";
        let good = "// SAFETY: g has no preconditions here\nfn f() { unsafe { g() } }\n";
        assert_eq!(lint_text("sim/x.rs", bad, &Allowlist::empty()).len(), 1);
        assert!(lint_text("sim/x.rs", good, &Allowlist::empty()).is_empty());
        let diags = lint_text("sim/x.rs", "static mut COUNTER: u64 = 0;\n", &Allowlist::empty());
        assert_eq!(diags.len(), 1);
        assert!(diags[0].message.contains("static mut"));
    }

    #[test]
    fn json_report_shape() {
        let diags = lint_text(
            "sim/x.rs",
            "use std::collections::HashMap;\n",
            &Allowlist::empty(),
        );
        let json = diagnostics_to_json(&diags);
        assert_eq!(json.get("count").and_then(|c| c.as_u64()), Some(1));
        let rows = json.get("diagnostics").and_then(|d| d.as_arr()).unwrap();
        assert_eq!(rows[0].get("rule").and_then(|r| r.as_str()), Some("hash-container"));
        assert_eq!(rows[0].get("line").and_then(|l| l.as_u64()), Some(1));
    }
}
