//! Discrete-event simulation engine.
//!
//! The paper evaluates HFSP on a 100-node EC2 cluster and on **Mumak**,
//! Hadoop's own discrete-event emulator. This module is our
//! Mumak-equivalent substrate: a deterministic event queue + virtual clock
//! over which the cluster model ([`crate::cluster`]) is built.
//!
//! Determinism notes:
//! * events at equal timestamps are delivered in insertion (FIFO) order —
//!   the queue carries a monotonically increasing sequence number;
//! * simulated time is `f64` seconds; the engine asserts time never flows
//!   backwards.
//!
//! The pending-event set has two interchangeable backends behind the
//! sealed [`PendingQueue`] trait — the binary-heap [`EventQueue`]
//! reference and the bucketed [`CalendarQueue`] default — selected per
//! run via [`QueueKind`] (`SimConfig.queue` / `--queue`). Both realize
//! the identical `(time, class, seq)` delivery order, pinned by the
//! differential testbed in `tests/queue_differential.rs`.

pub mod calendar;
pub mod engine;
pub mod queue;
pub mod shard;

pub use calendar::CalendarQueue;
pub use engine::{Engine, StopReason};
pub use queue::{EventQueue, PendingQueue, QueueKind, ScheduledEvent};
pub use shard::{AutoWindow, MergeMode, ShardSpec, ShardedQueue, WindowArg, WindowAuto, WindowTraffic};

/// Simulated time, in seconds since simulation start.
pub type Time = f64;
