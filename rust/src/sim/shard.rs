//! Sharded execution: spec types and the deterministic-merge queue.
//!
//! One simulation can be split across `S` shards, each owning a
//! contiguous slice of the cluster ([`Partition`]) and its own event
//! timeline. Two merge modes exist (selected by [`MergeMode`] on the
//! [`ShardSpec`]):
//!
//! * **Deterministic** — the per-shard timelines are lanes of one
//!   [`ShardedQueue`], k-way merged on the exact global
//!   `(time, class, seq)` delivery order. A single driver loop consumes
//!   the merged stream, so the run is *byte-identical* to the serial
//!   driver — the shard structure is observable only through the queue
//!   label. This is the pinned serial-equivalence mode.
//! * **Fast** — shards run on real threads under a conservative
//!   time-window barrier, exchanging jobs and demand digests through
//!   MPSC channels at window boundaries (see
//!   [`run_session`](crate::cluster::driver::run_session)). Tie order
//!   across shards is relaxed; aggregate metrics are gated by tolerance
//!   instead of byte equality.
//!
//! [`Partition`]: crate::cluster::partition::Partition

use super::queue::{sealed, PendingQueue, ScheduledEvent};
use super::Time;

/// How a sharded run recombines its per-shard results.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum MergeMode {
    /// Exact k-way merge on the global `(time, class, seq)` order:
    /// byte-identical to the serial driver (the default).
    #[default]
    Deterministic,
    /// Threaded window-barrier execution; same-instant tie order across
    /// shards is relaxed for throughput.
    Fast,
}

impl MergeMode {
    pub fn name(self) -> &'static str {
        match self {
            MergeMode::Deterministic => "deterministic",
            MergeMode::Fast => "fast",
        }
    }

    pub fn from_name(name: &str) -> anyhow::Result<Self> {
        match name {
            "deterministic" => Ok(MergeMode::Deterministic),
            "fast" => Ok(MergeMode::Fast),
            other => anyhow::bail!("unknown merge mode {other:?} (deterministic|fast)"),
        }
    }
}

/// Bounds for the adaptive barrier window (`--window auto[:min,max]`,
/// `sim.window_auto*` config keys). `None` bounds derive from the base
/// window at [`AutoWindow::new`] time.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WindowAuto {
    /// Narrowest the controller may shrink the window, simulated
    /// seconds. `None` = the base (fixed) window.
    pub min_s: Option<f64>,
    /// Widest the controller may grow the window, simulated seconds.
    /// `None` = 64x the base window.
    pub max_s: Option<f64>,
}

/// Sharding configuration carried on
/// [`SimConfig`](crate::cluster::driver::SimConfig) (`--shards`,
/// `--merge`, `--window`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShardSpec {
    /// Number of cluster partitions; `1` is the plain serial driver.
    pub count: usize,
    /// Recombination mode for `count > 1`.
    pub merge: MergeMode,
    /// Barrier window length for the fast mode, simulated seconds.
    /// `None` derives the window from the heartbeat period (safe but
    /// barrier-heavy on sparse workloads; benches use wider windows).
    pub window_s: Option<f64>,
    /// Adaptive window sizing for the fast mode: the coordinator
    /// widens/narrows the next window from observed cross-shard traffic
    /// within these bounds. `None` keeps the window fixed. Ignored by
    /// the deterministic merge (it has no window barrier).
    pub auto_window: Option<WindowAuto>,
}

impl Default for ShardSpec {
    fn default() -> Self {
        Self {
            count: 1,
            merge: MergeMode::Deterministic,
            window_s: None,
            auto_window: None,
        }
    }
}

impl ShardSpec {
    /// Whether this spec degenerates to the single-loop serial driver.
    pub fn is_serial(&self) -> bool {
        self.count <= 1
    }

    /// Clamp the shard count to the node count (every shard must own at
    /// least one node).
    pub fn normalized(mut self, nodes: usize) -> Self {
        self.count = self.count.clamp(1, nodes.max(1));
        self
    }

    /// The effective barrier window: the explicit setting when positive
    /// and finite, else one heartbeat period.
    pub fn window(&self, heartbeat_s: f64) -> f64 {
        match self.window_s {
            Some(w) if w.is_finite() && w > 0.0 => w,
            _ => heartbeat_s.max(f64::MIN_POSITIVE),
        }
    }
}

/// Per-window cross-shard traffic, as observed by the coordinator at
/// one barrier. Every field is a sum/count over the window's shard
/// reports, so the value is invariant under report arrival order —
/// the property that keeps [`AutoWindow`] deterministic.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WindowTraffic {
    /// Jobs the coordinator routed into shards this window (new
    /// arrivals plus re-routed backlog).
    pub routed_jobs: usize,
    /// Jobs that crossed shards at this barrier: spillover exports plus
    /// stolen jobs. High crossing traffic means the window is too wide
    /// for the current contention level.
    pub crossed_jobs: usize,
    /// Shards that reported zero live jobs at the barrier — idle shards
    /// paid the barrier for nothing, so the window is too narrow.
    pub idle_shards: usize,
    /// Total shard count, for context.
    pub shards: usize,
}

/// Deterministic multiplicative-increase/multiplicative-decrease
/// controller for the fast-merge barrier window.
///
/// The rule, applied once per barrier from that window's
/// [`WindowTraffic`]:
///
/// * any cross-shard job movement (`crossed_jobs > 0`) → **halve** the
///   window (clamped to `min`): barriers are doing real work, so make
///   them cheap and frequent to cut job latency across shards;
/// * no crossing traffic at all → **double** the window (clamped to
///   `max`): low-interaction phases stop paying a barrier per
///   heartbeat.
///
/// The controller is a pure function of its input sequence: given the
/// same per-window reports it produces the same horizon sequence, on
/// any thread interleaving (pinned by `tests/barrier_model.rs`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AutoWindow {
    min_s: f64,
    max_s: f64,
    current_s: f64,
}

impl AutoWindow {
    /// A controller starting at `base_s` (the fixed window the spec
    /// would have used), bounded by the spec's `auto_window` bounds.
    pub fn new(base_s: f64, auto: WindowAuto) -> Self {
        let base = if base_s.is_finite() && base_s > 0.0 {
            base_s
        } else {
            f64::MIN_POSITIVE
        };
        let sane = |v: Option<f64>, fallback: f64| match v {
            Some(x) if x.is_finite() && x > 0.0 => x,
            _ => fallback,
        };
        let min_s = sane(auto.min_s, base);
        let max_s = sane(auto.max_s, base * 64.0).max(min_s);
        Self {
            min_s,
            max_s,
            current_s: base.clamp(min_s, max_s),
        }
    }

    /// The window length to use for the next barrier.
    pub fn current(&self) -> f64 {
        self.current_s
    }

    /// Fold one barrier's traffic into the controller.
    pub fn observe(&mut self, traffic: WindowTraffic) {
        if traffic.crossed_jobs > 0 {
            self.current_s = (self.current_s * 0.5).max(self.min_s);
        } else {
            self.current_s = (self.current_s * 2.0).min(self.max_s);
        }
    }
}

/// Parsed form of the `--window` CLI flag.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum WindowArg {
    /// `--window 30`: a fixed barrier window.
    Fixed(f64),
    /// `--window auto` / `--window auto:5,120`: adaptive sizing with
    /// optional explicit bounds.
    Auto(WindowAuto),
}

impl WindowArg {
    /// Parse `"30"`, `"auto"`, or `"auto:MIN,MAX"` (either bound may be
    /// left empty, as in `"auto:,120"`).
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        if let Some(bounds) = s.strip_prefix("auto") {
            let bounds = bounds.strip_prefix(':').unwrap_or(bounds);
            if bounds.is_empty() {
                return Ok(WindowArg::Auto(WindowAuto::default()));
            }
            let mut it = bounds.splitn(2, ',');
            let parse_bound = |part: Option<&str>, which: &str| -> anyhow::Result<Option<f64>> {
                match part.map(str::trim) {
                    None | Some("") => Ok(None),
                    Some(v) => {
                        let x: f64 = v
                            .parse()
                            .map_err(|_| anyhow::anyhow!("bad --window auto {which} bound {v:?}"))?;
                        anyhow::ensure!(
                            x.is_finite() && x > 0.0,
                            "--window auto {which} bound must be positive and finite"
                        );
                        Ok(Some(x))
                    }
                }
            };
            let min_s = parse_bound(it.next(), "min")?;
            let max_s = parse_bound(it.next(), "max")?;
            if let (Some(lo), Some(hi)) = (min_s, max_s) {
                anyhow::ensure!(lo <= hi, "--window auto bounds must satisfy min <= max");
            }
            return Ok(WindowArg::Auto(WindowAuto { min_s, max_s }));
        }
        let w: f64 = s
            .parse()
            .map_err(|_| anyhow::anyhow!("--window must be a number or auto[:min,max], got {s:?}"))?;
        anyhow::ensure!(w > 0.0 && w.is_finite(), "--window must be positive and finite");
        Ok(WindowArg::Fixed(w))
    }
}

/// Routes an event to the shard lane that owns it.
pub type LaneRouter<E> = Box<dyn Fn(&E) -> usize>;

/// The deterministic-merge pending-event set: `S` per-shard lanes (each
/// an ordinary [`PendingQueue`] backend over `(global_seq, event)`
/// payloads) k-way merged on the **global** `(time, class, seq)` order.
///
/// Every push stamps the event with a queue-wide sequence number and
/// routes it to its owning lane; pop compares lane heads on
/// `(time, class, global_seq)`. Within one lane the lane-local insertion
/// order and the global sequence order agree (both increase with every
/// push), so each lane's head is also its global minimum — the k-way
/// min over heads reproduces the exact serial delivery order, and the
/// observable `ScheduledEvent` stream (times, classes, sequence numbers)
/// is identical to a single [`EventQueue`](super::EventQueue).
///
/// `peek` serves from a stash that is a *pure cache* of the lane heads
/// (cloned out and re-stamped, invalidated by any push/pop): the trait
/// returns a borrow, but the merged head lives in no single lane.
pub struct ShardedQueue<E: Clone, Q: PendingQueue<(u64, E)>> {
    lanes: Vec<Q>,
    router: LaneRouter<E>,
    next_seq: u64,
    live: usize,
    peak_len: usize,
    stash: Option<ScheduledEvent<E>>,
}

impl<E: Clone, Q: PendingQueue<(u64, E)>> ShardedQueue<E, Q> {
    /// A queue with `count` lanes. `gap_s` is the *global* typical
    /// inter-event gap; each lane sees only `1/count` of the stream, so
    /// lanes are tuned to `gap_s * count`.
    pub fn new(count: usize, gap_s: f64, router: LaneRouter<E>) -> Self {
        let count = count.max(1);
        let lane_gap = gap_s * count as f64;
        Self {
            lanes: (0..count).map(|_| Q::with_gap_hint(lane_gap)).collect(),
            router,
            next_seq: 0,
            live: 0,
            peak_len: 0,
            stash: None,
        }
    }

    /// Number of lanes.
    pub fn lane_count(&self) -> usize {
        self.lanes.len()
    }

    fn push_routed(&mut self, time: Time, event: E, priority: bool) -> u64 {
        self.stash = None;
        let seq = self.next_seq;
        self.next_seq += 1;
        let lane = (self.router)(&event).min(self.lanes.len() - 1);
        if priority {
            self.lanes[lane].push_priority(time, (seq, event));
        } else {
            self.lanes[lane].push(time, (seq, event));
        }
        self.live += 1;
        self.peak_len = self.peak_len.max(self.live);
        seq
    }

    /// The lane holding the global minimum head, by `(time, class,
    /// global_seq)`. Global sequence numbers are unique, so the order is
    /// total and tie-free across lanes.
    fn min_lane(&mut self) -> Option<usize> {
        let mut best: Option<(Time, u8, u64, usize)> = None;
        for (i, lane) in self.lanes.iter_mut().enumerate() {
            let Some(head) = lane.peek() else { continue };
            let key = (head.time, head.class, head.event.0, i);
            let better = match &best {
                None => true,
                Some((t, c, s, _)) => {
                    (key.0.total_cmp(t))
                        .then(key.1.cmp(c))
                        .then(key.2.cmp(s))
                        .is_lt()
                }
            };
            if better {
                best = Some(key);
            }
        }
        best.map(|(_, _, _, i)| i)
    }
}

impl<E: Clone, Q: PendingQueue<(u64, E)>> sealed::Sealed for ShardedQueue<E, Q> {}

impl<E: Clone, Q: PendingQueue<(u64, E)>> PendingQueue<E> for ShardedQueue<E, Q> {
    const LABEL: &'static str = "sharded";

    /// Trait-mandated fallback: a single lane with a trivial router
    /// (the driver always constructs sharded queues via
    /// [`ShardedQueue::new`]).
    fn with_gap_hint(gap_s: f64) -> Self {
        Self::new(1, gap_s, Box::new(|_| 0))
    }

    fn push(&mut self, time: Time, event: E) -> u64 {
        self.push_routed(time, event, false)
    }

    fn push_priority(&mut self, time: Time, event: E) -> u64 {
        self.push_routed(time, event, true)
    }

    fn pop(&mut self) -> Option<ScheduledEvent<E>> {
        self.stash = None;
        let lane = self.min_lane()?;
        let inner = self.lanes[lane].pop().expect("peeked lane head vanished");
        self.live -= 1;
        // Re-stamp with the global sequence number: the merged stream is
        // observably identical to a single queue's.
        Some(ScheduledEvent {
            time: inner.time,
            class: inner.class,
            seq: inner.event.0,
            event: inner.event.1,
        })
    }

    fn peek(&mut self) -> Option<&ScheduledEvent<E>> {
        if self.stash.is_none() {
            let lane = self.min_lane()?;
            let head = self.lanes[lane].peek().expect("min lane lost its head");
            self.stash = Some(ScheduledEvent {
                time: head.time,
                class: head.class,
                seq: head.event.0,
                event: head.event.1.clone(),
            });
        }
        self.stash.as_ref()
    }

    fn peek_time(&mut self) -> Option<Time> {
        // Stash-free: the earliest time needs no tie-breaking.
        self.lanes
            .iter_mut()
            .filter_map(|l| l.peek_time())
            .min_by(|a, b| a.total_cmp(b))
    }

    fn len(&self) -> usize {
        self.live
    }

    fn scheduled_count(&self) -> u64 {
        self.next_seq
    }

    fn peak_len(&self) -> usize {
        self.peak_len
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{CalendarQueue, EventQueue};

    #[test]
    fn merge_mode_names_round_trip() {
        for mode in [MergeMode::Deterministic, MergeMode::Fast] {
            assert_eq!(MergeMode::from_name(mode.name()).unwrap(), mode);
        }
        assert!(MergeMode::from_name("loose").is_err());
        assert_eq!(MergeMode::default(), MergeMode::Deterministic);
    }

    #[test]
    fn spec_defaults_to_serial_and_normalizes() {
        let spec = ShardSpec::default();
        assert!(spec.is_serial());
        assert_eq!(spec.normalized(4).count, 1);
        let wide = ShardSpec {
            count: 16,
            ..Default::default()
        };
        assert_eq!(wide.normalized(4).count, 4);
        assert_eq!(wide.normalized(0).count, 1);
        assert!(!wide.normalized(8).is_serial());
    }

    #[test]
    fn spec_window_falls_back_to_heartbeat() {
        let mut spec = ShardSpec::default();
        assert_eq!(spec.window(3.0), 3.0);
        spec.window_s = Some(30.0);
        assert_eq!(spec.window(3.0), 30.0);
        spec.window_s = Some(0.0);
        assert_eq!(spec.window(3.0), 3.0);
        spec.window_s = Some(f64::INFINITY);
        assert_eq!(spec.window(3.0), 3.0);
    }

    #[test]
    fn window_arg_parses_fixed_auto_and_bounds() {
        assert_eq!(WindowArg::parse("30").unwrap(), WindowArg::Fixed(30.0));
        assert_eq!(
            WindowArg::parse("auto").unwrap(),
            WindowArg::Auto(WindowAuto { min_s: None, max_s: None })
        );
        assert_eq!(
            WindowArg::parse("auto:5,120").unwrap(),
            WindowArg::Auto(WindowAuto { min_s: Some(5.0), max_s: Some(120.0) })
        );
        assert_eq!(
            WindowArg::parse("auto:,120").unwrap(),
            WindowArg::Auto(WindowAuto { min_s: None, max_s: Some(120.0) })
        );
        assert_eq!(
            WindowArg::parse("auto:5").unwrap(),
            WindowArg::Auto(WindowAuto { min_s: Some(5.0), max_s: None })
        );
        assert!(WindowArg::parse("0").is_err());
        assert!(WindowArg::parse("-3").is_err());
        assert!(WindowArg::parse("inf").is_err());
        assert!(WindowArg::parse("auto:120,5").is_err());
        assert!(WindowArg::parse("auto:x,5").is_err());
        assert!(WindowArg::parse("fast").is_err());
    }

    #[test]
    fn auto_window_mimd_rule_is_bounded_and_deterministic() {
        let mut w = AutoWindow::new(10.0, WindowAuto { min_s: Some(5.0), max_s: Some(40.0) });
        assert_eq!(w.current(), 10.0);
        let quiet = WindowTraffic { shards: 4, ..Default::default() };
        let busy = WindowTraffic { crossed_jobs: 3, shards: 4, ..Default::default() };
        w.observe(quiet);
        assert_eq!(w.current(), 20.0);
        w.observe(quiet);
        assert_eq!(w.current(), 40.0);
        w.observe(quiet);
        assert_eq!(w.current(), 40.0, "clamped at max");
        w.observe(busy);
        assert_eq!(w.current(), 20.0);
        w.observe(busy);
        w.observe(busy);
        assert_eq!(w.current(), 5.0, "clamped at min");
        // Replaying the same traffic sequence reproduces the same state.
        let mut replay = AutoWindow::new(10.0, WindowAuto { min_s: Some(5.0), max_s: Some(40.0) });
        for t in [quiet, quiet, quiet, busy, busy, busy] {
            replay.observe(t);
        }
        assert_eq!(replay, w);
    }

    #[test]
    fn auto_window_defaults_derive_from_base() {
        let mut w = AutoWindow::new(3.0, WindowAuto::default());
        // min defaults to the base window, max to 64x base.
        for _ in 0..10 {
            w.observe(WindowTraffic::default());
        }
        assert_eq!(w.current(), 3.0 * 64.0);
        for _ in 0..10 {
            w.observe(WindowTraffic { crossed_jobs: 1, ..Default::default() });
        }
        assert_eq!(w.current(), 3.0);
        // min > max inputs are reconciled instead of panicking.
        let odd = AutoWindow::new(10.0, WindowAuto { min_s: Some(50.0), max_s: Some(20.0) });
        assert!(odd.current() >= 20.0 && odd.current() <= 50.0);
    }

    /// Drive the same operation stream through a plain queue and a
    /// sharded one; the full observable pop stream — times, classes and
    /// sequence numbers — must match exactly, whatever the router.
    fn assert_merged_stream_matches<Q: PendingQueue<(u64, u32)>>(lanes: usize) {
        let mut reference = EventQueue::new();
        let mut sharded: ShardedQueue<u32, Q> =
            ShardedQueue::new(lanes, 0.5, Box::new(|ev: &u32| (*ev as usize) % 7));
        let times = [
            3.0, 1.0, 1.0, 2.5, 1.0, 9.0, 2.5, 2.5, 0.5, 4.0, 1.0, 3.0, 3.0, 0.5, 6.0,
        ];
        for (i, &t) in times.iter().enumerate() {
            let ev = i as u32;
            if i % 4 == 0 {
                reference.push_priority(t, ev);
                sharded.push_priority(t, ev);
            } else {
                reference.push(t, ev);
                sharded.push(t, ev);
            }
        }
        assert_eq!(sharded.len(), times.len());
        assert_eq!(sharded.scheduled_count(), times.len() as u64);
        assert_eq!(sharded.peak_len(), times.len());
        loop {
            // Interleave peeks to exercise the stash cache.
            let (pt, ps) = match sharded.peek() {
                Some(head) => (head.time, head.seq),
                None => break,
            };
            assert_eq!(sharded.peek_time(), Some(pt));
            let want = reference.pop().expect("reference drained early");
            let got = sharded.pop().expect("sharded drained early");
            assert_eq!((got.time, got.class, got.seq), (want.time, want.class, want.seq));
            assert_eq!(got.event, want.event);
            assert_eq!((pt, ps), (want.time, want.seq), "peek matches pop");
        }
        assert!(reference.pop().is_none(), "sharded queue dropped events");
        assert!(sharded.is_empty());
    }

    #[test]
    fn sharded_heap_lanes_reproduce_serial_order() {
        assert_merged_stream_matches::<EventQueue<(u64, u32)>>(1);
        assert_merged_stream_matches::<EventQueue<(u64, u32)>>(3);
        assert_merged_stream_matches::<EventQueue<(u64, u32)>>(7);
    }

    #[test]
    fn sharded_calendar_lanes_reproduce_serial_order() {
        assert_merged_stream_matches::<CalendarQueue<(u64, u32)>>(2);
        assert_merged_stream_matches::<CalendarQueue<(u64, u32)>>(5);
    }

    #[test]
    fn push_invalidates_the_peek_stash() {
        let mut q: ShardedQueue<u32, EventQueue<(u64, u32)>> =
            ShardedQueue::new(2, 1.0, Box::new(|ev: &u32| *ev as usize));
        q.push(5.0, 1);
        assert_eq!(q.peek().unwrap().event, 1);
        // An earlier event on the *other* lane must displace the cached
        // head.
        q.push(1.0, 0);
        assert_eq!(q.peek().unwrap().event, 0);
        assert_eq!(q.pop().unwrap().event, 0);
        assert_eq!(q.pop().unwrap().event, 1);
        assert!(q.pop().is_none());
    }

    #[test]
    fn router_out_of_range_clamps_to_last_lane() {
        let mut q: ShardedQueue<u32, EventQueue<(u64, u32)>> =
            ShardedQueue::new(2, 1.0, Box::new(|_| 99));
        q.push(1.0, 7);
        assert_eq!(q.lane_count(), 2);
        assert_eq!(q.pop().unwrap().event, 7);
    }
}
