//! Sharded execution: spec types and the deterministic-merge queue.
//!
//! One simulation can be split across `S` shards, each owning a
//! contiguous slice of the cluster ([`Partition`]) and its own event
//! timeline. Two merge modes exist (selected by [`MergeMode`] on the
//! [`ShardSpec`]):
//!
//! * **Deterministic** — the per-shard timelines are lanes of one
//!   [`ShardedQueue`], k-way merged on the exact global
//!   `(time, class, seq)` delivery order. A single driver loop consumes
//!   the merged stream, so the run is *byte-identical* to the serial
//!   driver — the shard structure is observable only through the queue
//!   label. This is the pinned serial-equivalence mode.
//! * **Fast** — shards run on real threads under a conservative
//!   time-window barrier, exchanging jobs and demand digests through
//!   MPSC channels at window boundaries (see
//!   [`run_session`](crate::cluster::driver::run_session)). Tie order
//!   across shards is relaxed; aggregate metrics are gated by tolerance
//!   instead of byte equality.
//!
//! [`Partition`]: crate::cluster::partition::Partition

use super::queue::{sealed, PendingQueue, ScheduledEvent};
use super::Time;

/// How a sharded run recombines its per-shard results.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum MergeMode {
    /// Exact k-way merge on the global `(time, class, seq)` order:
    /// byte-identical to the serial driver (the default).
    #[default]
    Deterministic,
    /// Threaded window-barrier execution; same-instant tie order across
    /// shards is relaxed for throughput.
    Fast,
}

impl MergeMode {
    pub fn name(self) -> &'static str {
        match self {
            MergeMode::Deterministic => "deterministic",
            MergeMode::Fast => "fast",
        }
    }

    pub fn from_name(name: &str) -> anyhow::Result<Self> {
        match name {
            "deterministic" => Ok(MergeMode::Deterministic),
            "fast" => Ok(MergeMode::Fast),
            other => anyhow::bail!("unknown merge mode {other:?} (deterministic|fast)"),
        }
    }
}

/// Sharding configuration carried on
/// [`SimConfig`](crate::cluster::driver::SimConfig) (`--shards`,
/// `--merge`, `--window`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShardSpec {
    /// Number of cluster partitions; `1` is the plain serial driver.
    pub count: usize,
    /// Recombination mode for `count > 1`.
    pub merge: MergeMode,
    /// Barrier window length for the fast mode, simulated seconds.
    /// `None` derives the window from the heartbeat period (safe but
    /// barrier-heavy on sparse workloads; benches use wider windows).
    pub window_s: Option<f64>,
}

impl Default for ShardSpec {
    fn default() -> Self {
        Self {
            count: 1,
            merge: MergeMode::Deterministic,
            window_s: None,
        }
    }
}

impl ShardSpec {
    /// Whether this spec degenerates to the single-loop serial driver.
    pub fn is_serial(&self) -> bool {
        self.count <= 1
    }

    /// Clamp the shard count to the node count (every shard must own at
    /// least one node).
    pub fn normalized(mut self, nodes: usize) -> Self {
        self.count = self.count.clamp(1, nodes.max(1));
        self
    }

    /// The effective barrier window: the explicit setting when positive
    /// and finite, else one heartbeat period.
    pub fn window(&self, heartbeat_s: f64) -> f64 {
        match self.window_s {
            Some(w) if w.is_finite() && w > 0.0 => w,
            _ => heartbeat_s.max(f64::MIN_POSITIVE),
        }
    }
}

/// Routes an event to the shard lane that owns it.
pub type LaneRouter<E> = Box<dyn Fn(&E) -> usize>;

/// The deterministic-merge pending-event set: `S` per-shard lanes (each
/// an ordinary [`PendingQueue`] backend over `(global_seq, event)`
/// payloads) k-way merged on the **global** `(time, class, seq)` order.
///
/// Every push stamps the event with a queue-wide sequence number and
/// routes it to its owning lane; pop compares lane heads on
/// `(time, class, global_seq)`. Within one lane the lane-local insertion
/// order and the global sequence order agree (both increase with every
/// push), so each lane's head is also its global minimum — the k-way
/// min over heads reproduces the exact serial delivery order, and the
/// observable `ScheduledEvent` stream (times, classes, sequence numbers)
/// is identical to a single [`EventQueue`](super::EventQueue).
///
/// `peek` serves from a stash that is a *pure cache* of the lane heads
/// (cloned out and re-stamped, invalidated by any push/pop): the trait
/// returns a borrow, but the merged head lives in no single lane.
pub struct ShardedQueue<E: Clone, Q: PendingQueue<(u64, E)>> {
    lanes: Vec<Q>,
    router: LaneRouter<E>,
    next_seq: u64,
    live: usize,
    peak_len: usize,
    stash: Option<ScheduledEvent<E>>,
}

impl<E: Clone, Q: PendingQueue<(u64, E)>> ShardedQueue<E, Q> {
    /// A queue with `count` lanes. `gap_s` is the *global* typical
    /// inter-event gap; each lane sees only `1/count` of the stream, so
    /// lanes are tuned to `gap_s * count`.
    pub fn new(count: usize, gap_s: f64, router: LaneRouter<E>) -> Self {
        let count = count.max(1);
        let lane_gap = gap_s * count as f64;
        Self {
            lanes: (0..count).map(|_| Q::with_gap_hint(lane_gap)).collect(),
            router,
            next_seq: 0,
            live: 0,
            peak_len: 0,
            stash: None,
        }
    }

    /// Number of lanes.
    pub fn lane_count(&self) -> usize {
        self.lanes.len()
    }

    fn push_routed(&mut self, time: Time, event: E, priority: bool) -> u64 {
        self.stash = None;
        let seq = self.next_seq;
        self.next_seq += 1;
        let lane = (self.router)(&event).min(self.lanes.len() - 1);
        if priority {
            self.lanes[lane].push_priority(time, (seq, event));
        } else {
            self.lanes[lane].push(time, (seq, event));
        }
        self.live += 1;
        self.peak_len = self.peak_len.max(self.live);
        seq
    }

    /// The lane holding the global minimum head, by `(time, class,
    /// global_seq)`. Global sequence numbers are unique, so the order is
    /// total and tie-free across lanes.
    fn min_lane(&mut self) -> Option<usize> {
        let mut best: Option<(Time, u8, u64, usize)> = None;
        for (i, lane) in self.lanes.iter_mut().enumerate() {
            let Some(head) = lane.peek() else { continue };
            let key = (head.time, head.class, head.event.0, i);
            let better = match &best {
                None => true,
                Some((t, c, s, _)) => {
                    (key.0.total_cmp(t))
                        .then(key.1.cmp(c))
                        .then(key.2.cmp(s))
                        .is_lt()
                }
            };
            if better {
                best = Some(key);
            }
        }
        best.map(|(_, _, _, i)| i)
    }
}

impl<E: Clone, Q: PendingQueue<(u64, E)>> sealed::Sealed for ShardedQueue<E, Q> {}

impl<E: Clone, Q: PendingQueue<(u64, E)>> PendingQueue<E> for ShardedQueue<E, Q> {
    const LABEL: &'static str = "sharded";

    /// Trait-mandated fallback: a single lane with a trivial router
    /// (the driver always constructs sharded queues via
    /// [`ShardedQueue::new`]).
    fn with_gap_hint(gap_s: f64) -> Self {
        Self::new(1, gap_s, Box::new(|_| 0))
    }

    fn push(&mut self, time: Time, event: E) -> u64 {
        self.push_routed(time, event, false)
    }

    fn push_priority(&mut self, time: Time, event: E) -> u64 {
        self.push_routed(time, event, true)
    }

    fn pop(&mut self) -> Option<ScheduledEvent<E>> {
        self.stash = None;
        let lane = self.min_lane()?;
        let inner = self.lanes[lane].pop().expect("peeked lane head vanished");
        self.live -= 1;
        // Re-stamp with the global sequence number: the merged stream is
        // observably identical to a single queue's.
        Some(ScheduledEvent {
            time: inner.time,
            class: inner.class,
            seq: inner.event.0,
            event: inner.event.1,
        })
    }

    fn peek(&mut self) -> Option<&ScheduledEvent<E>> {
        if self.stash.is_none() {
            let lane = self.min_lane()?;
            let head = self.lanes[lane].peek().expect("min lane lost its head");
            self.stash = Some(ScheduledEvent {
                time: head.time,
                class: head.class,
                seq: head.event.0,
                event: head.event.1.clone(),
            });
        }
        self.stash.as_ref()
    }

    fn peek_time(&mut self) -> Option<Time> {
        // Stash-free: the earliest time needs no tie-breaking.
        self.lanes
            .iter_mut()
            .filter_map(|l| l.peek_time())
            .min_by(|a, b| a.total_cmp(b))
    }

    fn len(&self) -> usize {
        self.live
    }

    fn scheduled_count(&self) -> u64 {
        self.next_seq
    }

    fn peak_len(&self) -> usize {
        self.peak_len
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{CalendarQueue, EventQueue};

    #[test]
    fn merge_mode_names_round_trip() {
        for mode in [MergeMode::Deterministic, MergeMode::Fast] {
            assert_eq!(MergeMode::from_name(mode.name()).unwrap(), mode);
        }
        assert!(MergeMode::from_name("loose").is_err());
        assert_eq!(MergeMode::default(), MergeMode::Deterministic);
    }

    #[test]
    fn spec_defaults_to_serial_and_normalizes() {
        let spec = ShardSpec::default();
        assert!(spec.is_serial());
        assert_eq!(spec.normalized(4).count, 1);
        let wide = ShardSpec {
            count: 16,
            ..Default::default()
        };
        assert_eq!(wide.normalized(4).count, 4);
        assert_eq!(wide.normalized(0).count, 1);
        assert!(!wide.normalized(8).is_serial());
    }

    #[test]
    fn spec_window_falls_back_to_heartbeat() {
        let mut spec = ShardSpec::default();
        assert_eq!(spec.window(3.0), 3.0);
        spec.window_s = Some(30.0);
        assert_eq!(spec.window(3.0), 30.0);
        spec.window_s = Some(0.0);
        assert_eq!(spec.window(3.0), 3.0);
        spec.window_s = Some(f64::INFINITY);
        assert_eq!(spec.window(3.0), 3.0);
    }

    /// Drive the same operation stream through a plain queue and a
    /// sharded one; the full observable pop stream — times, classes and
    /// sequence numbers — must match exactly, whatever the router.
    fn assert_merged_stream_matches<Q: PendingQueue<(u64, u32)>>(lanes: usize) {
        let mut reference = EventQueue::new();
        let mut sharded: ShardedQueue<u32, Q> =
            ShardedQueue::new(lanes, 0.5, Box::new(|ev: &u32| (*ev as usize) % 7));
        let times = [
            3.0, 1.0, 1.0, 2.5, 1.0, 9.0, 2.5, 2.5, 0.5, 4.0, 1.0, 3.0, 3.0, 0.5, 6.0,
        ];
        for (i, &t) in times.iter().enumerate() {
            let ev = i as u32;
            if i % 4 == 0 {
                reference.push_priority(t, ev);
                sharded.push_priority(t, ev);
            } else {
                reference.push(t, ev);
                sharded.push(t, ev);
            }
        }
        assert_eq!(sharded.len(), times.len());
        assert_eq!(sharded.scheduled_count(), times.len() as u64);
        assert_eq!(sharded.peak_len(), times.len());
        loop {
            // Interleave peeks to exercise the stash cache.
            let (pt, ps) = match sharded.peek() {
                Some(head) => (head.time, head.seq),
                None => break,
            };
            assert_eq!(sharded.peek_time(), Some(pt));
            let want = reference.pop().expect("reference drained early");
            let got = sharded.pop().expect("sharded drained early");
            assert_eq!((got.time, got.class, got.seq), (want.time, want.class, want.seq));
            assert_eq!(got.event, want.event);
            assert_eq!((pt, ps), (want.time, want.seq), "peek matches pop");
        }
        assert!(reference.pop().is_none(), "sharded queue dropped events");
        assert!(sharded.is_empty());
    }

    #[test]
    fn sharded_heap_lanes_reproduce_serial_order() {
        assert_merged_stream_matches::<EventQueue<(u64, u32)>>(1);
        assert_merged_stream_matches::<EventQueue<(u64, u32)>>(3);
        assert_merged_stream_matches::<EventQueue<(u64, u32)>>(7);
    }

    #[test]
    fn sharded_calendar_lanes_reproduce_serial_order() {
        assert_merged_stream_matches::<CalendarQueue<(u64, u32)>>(2);
        assert_merged_stream_matches::<CalendarQueue<(u64, u32)>>(5);
    }

    #[test]
    fn push_invalidates_the_peek_stash() {
        let mut q: ShardedQueue<u32, EventQueue<(u64, u32)>> =
            ShardedQueue::new(2, 1.0, Box::new(|ev: &u32| *ev as usize));
        q.push(5.0, 1);
        assert_eq!(q.peek().unwrap().event, 1);
        // An earlier event on the *other* lane must displace the cached
        // head.
        q.push(1.0, 0);
        assert_eq!(q.peek().unwrap().event, 0);
        assert_eq!(q.pop().unwrap().event, 0);
        assert_eq!(q.pop().unwrap().event, 1);
        assert!(q.pop().is_none());
    }

    #[test]
    fn router_out_of_range_clamps_to_last_lane() {
        let mut q: ShardedQueue<u32, EventQueue<(u64, u32)>> =
            ShardedQueue::new(2, 1.0, Box::new(|_| 99));
        q.push(1.0, 7);
        assert_eq!(q.lane_count(), 2);
        assert_eq!(q.pop().unwrap().event, 7);
    }
}
