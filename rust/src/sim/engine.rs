//! The simulation engine: event loop + virtual clock.
//!
//! Generic over the event type **and** the pending-queue backend
//! ([`PendingQueue`]; the binary-heap [`EventQueue`] by default, the
//! bucketed [`CalendarQueue`](super::calendar::CalendarQueue) in
//! production — both realize the identical delivery order, so every
//! engine feature below behaves bit-identically on either). The cluster
//! driver supplies a handler that may schedule further events through
//! [`Engine::schedule_in`] / [`Engine::schedule_at`]. The engine
//! enforces the monotonic-time invariant and supports a hard
//! event-count limit as a runaway guard.
//!
//! The queue is *demand-driven* by design: it holds only what handlers
//! have scheduled so far, so a streaming session that feeds arrivals one
//! batch at a time (see [`crate::cluster::driver::run_session`]) keeps
//! the pending set proportional to in-flight work — there is no upfront
//! arrival flood, and a million-job open run never materializes its
//! future in the heap. [`Engine::halt`] is the cooperative stop used
//! both for natural completion and for probe-requested early halts.
//!
//! ## Epoch chains & lazy deletion
//!
//! Periodic event chains (per-node heartbeats) cannot be deleted from
//! the queue when they are invalidated (a node crash/recover cycle);
//! instead each chain carries an **epoch** and the engine performs *lazy
//! deletion*: [`Engine::run_filtered`] drops events whose epoch no
//! longer matches the chain's current epoch ([`Engine::bump_chain`]) at
//! pop time, without dispatching them into the handler. Skips are
//! counted ([`Engine::skipped`]) and surfaced as a run diagnostic.

use super::queue::{EventQueue, PendingQueue};
use super::Time;
use std::marker::PhantomData;

/// Why the run loop returned.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// No pending events remain.
    Drained,
    /// The handler requested an early stop.
    Halted,
    /// The event-count guard tripped (indicates a livelock/bug).
    EventLimit,
    /// The run reached a time-window horizon ([`Engine::run_until`]):
    /// every remaining event fires at or after the horizon.
    Horizon,
}

/// Event loop over a [`PendingQueue`] backend (`Q` defaults to the
/// binary-heap [`EventQueue`]; the cluster driver selects the backend
/// at runtime from `SimConfig.queue`).
pub struct Engine<E, Q = EventQueue<E>> {
    queue: Q,
    now: Time,
    processed: u64,
    /// Stale chain events dropped at pop time (lazy deletion).
    skipped: u64,
    event_limit: u64,
    halt: bool,
    /// Current epoch per registered event chain (see module docs).
    chain_epochs: Vec<u32>,
    /// The event type only appears through `Q`'s trait impl.
    _ev: PhantomData<fn(E)>,
}

impl<E> Default for Engine<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> Engine<E> {
    /// An engine over the default binary-heap backend (type-parameter
    /// defaults do not drive expression inference, so this stays on the
    /// concrete default type; use [`Engine::from_queue`] for an
    /// explicit backend).
    pub fn new() -> Self {
        Self::from_queue(EventQueue::new())
    }
}

impl<E, Q: PendingQueue<E>> Engine<E, Q> {
    /// An engine over an explicitly constructed queue backend.
    pub fn from_queue(queue: Q) -> Self {
        Self {
            queue,
            now: 0.0,
            processed: 0,
            skipped: 0,
            // Generous fallback: the FB-dataset macro run is ~1e6 events.
            // Simulation runs configure this through `SimConfig::event_limit`
            // (CLI `--event-limit` / config key `sim.event_limit`); a trip is
            // surfaced as `StopReason::EventLimit` in `SimOutcome::stop`.
            event_limit: 500_000_000,
            halt: false,
            chain_epochs: Vec::new(),
            _ev: PhantomData,
        }
    }

    /// Register `n` epoch chains (e.g. one per cluster node), all
    /// starting at epoch 0.
    pub fn init_chains(&mut self, n: usize) {
        self.chain_epochs = vec![0; n];
    }

    /// Current epoch of a chain.
    pub fn chain_epoch(&self, chain: usize) -> u32 {
        self.chain_epochs[chain]
    }

    /// Invalidate a chain's in-flight events: every queued event stamped
    /// with an older epoch is dropped at pop time. Returns the new epoch
    /// to stamp on the chain's next event.
    pub fn bump_chain(&mut self, chain: usize) -> u32 {
        let e = self.chain_epochs[chain].wrapping_add(1);
        self.chain_epochs[chain] = e;
        e
    }

    /// Override the runaway guard.
    pub fn with_event_limit(mut self, limit: u64) -> Self {
        self.event_limit = limit;
        self
    }

    /// Current simulated time (seconds).
    pub fn now(&self) -> Time {
        self.now
    }

    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Stale chain events dropped at pop time without dispatch.
    pub fn skipped(&self) -> u64 {
        self.skipped
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Total events ever scheduled on this engine (bench diagnostic).
    pub fn pushed(&self) -> u64 {
        self.queue.scheduled_count()
    }

    /// High-water mark of the pending-event set (bench diagnostic).
    pub fn heap_peak(&self) -> usize {
        self.queue.peak_len()
    }

    /// Schedule at an absolute time; must not be in the past.
    pub fn schedule_at(&mut self, time: Time, event: E) {
        assert!(
            time >= self.now,
            "cannot schedule into the past: now={} requested={}",
            self.now,
            time
        );
        self.queue.push(time, event);
    }

    /// Schedule at an absolute time with same-instant priority: the
    /// event is delivered before every ordinary event at that instant,
    /// regardless of when either was scheduled. Sessions use this for
    /// job arrivals, reproducing the batch driver's all-arrivals-first
    /// tie-breaking (see [`EventQueue::push_priority`]).
    ///
    /// [`EventQueue::push_priority`]: super::queue::EventQueue::push_priority
    pub fn schedule_at_priority(&mut self, time: Time, event: E) {
        assert!(
            time >= self.now,
            "cannot schedule into the past: now={} requested={}",
            self.now,
            time
        );
        self.queue.push_priority(time, event);
    }

    /// Schedule after a non-negative delay.
    pub fn schedule_in(&mut self, delay: Time, event: E) {
        assert!(delay >= 0.0, "negative delay {delay}");
        self.queue.push(self.now + delay, event);
    }

    /// Ask the run loop to stop after the current event.
    pub fn halt(&mut self) {
        self.halt = true;
    }

    /// Pop the next event only if it fires at the **current instant** and
    /// matches `pred`, with the same lazy chain deletion and event
    /// accounting as [`Engine::run_filtered`]. Handlers use this to
    /// coalesce a same-instant burst (e.g. several node heartbeats
    /// landing on one tick) into a single dispatch, skipping the outer
    /// loop's per-event overhead. Returns `None` once the head event is
    /// later, different in kind, blocked by a pending halt, or when
    /// popping would trip the event-count guard (the main loop must be
    /// the one to observe the trip).
    pub fn pop_coalesced<C, P>(&mut self, chain_of: C, pred: P) -> Option<E>
    where
        C: Fn(&E) -> Option<(usize, u32)>,
        P: Fn(&E) -> bool,
    {
        loop {
            if self.halt || self.processed >= self.event_limit {
                return None;
            }
            {
                let head = self.queue.peek()?;
                if head.time.total_cmp(&self.now).is_ne() || !pred(&head.event) {
                    return None;
                }
            }
            let ev = self.queue.pop().expect("peeked event vanished");
            if let Some((chain, epoch)) = chain_of(&ev.event) {
                let stale = match self.chain_epochs.get(chain) {
                    Some(&cur) => cur != epoch,
                    None => false,
                };
                if stale {
                    self.skipped += 1;
                    continue;
                }
            }
            self.processed += 1;
            return Some(ev.event);
        }
    }

    /// Run until the queue drains, the handler halts, or the guard trips.
    ///
    /// The handler receives `(engine, time, event)` — it can freely
    /// schedule new events on `engine`.
    pub fn run<F>(&mut self, handler: F) -> StopReason
    where
        F: FnMut(&mut Engine<E, Q>, Time, E),
    {
        self.run_filtered(|_| None, handler)
    }

    /// [`Engine::run`] with lazy deletion of stale chain events.
    ///
    /// `chain_of` classifies an event: `Some((chain, epoch))` for events
    /// that belong to an epoch chain, `None` for everything else. A
    /// chain event whose epoch no longer matches the chain's current
    /// epoch (see [`Engine::bump_chain`]) is dropped at pop time — it
    /// advances the clock but is neither counted as processed nor
    /// dispatched into the handler; it increments [`Engine::skipped`]
    /// instead.
    pub fn run_filtered<C, F>(&mut self, chain_of: C, mut handler: F) -> StopReason
    where
        C: Fn(&E) -> Option<(usize, u32)>,
        F: FnMut(&mut Engine<E, Q>, Time, E),
    {
        loop {
            if self.halt {
                self.halt = false;
                return StopReason::Halted;
            }
            let Some(ev) = self.queue.pop() else {
                return StopReason::Drained;
            };
            debug_assert!(
                ev.time >= self.now,
                "time went backwards: {} -> {}",
                self.now,
                ev.time
            );
            self.now = ev.time;
            if let Some((chain, epoch)) = chain_of(&ev.event) {
                let stale = match self.chain_epochs.get(chain) {
                    Some(&cur) => cur != epoch,
                    None => false,
                };
                if stale {
                    self.skipped += 1;
                    continue;
                }
            }
            self.processed += 1;
            if self.processed > self.event_limit {
                return StopReason::EventLimit;
            }
            handler(self, ev.time, ev.event);
        }
    }

    /// [`Engine::run_filtered`] bounded by a time-window horizon: the
    /// loop returns [`StopReason::Horizon`] as soon as the earliest
    /// pending event fires at or after `horizon`, **without** popping it
    /// or advancing the clock — events at exactly the horizon belong to
    /// the next window. Sharded execution drives each shard's engine in
    /// conservative windows with this entry point, then drains the tail
    /// with a final [`Engine::run_filtered`] call.
    pub fn run_until<C, F>(&mut self, horizon: Time, chain_of: C, mut handler: F) -> StopReason
    where
        C: Fn(&E) -> Option<(usize, u32)>,
        F: FnMut(&mut Engine<E, Q>, Time, E),
    {
        loop {
            if self.halt {
                self.halt = false;
                return StopReason::Halted;
            }
            match self.queue.peek_time() {
                None => return StopReason::Drained,
                Some(t) if t >= horizon => return StopReason::Horizon,
                Some(_) => {}
            }
            let ev = self.queue.pop().expect("peeked event vanished");
            debug_assert!(
                ev.time >= self.now,
                "time went backwards: {} -> {}",
                self.now,
                ev.time
            );
            self.now = ev.time;
            if let Some((chain, epoch)) = chain_of(&ev.event) {
                let stale = match self.chain_epochs.get(chain) {
                    Some(&cur) => cur != epoch,
                    None => false,
                };
                if stale {
                    self.skipped += 1;
                    continue;
                }
            }
            self.processed += 1;
            if self.processed > self.event_limit {
                return StopReason::EventLimit;
            }
            handler(self, ev.time, ev.event);
        }
    }

    /// Advance the clock to `now` without dispatching anything. Sharded
    /// window execution uses this to pin a shard's clock to the window
    /// boundary before injecting the next window's events (so injected
    /// arrivals at the boundary never look like the past).
    pub fn advance_to(&mut self, now: Time) {
        assert!(
            now >= self.now,
            "cannot rewind the clock: now={} requested={}",
            self.now,
            now
        );
        debug_assert!(
            now <= self.queue.peek_time().unwrap_or(f64::INFINITY),
            "advancing past a pending event"
        );
        self.now = now;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, PartialEq)]
    enum Ev {
        Ping(u32),
        Stop,
    }

    #[test]
    fn processes_in_order_and_advances_clock() {
        let mut eng = Engine::new();
        eng.schedule_at(2.0, Ev::Ping(2));
        eng.schedule_at(1.0, Ev::Ping(1));
        let mut seen = Vec::new();
        let reason = eng.run(|e, t, ev| {
            seen.push((t, format!("{ev:?}")));
            if let Ev::Ping(1) = ev {
                e.schedule_in(0.5, Ev::Ping(15));
            }
        });
        assert_eq!(reason, StopReason::Drained);
        assert_eq!(seen.len(), 3);
        assert_eq!(seen[0].0, 1.0);
        assert_eq!(seen[1].0, 1.5);
        assert_eq!(seen[2].0, 2.0);
        assert_eq!(eng.now(), 2.0);
        assert_eq!(eng.processed(), 3);
    }

    #[test]
    fn halt_stops_early() {
        let mut eng = Engine::new();
        eng.schedule_at(1.0, Ev::Stop);
        eng.schedule_at(2.0, Ev::Ping(9));
        let reason = eng.run(|e, _, ev| {
            if let Ev::Stop = ev {
                e.halt();
            }
        });
        assert_eq!(reason, StopReason::Halted);
        assert_eq!(eng.pending(), 1);
    }

    #[test]
    fn event_limit_guard() {
        let mut eng = Engine::new().with_event_limit(10);
        eng.schedule_at(0.0, Ev::Ping(0));
        let reason = eng.run(|e, _, _| {
            // Livelock: every event schedules another at the same time.
            e.schedule_in(0.0, Ev::Ping(0));
        });
        assert_eq!(reason, StopReason::EventLimit);
    }

    #[test]
    #[should_panic(expected = "past")]
    fn cannot_schedule_into_past() {
        let mut eng = Engine::new();
        eng.schedule_at(5.0, Ev::Ping(0));
        eng.run(|e, _, _| {
            e.schedule_at(1.0, Ev::Ping(1));
        });
    }

    #[test]
    fn stale_chain_events_are_lazily_deleted() {
        #[derive(Debug)]
        enum Cev {
            Tick { chain: usize, epoch: u32 },
            Plain,
        }
        let chain_of = |ev: &Cev| match ev {
            Cev::Tick { chain, epoch } => Some((*chain, *epoch)),
            Cev::Plain => None,
        };
        let mut eng: Engine<Cev> = Engine::new();
        eng.init_chains(2);
        eng.schedule_at(1.0, Cev::Tick { chain: 0, epoch: 0 });
        eng.schedule_at(2.0, Cev::Tick { chain: 1, epoch: 0 });
        eng.schedule_at(3.0, Cev::Plain);
        // Invalidate chain 1 before running: its queued event is stale.
        assert_eq!(eng.bump_chain(1), 1);
        assert_eq!(eng.chain_epoch(1), 1);
        let mut seen = Vec::new();
        let reason = eng.run_filtered(chain_of, |_, t, ev| seen.push((t, format!("{ev:?}"))));
        assert_eq!(reason, StopReason::Drained);
        // The stale tick was dropped without dispatch; the clock still
        // advanced past it.
        assert_eq!(seen.len(), 2);
        assert_eq!(seen[0].0, 1.0);
        assert_eq!(seen[1].0, 3.0);
        assert_eq!(eng.skipped(), 1);
        assert_eq!(eng.processed(), 2);
        assert_eq!(eng.now(), 3.0);
    }

    #[test]
    fn unregistered_chains_are_never_stale() {
        // Events pointing at chains the engine does not track (e.g.
        // before init_chains) dispatch normally.
        let mut eng: Engine<u32> = Engine::new();
        eng.schedule_at(1.0, 7);
        let mut n = 0;
        eng.run_filtered(|_| Some((99, 3)), |_, _, _| n += 1);
        assert_eq!(n, 1);
        assert_eq!(eng.skipped(), 0);
    }

    #[test]
    fn pop_coalesced_drains_same_instant_matches_only() {
        let mut eng: Engine<Ev> = Engine::new();
        eng.schedule_at(1.0, Ev::Ping(1));
        eng.schedule_at(1.0, Ev::Ping(2));
        eng.schedule_at(1.0, Ev::Stop);
        eng.schedule_at(2.0, Ev::Ping(3));
        let mut dispatched = Vec::new();
        let mut coalesced = Vec::new();
        eng.run(|e, _, ev| {
            dispatched.push(format!("{ev:?}"));
            if matches!(ev, Ev::Ping(_)) {
                // Drain the same-instant Ping burst; Stop (different
                // kind) and the t=2 Ping (later instant) must stay.
                while let Some(next) =
                    e.pop_coalesced(|_| None, |ev| matches!(ev, Ev::Ping(_)))
                {
                    coalesced.push(format!("{next:?}"));
                }
            }
        });
        assert_eq!(dispatched, vec!["Ping(1)", "Stop", "Ping(3)"]);
        assert_eq!(coalesced, vec!["Ping(2)"]);
        // Coalesced events count as processed exactly like dispatched ones.
        assert_eq!(eng.processed(), 4);
        assert_eq!(eng.pushed(), 4);
        assert!(eng.heap_peak() >= 4);
    }

    #[test]
    fn pop_coalesced_respects_chain_staleness_and_event_limit() {
        #[derive(Debug, PartialEq)]
        enum Cev {
            Tick { chain: usize, epoch: u32 },
        }
        let chain_of = |ev: &Cev| {
            let Cev::Tick { chain, epoch } = ev;
            Some((*chain, *epoch))
        };
        let mut eng: Engine<Cev> = Engine::new();
        eng.init_chains(2);
        eng.schedule_at(1.0, Cev::Tick { chain: 0, epoch: 0 });
        eng.schedule_at(1.0, Cev::Tick { chain: 1, epoch: 0 });
        eng.schedule_at(1.0, Cev::Tick { chain: 0, epoch: 0 });
        eng.bump_chain(1); // the middle event is now stale
        let mut seen = 0;
        let mut coalesced = 0;
        eng.run_filtered(chain_of, |e, _, _| {
            seen += 1;
            while e.pop_coalesced(chain_of, |_| true).is_some() {
                coalesced += 1;
            }
        });
        assert_eq!(seen, 1);
        assert_eq!(coalesced, 1, "stale tick skipped, live tick coalesced");
        assert_eq!(eng.skipped(), 1);
        assert_eq!(eng.processed(), 2);

        // At the event limit, coalescing defers to the main loop so the
        // guard trips identically with or without coalescing.
        let mut lim: Engine<Ev> = Engine::new().with_event_limit(1);
        lim.schedule_at(1.0, Ev::Ping(0));
        lim.schedule_at(1.0, Ev::Ping(1));
        let reason = lim.run(|e, _, _| {
            assert!(e.pop_coalesced(|_| None, |_| true).is_none());
        });
        assert_eq!(reason, StopReason::EventLimit);
    }

    #[test]
    fn engine_is_generic_over_the_calendar_backend() {
        use crate::sim::calendar::CalendarQueue;
        let mut eng: Engine<Ev, CalendarQueue<Ev>> =
            Engine::from_queue(CalendarQueue::with_gap_hint(0.5));
        eng.schedule_at(2.0, Ev::Ping(2));
        eng.schedule_at(1.0, Ev::Ping(1));
        let mut seen = Vec::new();
        let reason = eng.run(|e, t, ev| {
            seen.push(t);
            if let Ev::Ping(1) = ev {
                e.schedule_in(0.5, Ev::Ping(15));
            }
        });
        assert_eq!(reason, StopReason::Drained);
        assert_eq!(seen, vec![1.0, 1.5, 2.0]);
        assert_eq!(eng.processed(), 3);
        assert_eq!(eng.pushed(), 3);
        assert_eq!(eng.heap_peak(), 2);
    }

    #[test]
    fn run_until_stops_at_the_horizon_without_advancing() {
        let mut eng: Engine<Ev> = Engine::new();
        eng.schedule_at(1.0, Ev::Ping(1));
        eng.schedule_at(2.0, Ev::Ping(2));
        eng.schedule_at(3.0, Ev::Ping(3));
        let mut seen = Vec::new();
        // An event at exactly the horizon belongs to the *next* window.
        let reason = eng.run_until(2.0, |_| None, |_, t, _| seen.push(t));
        assert_eq!(reason, StopReason::Horizon);
        assert_eq!(seen, vec![1.0]);
        assert_eq!(eng.now(), 1.0, "clock stays at the last dispatched event");
        assert_eq!(eng.pending(), 2);
        // The boundary pin lets the next window inject at the horizon.
        eng.advance_to(2.0);
        eng.schedule_at_priority(2.0, Ev::Ping(20));
        let reason = eng.run_until(4.0, |_| None, |_, t, _| seen.push(t));
        assert_eq!(reason, StopReason::Horizon);
        assert_eq!(seen, vec![1.0, 2.0, 2.0, 3.0]);
        let reason = eng.run_until(f64::INFINITY, |_| None, |_, t, _| seen.push(t));
        assert_eq!(reason, StopReason::Drained);
        assert_eq!(seen.len(), 4);
    }

    #[test]
    fn run_until_honors_halt_chains_and_the_event_limit() {
        // Halt wins over the horizon check.
        let mut eng: Engine<Ev> = Engine::new();
        eng.schedule_at(1.0, Ev::Stop);
        eng.schedule_at(1.5, Ev::Ping(9));
        let reason = eng.run_until(10.0, |_| None, |e, _, ev| {
            if let Ev::Stop = ev {
                e.halt();
            }
        });
        assert_eq!(reason, StopReason::Halted);
        assert_eq!(eng.pending(), 1);

        // Stale chain events are lazily dropped inside the window.
        #[derive(Debug)]
        enum Cev {
            Tick { chain: usize, epoch: u32 },
        }
        let chain_of = |ev: &Cev| {
            let Cev::Tick { chain, epoch } = ev;
            Some((*chain, *epoch))
        };
        let mut ceng: Engine<Cev> = Engine::new();
        ceng.init_chains(1);
        ceng.schedule_at(1.0, Cev::Tick { chain: 0, epoch: 0 });
        ceng.bump_chain(0);
        ceng.schedule_at(2.0, Cev::Tick { chain: 0, epoch: 1 });
        let mut n = 0;
        let reason = ceng.run_until(5.0, chain_of, |_, _, _| n += 1);
        assert_eq!(reason, StopReason::Drained);
        assert_eq!(n, 1);
        assert_eq!(ceng.skipped(), 1);

        // The runaway guard trips identically under a horizon.
        let mut lim: Engine<Ev> = Engine::new().with_event_limit(5);
        lim.schedule_at(0.0, Ev::Ping(0));
        let reason = lim.run_until(1.0, |_| None, |e, _, _| e.schedule_in(0.0, Ev::Ping(0)));
        assert_eq!(reason, StopReason::EventLimit);
    }

    #[test]
    #[should_panic(expected = "rewind")]
    fn advance_to_rejects_the_past() {
        let mut eng: Engine<Ev> = Engine::new();
        eng.schedule_at(1.0, Ev::Ping(1));
        eng.run(|_, _, _| {});
        eng.advance_to(0.5);
    }

    #[test]
    fn same_time_events_fifo() {
        let mut eng = Engine::new();
        for i in 0..10 {
            eng.schedule_at(1.0, Ev::Ping(i));
        }
        let mut seen = Vec::new();
        eng.run(|_, _, ev| {
            if let Ev::Ping(i) = ev {
                seen.push(i)
            }
        });
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
    }
}
