//! The simulation engine: event loop + virtual clock.
//!
//! Generic over the event type; the cluster driver supplies a handler that
//! may schedule further events through [`Engine::schedule_in`] /
//! [`Engine::schedule_at`]. The engine enforces the monotonic-time
//! invariant and supports a hard event-count limit as a runaway guard.

use super::queue::EventQueue;
use super::Time;

/// Why the run loop returned.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// No pending events remain.
    Drained,
    /// The handler requested an early stop.
    Halted,
    /// The event-count guard tripped (indicates a livelock/bug).
    EventLimit,
}

/// Event loop over an [`EventQueue`].
pub struct Engine<E> {
    queue: EventQueue<E>,
    now: Time,
    processed: u64,
    event_limit: u64,
    halt: bool,
}

impl<E> Default for Engine<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> Engine<E> {
    pub fn new() -> Self {
        Self {
            queue: EventQueue::new(),
            now: 0.0,
            processed: 0,
            // Generous fallback: the FB-dataset macro run is ~1e6 events.
            // Simulation runs configure this through `SimConfig::event_limit`
            // (CLI `--event-limit` / config key `sim.event_limit`); a trip is
            // surfaced as `StopReason::EventLimit` in `SimOutcome::stop`.
            event_limit: 500_000_000,
            halt: false,
        }
    }

    /// Override the runaway guard.
    pub fn with_event_limit(mut self, limit: u64) -> Self {
        self.event_limit = limit;
        self
    }

    /// Current simulated time (seconds).
    pub fn now(&self) -> Time {
        self.now
    }

    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Schedule at an absolute time; must not be in the past.
    pub fn schedule_at(&mut self, time: Time, event: E) {
        assert!(
            time >= self.now,
            "cannot schedule into the past: now={} requested={}",
            self.now,
            time
        );
        self.queue.push(time, event);
    }

    /// Schedule after a non-negative delay.
    pub fn schedule_in(&mut self, delay: Time, event: E) {
        assert!(delay >= 0.0, "negative delay {delay}");
        self.queue.push(self.now + delay, event);
    }

    /// Ask the run loop to stop after the current event.
    pub fn halt(&mut self) {
        self.halt = true;
    }

    /// Run until the queue drains, the handler halts, or the guard trips.
    ///
    /// The handler receives `(engine, time, event)` — it can freely
    /// schedule new events on `engine`.
    pub fn run<F>(&mut self, mut handler: F) -> StopReason
    where
        F: FnMut(&mut Engine<E>, Time, E),
    {
        loop {
            if self.halt {
                self.halt = false;
                return StopReason::Halted;
            }
            let Some(ev) = self.queue.pop() else {
                return StopReason::Drained;
            };
            debug_assert!(
                ev.time >= self.now,
                "time went backwards: {} -> {}",
                self.now,
                ev.time
            );
            self.now = ev.time;
            self.processed += 1;
            if self.processed > self.event_limit {
                return StopReason::EventLimit;
            }
            handler(self, ev.time, ev.event);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, PartialEq)]
    enum Ev {
        Ping(u32),
        Stop,
    }

    #[test]
    fn processes_in_order_and_advances_clock() {
        let mut eng = Engine::new();
        eng.schedule_at(2.0, Ev::Ping(2));
        eng.schedule_at(1.0, Ev::Ping(1));
        let mut seen = Vec::new();
        let reason = eng.run(|e, t, ev| {
            seen.push((t, format!("{ev:?}")));
            if let Ev::Ping(1) = ev {
                e.schedule_in(0.5, Ev::Ping(15));
            }
        });
        assert_eq!(reason, StopReason::Drained);
        assert_eq!(seen.len(), 3);
        assert_eq!(seen[0].0, 1.0);
        assert_eq!(seen[1].0, 1.5);
        assert_eq!(seen[2].0, 2.0);
        assert_eq!(eng.now(), 2.0);
        assert_eq!(eng.processed(), 3);
    }

    #[test]
    fn halt_stops_early() {
        let mut eng = Engine::new();
        eng.schedule_at(1.0, Ev::Stop);
        eng.schedule_at(2.0, Ev::Ping(9));
        let reason = eng.run(|e, _, ev| {
            if let Ev::Stop = ev {
                e.halt();
            }
        });
        assert_eq!(reason, StopReason::Halted);
        assert_eq!(eng.pending(), 1);
    }

    #[test]
    fn event_limit_guard() {
        let mut eng = Engine::new().with_event_limit(10);
        eng.schedule_at(0.0, Ev::Ping(0));
        let reason = eng.run(|e, _, _| {
            // Livelock: every event schedules another at the same time.
            e.schedule_in(0.0, Ev::Ping(0));
        });
        assert_eq!(reason, StopReason::EventLimit);
    }

    #[test]
    #[should_panic(expected = "past")]
    fn cannot_schedule_into_past() {
        let mut eng = Engine::new();
        eng.schedule_at(5.0, Ev::Ping(0));
        eng.run(|e, _, _| {
            e.schedule_at(1.0, Ev::Ping(1));
        });
    }

    #[test]
    fn same_time_events_fifo() {
        let mut eng = Engine::new();
        for i in 0..10 {
            eng.schedule_at(1.0, Ev::Ping(i));
        }
        let mut seen = Vec::new();
        eng.run(|_, _, ev| {
            if let Ev::Ping(i) = ev {
                seen.push(i)
            }
        });
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
    }
}
