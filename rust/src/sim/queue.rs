//! Deterministic pending-event queue.
//!
//! A binary min-heap ordered by `(time, class, seq)` where `seq` is a
//! global insertion counter: events scheduled for the same instant are
//! delivered in the order they were scheduled. This stable tie-break is
//! what makes whole simulation runs bit-reproducible across platforms.
//!
//! The **class** is a two-level priority within an instant:
//! [`EventQueue::push_priority`] events (class 0) are delivered before
//! ordinary [`EventQueue::push`] events (class 1) at the same time,
//! regardless of insertion order. Streaming sessions use it for job
//! arrivals: the historical batch driver scheduled every arrival up
//! front, giving them the lowest sequence numbers in the run, so an
//! arrival always won any same-instant tie — a lazily pulled arrival
//! would otherwise lose ties to events scheduled before it was pulled.
//! The priority class reproduces the batch ordering exactly.

use super::Time;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// An event with its scheduled delivery time.
#[derive(Clone, Debug)]
pub struct ScheduledEvent<E> {
    pub time: Time,
    /// Same-instant priority: 0 before 1 (see module docs).
    pub class: u8,
    pub seq: u64,
    pub event: E,
}

impl<E> PartialEq for ScheduledEvent<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.class == other.class && self.seq == other.seq
    }
}
impl<E> Eq for ScheduledEvent<E> {}

impl<E> PartialOrd for ScheduledEvent<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> Ord for ScheduledEvent<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse: BinaryHeap is a max-heap, we want the earliest event on
        // top. Total order on (time, class, seq); times are finite by
        // invariant.
        other
            .time
            .partial_cmp(&self.time)
            .expect("non-finite event time")
            .then_with(|| other.class.cmp(&self.class))
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Pending-event set.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<ScheduledEvent<E>>,
    next_seq: u64,
    /// High-water mark of the pending set (bench diagnostic: attributes
    /// wall time to event volume vs per-event cost).
    peak_len: usize,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self {
            heap: BinaryHeap::new(),
            next_seq: 0,
            peak_len: 0,
        }
    }

    /// Schedule `event` at absolute time `time`. Panics on NaN/negative
    /// time — both indicate a simulator bug upstream.
    pub fn push(&mut self, time: Time, event: E) -> u64 {
        self.push_class(time, 1, event)
    }

    /// Schedule `event` to be delivered **before** any ordinary event at
    /// the same instant (class 0; see module docs).
    pub fn push_priority(&mut self, time: Time, event: E) -> u64 {
        self.push_class(time, 0, event)
    }

    fn push_class(&mut self, time: Time, class: u8, event: E) -> u64 {
        assert!(
            time.is_finite() && time >= 0.0,
            "event time must be finite and non-negative, got {time}"
        );
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(ScheduledEvent {
            time,
            class,
            seq,
            event,
        });
        self.peak_len = self.peak_len.max(self.heap.len());
        seq
    }

    /// Pop the earliest event.
    pub fn pop(&mut self) -> Option<ScheduledEvent<E>> {
        self.heap.pop()
    }

    /// The earliest pending event, without removing it.
    pub fn peek(&self) -> Option<&ScheduledEvent<E>> {
        self.heap.peek()
    }

    /// Time of the earliest pending event.
    pub fn peek_time(&self) -> Option<Time> {
        self.heap.peek().map(|e| e.time)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Total number of events ever scheduled (diagnostics).
    pub fn scheduled_count(&self) -> u64 {
        self.next_seq
    }

    /// Largest number of simultaneously pending events so far.
    pub fn peak_len(&self) -> usize {
        self.peak_len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|e| e.event)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn fifo_tie_break_at_equal_times() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.push(5.0, i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|e| e.event)).collect();
        assert_eq!(order, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn interleaved_push_pop() {
        let mut q = EventQueue::new();
        q.push(10.0, 'x');
        q.push(1.0, 'y');
        assert_eq!(q.pop().unwrap().event, 'y');
        q.push(5.0, 'z');
        assert_eq!(q.pop().unwrap().event, 'z');
        assert_eq!(q.pop().unwrap().event, 'x');
        assert!(q.pop().is_none());
        assert_eq!(q.scheduled_count(), 3);
        // Peak pending set: both initial pushes were in flight together.
        assert_eq!(q.peak_len(), 2);
    }

    #[test]
    fn peek_time_matches_pop() {
        let mut q = EventQueue::new();
        q.push(2.5, ());
        q.push(1.5, ());
        assert_eq!(q.peek_time(), Some(1.5));
        q.pop();
        assert_eq!(q.peek_time(), Some(2.5));
    }

    #[test]
    fn priority_class_wins_same_instant_ties_regardless_of_insertion_order() {
        let mut q = EventQueue::new();
        q.push(5.0, "normal-early");
        q.push_priority(5.0, "prio-late");
        q.push(5.0, "normal-late");
        q.push_priority(5.0, "prio-later");
        q.push(4.0, "earlier-time");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|e| e.event)).collect();
        assert_eq!(
            order,
            vec![
                "earlier-time",
                "prio-late",
                "prio-later",
                "normal-early",
                "normal-late"
            ]
        );
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn rejects_nan_time() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, ());
    }

    #[test]
    #[should_panic(expected = "non-negative")]
    fn rejects_negative_time() {
        let mut q = EventQueue::new();
        q.push(-1.0, ());
    }
}
