//! Deterministic pending-event queue: the backend contract plus the
//! binary-heap reference implementation.
//!
//! Both backends realize the same total order on `(time, class, seq)`
//! where `seq` is a global insertion counter: events scheduled for the
//! same instant are delivered in the order they were scheduled. This
//! stable tie-break is what makes whole simulation runs bit-reproducible
//! across platforms.
//!
//! The **class** is a two-level priority within an instant:
//! [`EventQueue::push_priority`] events (class 0) are delivered before
//! ordinary [`EventQueue::push`] events (class 1) at the same time,
//! regardless of insertion order. Streaming sessions use it for job
//! arrivals: the historical batch driver scheduled every arrival up
//! front, giving them the lowest sequence numbers in the run, so an
//! arrival always won any same-instant tie — a lazily pulled arrival
//! would otherwise lose ties to events scheduled before it was pulled.
//! The priority class reproduces the batch ordering exactly.
//!
//! ## Backends
//!
//! * [`EventQueue`] (this module) — a `BinaryHeap`; O(log n) per op,
//!   no tuning, the reference the differential testbed pins against
//!   (`tests/queue_differential.rs`).
//! * [`CalendarQueue`](super::calendar::CalendarQueue) — a bucketed
//!   calendar queue tuned to the heartbeat interval; near-O(1) per op
//!   on the heartbeat-dominated streams the simulator produces, and the
//!   default backend.
//!
//! The [`PendingQueue`] trait is **sealed**: the engine's determinism
//! contract (exact `(time, class, seq)` order) cannot be soundly
//! promised by out-of-crate implementations, so only these two backends
//! exist. Select one via `SimConfig.queue` / `--queue {heap,calendar}`.

use super::Time;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Seal for [`PendingQueue`]: backends live in this crate only (the
/// differential testbed is the licence to add one).
pub(crate) mod sealed {
    pub trait Sealed {}
}

/// The pending-event set contract shared by the heap and calendar
/// backends. [`Engine`](super::Engine) is generic over it.
///
/// Implementations must realize the exact total order of
/// [`ScheduledEvent::delivery_cmp`] — `(time, class, seq)` — including
/// the class-0-first same-instant semantics and FIFO `seq` tie-break
/// documented on [`EventQueue`]. `peek` takes `&mut self` because the
/// calendar backend advances its day cursor while locating the minimum.
pub trait PendingQueue<E>: sealed::Sealed + Sized {
    /// Backend label for logs and bench rows (`"heap"` / `"calendar"`).
    const LABEL: &'static str;

    /// Construct a queue tuned to an expected typical inter-event gap in
    /// simulated seconds (the calendar's initial bucket width; the heap
    /// ignores it). Non-finite or non-positive hints fall back to a
    /// safe default.
    fn with_gap_hint(gap_s: f64) -> Self;

    /// Schedule `event` at absolute time `time` (class 1). Panics on
    /// NaN/negative time — both indicate a simulator bug upstream.
    fn push(&mut self, time: Time, event: E) -> u64;

    /// Schedule `event` to be delivered **before** any ordinary event at
    /// the same instant (class 0; see [`EventQueue::push_priority`]).
    fn push_priority(&mut self, time: Time, event: E) -> u64;

    /// Pop the earliest event in delivery order.
    fn pop(&mut self) -> Option<ScheduledEvent<E>>;

    /// The earliest pending event, without removing it.
    fn peek(&mut self) -> Option<&ScheduledEvent<E>>;

    /// Time of the earliest pending event.
    fn peek_time(&mut self) -> Option<Time> {
        self.peek().map(|e| e.time)
    }

    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total number of events ever scheduled (diagnostics).
    fn scheduled_count(&self) -> u64;

    /// Largest number of simultaneously pending events so far.
    fn peak_len(&self) -> usize;
}

/// Which [`PendingQueue`] backend a simulation uses
/// (`SimConfig.queue` / `--queue` / config key `sim.queue`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum QueueKind {
    /// Binary-heap reference backend ([`EventQueue`]).
    Heap,
    /// Bucketed calendar queue, the default
    /// ([`CalendarQueue`](super::calendar::CalendarQueue)).
    #[default]
    Calendar,
}

impl QueueKind {
    pub const ALL: [QueueKind; 2] = [QueueKind::Heap, QueueKind::Calendar];

    pub fn name(self) -> &'static str {
        match self {
            QueueKind::Heap => "heap",
            QueueKind::Calendar => "calendar",
        }
    }

    pub fn from_name(name: &str) -> anyhow::Result<Self> {
        match name {
            "heap" => Ok(QueueKind::Heap),
            "calendar" => Ok(QueueKind::Calendar),
            other => anyhow::bail!("unknown queue backend {other:?} (heap|calendar)"),
        }
    }
}

/// An event with its scheduled delivery time.
#[derive(Clone, Debug)]
pub struct ScheduledEvent<E> {
    pub time: Time,
    /// Same-instant priority: 0 before 1 (see module docs).
    pub class: u8,
    pub seq: u64,
    pub event: E,
}

impl<E> PartialEq for ScheduledEvent<E> {
    fn eq(&self, other: &Self) -> bool {
        // Defined through `delivery_cmp` so equality is exactly
        // "neither orders before the other" (total_cmp semantics,
        // consistent with `Ord`).
        self.delivery_cmp(other) == Ordering::Equal
    }
}
impl<E> Eq for ScheduledEvent<E> {}

impl<E> PartialOrd for ScheduledEvent<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl<E> ScheduledEvent<E> {
    /// Forward **delivery order** on the `(time, class, seq)` key — the
    /// total order every [`PendingQueue`] backend must realize exactly
    /// (the heap's `Ord` is this comparison reversed, for max-heap
    /// storage). Times are finite by the push-time invariant.
    pub fn delivery_cmp(&self, other: &Self) -> Ordering {
        self.time
            .total_cmp(&other.time)
            .then_with(|| self.class.cmp(&other.class))
            .then_with(|| self.seq.cmp(&other.seq))
    }
}

impl<E> Ord for ScheduledEvent<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse: BinaryHeap is a max-heap, we want the earliest event
        // on top.
        other.delivery_cmp(self)
    }
}

/// Pending-event set.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<ScheduledEvent<E>>,
    next_seq: u64,
    /// High-water mark of the pending set (bench diagnostic: attributes
    /// wall time to event volume vs per-event cost).
    peak_len: usize,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self {
            heap: BinaryHeap::new(),
            next_seq: 0,
            peak_len: 0,
        }
    }

    /// Schedule `event` at absolute time `time`. Panics on NaN/negative
    /// time — both indicate a simulator bug upstream.
    pub fn push(&mut self, time: Time, event: E) -> u64 {
        self.push_class(time, 1, event)
    }

    /// Schedule `event` to be delivered **before** any ordinary event at
    /// the same instant (class 0; see module docs).
    pub fn push_priority(&mut self, time: Time, event: E) -> u64 {
        self.push_class(time, 0, event)
    }

    fn push_class(&mut self, time: Time, class: u8, event: E) -> u64 {
        assert!(
            time.is_finite() && time >= 0.0,
            "event time must be finite and non-negative, got {time}"
        );
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(ScheduledEvent {
            time,
            class,
            seq,
            event,
        });
        self.peak_len = self.peak_len.max(self.heap.len());
        seq
    }

    /// Pop the earliest event.
    pub fn pop(&mut self) -> Option<ScheduledEvent<E>> {
        self.heap.pop()
    }

    /// The earliest pending event, without removing it.
    pub fn peek(&self) -> Option<&ScheduledEvent<E>> {
        self.heap.peek()
    }

    /// Time of the earliest pending event.
    pub fn peek_time(&self) -> Option<Time> {
        self.heap.peek().map(|e| e.time)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Total number of events ever scheduled (diagnostics).
    pub fn scheduled_count(&self) -> u64 {
        self.next_seq
    }

    /// Largest number of simultaneously pending events so far.
    pub fn peak_len(&self) -> usize {
        self.peak_len
    }
}

impl<E> sealed::Sealed for EventQueue<E> {}

impl<E> PendingQueue<E> for EventQueue<E> {
    const LABEL: &'static str = "heap";

    fn with_gap_hint(_gap_s: f64) -> Self {
        Self::new()
    }

    fn push(&mut self, time: Time, event: E) -> u64 {
        EventQueue::push(self, time, event)
    }

    fn push_priority(&mut self, time: Time, event: E) -> u64 {
        EventQueue::push_priority(self, time, event)
    }

    fn pop(&mut self) -> Option<ScheduledEvent<E>> {
        EventQueue::pop(self)
    }

    fn peek(&mut self) -> Option<&ScheduledEvent<E>> {
        EventQueue::peek(self)
    }

    fn peek_time(&mut self) -> Option<Time> {
        EventQueue::peek_time(self)
    }

    fn len(&self) -> usize {
        EventQueue::len(self)
    }

    fn is_empty(&self) -> bool {
        EventQueue::is_empty(self)
    }

    fn scheduled_count(&self) -> u64 {
        EventQueue::scheduled_count(self)
    }

    fn peak_len(&self) -> usize {
        EventQueue::peak_len(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|e| e.event)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn fifo_tie_break_at_equal_times() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.push(5.0, i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|e| e.event)).collect();
        assert_eq!(order, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn interleaved_push_pop() {
        let mut q = EventQueue::new();
        q.push(10.0, 'x');
        q.push(1.0, 'y');
        assert_eq!(q.pop().unwrap().event, 'y');
        q.push(5.0, 'z');
        assert_eq!(q.pop().unwrap().event, 'z');
        assert_eq!(q.pop().unwrap().event, 'x');
        assert!(q.pop().is_none());
        assert_eq!(q.scheduled_count(), 3);
        // Peak pending set: both initial pushes were in flight together.
        assert_eq!(q.peak_len(), 2);
    }

    #[test]
    fn peek_time_matches_pop() {
        let mut q = EventQueue::new();
        q.push(2.5, ());
        q.push(1.5, ());
        assert_eq!(q.peek_time(), Some(1.5));
        q.pop();
        assert_eq!(q.peek_time(), Some(2.5));
    }

    #[test]
    fn priority_class_wins_same_instant_ties_regardless_of_insertion_order() {
        let mut q = EventQueue::new();
        q.push(5.0, "normal-early");
        q.push_priority(5.0, "prio-late");
        q.push(5.0, "normal-late");
        q.push_priority(5.0, "prio-later");
        q.push(4.0, "earlier-time");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|e| e.event)).collect();
        assert_eq!(
            order,
            vec![
                "earlier-time",
                "prio-late",
                "prio-later",
                "normal-early",
                "normal-late"
            ]
        );
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn rejects_nan_time() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, ());
    }

    #[test]
    #[should_panic(expected = "non-negative")]
    fn rejects_negative_time() {
        let mut q = EventQueue::new();
        q.push(-1.0, ());
    }

    #[test]
    fn queue_kind_names_round_trip_and_calendar_is_default() {
        assert_eq!(QueueKind::default(), QueueKind::Calendar);
        for kind in QueueKind::ALL {
            assert_eq!(QueueKind::from_name(kind.name()).unwrap(), kind);
        }
        assert!(QueueKind::from_name("splay").is_err());
    }

    #[test]
    fn delivery_cmp_orders_time_then_class_then_seq() {
        let ev = |time, class, seq| ScheduledEvent {
            time,
            class,
            seq,
            event: (),
        };
        use std::cmp::Ordering::*;
        assert_eq!(ev(1.0, 1, 9).delivery_cmp(&ev(2.0, 0, 0)), Less);
        assert_eq!(ev(1.0, 0, 9).delivery_cmp(&ev(1.0, 1, 0)), Less);
        assert_eq!(ev(1.0, 1, 3).delivery_cmp(&ev(1.0, 1, 4)), Less);
        assert_eq!(ev(1.0, 1, 3).delivery_cmp(&ev(1.0, 1, 3)), Equal);
    }

    #[test]
    fn trait_surface_matches_inherent_behaviour() {
        // The PendingQueue impl delegates to the inherent methods; pin
        // that the generic path observes identical accounting.
        fn drive<Q: PendingQueue<u32>>() -> (Vec<(f64, u8, u64, u32)>, usize, u64) {
            let mut q = Q::with_gap_hint(0.5);
            q.push(2.0, 1);
            q.push_priority(2.0, 2);
            q.push(1.0, 3);
            assert_eq!(q.peek_time(), Some(1.0));
            let mut order = Vec::new();
            while let Some(e) = q.pop() {
                order.push((e.time, e.class, e.seq, e.event));
            }
            (order, q.peak_len(), q.scheduled_count())
        }
        let (order, peak, count) = drive::<EventQueue<u32>>();
        assert_eq!(
            order,
            vec![(1.0, 1, 2, 3), (2.0, 0, 1, 2), (2.0, 1, 0, 1)]
        );
        assert_eq!(peak, 3);
        assert_eq!(count, 3);
    }
}
