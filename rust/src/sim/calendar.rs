//! Bucketed calendar queue — the default [`PendingQueue`] backend.
//!
//! A calendar queue (Brown, CACM 1988) hashes events by time into a
//! power-of-two array of *buckets*: with bucket width `w`, an event at
//! time `t` belongs to **day** `⌊t / w⌋`, stored in bucket
//! `day & (n_buckets − 1)`. Popping scans the bucket of the current day
//! and advances day by day; when the width matches the typical
//! inter-event gap (≈ one event per bucket-day), push and pop are O(1)
//! amortized instead of the heap's O(log n) — which is what the
//! heartbeat-dominated event streams of a MapReduce simulation produce:
//! one event roughly every `heartbeat_s / nodes` simulated seconds.
//!
//! ## Ordering contract
//!
//! Delivery order is **exactly** the engine-wide total order
//! `(time, class, seq)` ([`ScheduledEvent::delivery_cmp`]): class-0
//! (priority) events beat class-1 events at the same instant and `seq`
//! breaks the remaining ties FIFO. Equal times always map to the same
//! day and hence the same bucket, and the scan selects the bucket's
//! minimum by the *full* key, so the calendar realizes the same order
//! as the binary-heap reference bit-for-bit — proven by the
//! differential testbed (`tests/queue_differential.rs`), which is the
//! licence for this backend to be the default.
//!
//! ## Mechanics
//!
//! * **Lap scan** — the pop path checks the cursor day's bucket for
//!   slots due *this* day (slots of later laps are skipped), advancing
//!   at most one full lap of the array. An event due on the cursor day
//!   can only live in the cursor bucket, so advancing past an empty day
//!   never skips anything.
//! * **Sparse fallback** — if a whole lap finds nothing due (the next
//!   event is more than `n_buckets` days ahead), a direct scan finds
//!   the global minimum and jumps the cursor to its day, bounding the
//!   pop cost at O(pending) instead of walking empty days.
//! * **Self-resizing** — the array doubles when occupancy exceeds two
//!   events per bucket and halves below one event per two buckets
//!   (within `[16, 65536]`); each rebuild retunes the width to twice
//!   the mean adjacent gap of a sorted sample of pending event times.
//!   The factor-2 hysteresis amortizes the O(pending) rebuild to O(1)
//!   per operation.
//! * **Past pushes rewind** — pushing a time earlier than the cursor
//!   day moves the cursor back (the queue, like the heap, accepts any
//!   non-negative finite time regardless of pop history; the engine's
//!   monotonic-clock assertion lives a layer above).
//!
//! Resize decisions depend only on the queue's own deterministic
//! history, so runs remain bit-reproducible.

use super::queue::{sealed, PendingQueue, ScheduledEvent};
use super::Time;
use std::cmp::Ordering;

/// Bucket-count floor: below this a resize is never attempted (the
/// array is too small for the rebuild to be worth it).
const MIN_BUCKETS: usize = 16;
/// Bucket-count ceiling: beyond this buckets just grow longer (bounds
/// the array's memory at ~512 KiB of `Vec` headers).
const MAX_BUCKETS: usize = 1 << 16;
/// Width floor, guarding division blow-ups on degenerate gap samples.
const MIN_WIDTH: f64 = 1e-9;
/// Resize width tuning samples at most this many pending events.
const WIDTH_SAMPLE: usize = 64;

/// One stored event plus its (width-dependent) day, cached so the scan
/// never re-derives it.
#[derive(Debug)]
struct Slot<E> {
    day: u64,
    ev: ScheduledEvent<E>,
}

/// Pending-event set as a bucketed calendar (see module docs).
#[derive(Debug)]
pub struct CalendarQueue<E> {
    /// `buckets.len()` is always a power of two.
    buckets: Vec<Vec<Slot<E>>>,
    /// `buckets.len() - 1`, as the day→bucket mask.
    mask: u64,
    /// Bucket width in simulated seconds (> 0, finite).
    width: f64,
    /// Cursor: the day currently being drained. Invariant: no pending
    /// slot has `day < self.day`.
    day: u64,
    len: usize,
    next_seq: u64,
    /// High-water mark of the pending set (bench diagnostic).
    peak_len: usize,
}

impl<E> Default for CalendarQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> CalendarQueue<E> {
    /// A calendar with a 1-second initial bucket width; prefer
    /// [`CalendarQueue::with_gap_hint`] when the typical inter-event
    /// gap is known (resizes retune the width either way).
    pub fn new() -> Self {
        Self::with_gap_hint(1.0)
    }

    /// A calendar whose initial bucket width is the expected typical
    /// inter-event gap in simulated seconds. Non-finite or non-positive
    /// hints fall back to 1 s.
    pub fn with_gap_hint(gap_s: f64) -> Self {
        let width = if gap_s.is_finite() && gap_s > 0.0 {
            gap_s.max(MIN_WIDTH)
        } else {
            1.0
        };
        Self {
            buckets: (0..MIN_BUCKETS).map(|_| Vec::new()).collect(),
            mask: MIN_BUCKETS as u64 - 1,
            width,
            day: 0,
            len: 0,
            next_seq: 0,
            peak_len: 0,
        }
    }

    /// Day number of an event time under the current width. The
    /// float→int cast saturates, so astronomical times all land on the
    /// last day — which only coarsens bucketing, never ordering (order
    /// is always decided by the full `(time, class, seq)` key).
    fn day_of(&self, time: Time) -> u64 {
        (time / self.width) as u64
    }

    fn bucket_of(&self, day: u64) -> usize {
        (day & self.mask) as usize
    }

    /// Schedule `event` at absolute time `time` (class 1). Panics on
    /// NaN/negative time — both indicate a simulator bug upstream.
    pub fn push(&mut self, time: Time, event: E) -> u64 {
        self.push_class(time, 1, event)
    }

    /// Schedule `event` to be delivered **before** any ordinary event
    /// at the same instant (class 0; see
    /// [`EventQueue::push_priority`](super::queue::EventQueue::push_priority)).
    pub fn push_priority(&mut self, time: Time, event: E) -> u64 {
        self.push_class(time, 0, event)
    }

    fn push_class(&mut self, time: Time, class: u8, event: E) -> u64 {
        assert!(
            time.is_finite() && time >= 0.0,
            "event time must be finite and non-negative, got {time}"
        );
        let seq = self.next_seq;
        self.next_seq += 1;
        let day = self.day_of(time);
        // Rewind: the cursor must never sit past a pending event's day.
        if day < self.day {
            self.day = day;
        }
        let idx = self.bucket_of(day);
        self.buckets[idx].push(Slot {
            day,
            ev: ScheduledEvent {
                time,
                class,
                seq,
                event,
            },
        });
        self.len += 1;
        self.peak_len = self.peak_len.max(self.len);
        if self.len > 2 * self.buckets.len() && self.buckets.len() < MAX_BUCKETS {
            self.resize();
        }
        seq
    }

    /// Pop the earliest event in delivery order.
    pub fn pop(&mut self) -> Option<ScheduledEvent<E>> {
        let (bi, si) = self.locate_min()?;
        // swap_remove is safe: selection is always by the full key, so
        // in-bucket order carries no information.
        let slot = self.buckets[bi].swap_remove(si);
        self.len -= 1;
        if self.buckets.len() > MIN_BUCKETS && self.len < self.buckets.len() / 2 {
            self.resize();
        }
        Some(slot.ev)
    }

    /// The earliest pending event, without removing it. Takes `&mut`
    /// because locating the minimum advances the day cursor (toward,
    /// never past, the earliest pending day — a later `pop` returns
    /// exactly this event).
    pub fn peek(&mut self) -> Option<&ScheduledEvent<E>> {
        let (bi, si) = self.locate_min()?;
        Some(&self.buckets[bi][si].ev)
    }

    /// Time of the earliest pending event.
    pub fn peek_time(&mut self) -> Option<Time> {
        self.peek().map(|e| e.time)
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Total number of events ever scheduled (diagnostics).
    pub fn scheduled_count(&self) -> u64 {
        self.next_seq
    }

    /// Largest number of simultaneously pending events so far.
    pub fn peak_len(&self) -> usize {
        self.peak_len
    }

    /// Locate the minimum-key pending event, advancing the day cursor
    /// to its day. Returns `(bucket, slot)` indices.
    fn locate_min(&mut self) -> Option<(usize, usize)> {
        if self.len == 0 {
            return None;
        }
        // Lap scan: a slot due on the cursor day can only sit in the
        // cursor bucket, so inspect it and advance day by day, at most
        // one full lap of the array.
        for _ in 0..self.buckets.len() {
            let idx = self.bucket_of(self.day);
            let bucket = &self.buckets[idx];
            let mut best: Option<usize> = None;
            for (i, slot) in bucket.iter().enumerate() {
                debug_assert!(slot.day >= self.day, "pending slot behind the cursor");
                if slot.day > self.day {
                    continue; // a later lap of this bucket
                }
                let better = match best {
                    None => true,
                    Some(b) => slot.ev.delivery_cmp(&bucket[b].ev) == Ordering::Less,
                };
                if better {
                    best = Some(i);
                }
            }
            if let Some(i) = best {
                return Some((idx, i));
            }
            // No slot due this day anywhere (the cursor bucket is the
            // only place one could be): the day is exhausted.
            self.day = self.day.saturating_add(1);
        }
        // Sparse fallback: the next event is more than one lap ahead of
        // the cursor. Find the global minimum directly and jump to its
        // day (the min-key event has the min time, hence the min day).
        let mut best: Option<(usize, usize)> = None;
        for (bi, bucket) in self.buckets.iter().enumerate() {
            for (si, slot) in bucket.iter().enumerate() {
                let better = match best {
                    None => true,
                    Some((bb, bs)) => {
                        slot.ev.delivery_cmp(&self.buckets[bb][bs].ev) == Ordering::Less
                    }
                };
                if better {
                    best = Some((bi, si));
                }
            }
        }
        let (bi, si) = best.expect("non-empty queue has a minimum");
        self.day = self.buckets[bi][si].day;
        Some((bi, si))
    }

    /// Rebuild the bucket array sized to the pending count, retuning
    /// the bucket width from sampled inter-event gaps. O(pending), but
    /// triggered only at factor-2 occupancy thresholds, so the cost
    /// amortizes to O(1) per operation.
    fn resize(&mut self) {
        let target = self
            .len
            .max(1)
            .next_power_of_two()
            .clamp(MIN_BUCKETS, MAX_BUCKETS);
        let mut slots: Vec<Slot<E>> = Vec::with_capacity(self.len);
        for bucket in &mut self.buckets {
            slots.append(bucket);
        }
        self.width = self.tuned_width(&slots);
        if self.buckets.len() != target {
            // Resize in place instead of reallocating the whole array:
            // on shrink the surviving (emptied) bucket `Vec`s keep
            // their capacity, so oscillating occupancy stops paying an
            // allocation churn per factor-2 crossing.
            if self.buckets.len() > target {
                self.buckets.truncate(target);
            } else {
                self.buckets.resize_with(target, Vec::new);
            }
            self.mask = target as u64 - 1;
        }
        // Re-map every slot under the new width and aim the cursor at
        // the earliest pending day (0 when empty; pushes rewind).
        let mut min_day = u64::MAX;
        for mut slot in slots {
            slot.day = self.day_of(slot.ev.time);
            min_day = min_day.min(slot.day);
            let idx = self.bucket_of(slot.day);
            self.buckets[idx].push(slot);
        }
        self.day = if self.len == 0 { 0 } else { min_day };
    }

    /// Width ≈ twice the mean adjacent gap of a sorted sample of
    /// pending event times (≈ one event per bucket-day with headroom
    /// for jitter). Keeps the current width when the sample has no two
    /// distinct times — there is nothing to learn from it.
    fn tuned_width(&self, slots: &[Slot<E>]) -> f64 {
        let mut times: Vec<f64> = slots.iter().take(WIDTH_SAMPLE).map(|s| s.ev.time).collect();
        times.sort_by(|a, b| a.total_cmp(b));
        let mut sum = 0.0;
        let mut n = 0u32;
        for w in times.windows(2) {
            let gap = w[1] - w[0];
            if gap > 0.0 {
                sum += gap;
                n += 1;
            }
        }
        if n == 0 {
            self.width
        } else {
            (2.0 * sum / f64::from(n)).max(MIN_WIDTH)
        }
    }
}

impl<E> sealed::Sealed for CalendarQueue<E> {}

impl<E> PendingQueue<E> for CalendarQueue<E> {
    const LABEL: &'static str = "calendar";

    fn with_gap_hint(gap_s: f64) -> Self {
        CalendarQueue::with_gap_hint(gap_s)
    }

    fn push(&mut self, time: Time, event: E) -> u64 {
        CalendarQueue::push(self, time, event)
    }

    fn push_priority(&mut self, time: Time, event: E) -> u64 {
        CalendarQueue::push_priority(self, time, event)
    }

    fn pop(&mut self) -> Option<ScheduledEvent<E>> {
        CalendarQueue::pop(self)
    }

    fn peek(&mut self) -> Option<&ScheduledEvent<E>> {
        CalendarQueue::peek(self)
    }

    fn peek_time(&mut self) -> Option<Time> {
        CalendarQueue::peek_time(self)
    }

    fn len(&self) -> usize {
        CalendarQueue::len(self)
    }

    fn is_empty(&self) -> bool {
        CalendarQueue::is_empty(self)
    }

    fn scheduled_count(&self) -> u64 {
        CalendarQueue::scheduled_count(self)
    }

    fn peak_len(&self) -> usize {
        CalendarQueue::peak_len(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = CalendarQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|e| e.event)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn fifo_tie_break_at_equal_times() {
        let mut q = CalendarQueue::new();
        for i in 0..100 {
            q.push(5.0, i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|e| e.event)).collect();
        assert_eq!(order, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn priority_class_wins_same_instant_ties_regardless_of_insertion_order() {
        let mut q = CalendarQueue::new();
        q.push(5.0, "normal-early");
        q.push_priority(5.0, "prio-late");
        q.push(5.0, "normal-late");
        q.push_priority(5.0, "prio-later");
        q.push(4.0, "earlier-time");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|e| e.event)).collect();
        assert_eq!(
            order,
            vec![
                "earlier-time",
                "prio-late",
                "prio-later",
                "normal-early",
                "normal-late"
            ]
        );
    }

    #[test]
    fn interleaved_push_pop_and_stats() {
        let mut q = CalendarQueue::new();
        q.push(10.0, 'x');
        q.push(1.0, 'y');
        assert_eq!(q.pop().unwrap().event, 'y');
        q.push(5.0, 'z');
        assert_eq!(q.pop().unwrap().event, 'z');
        assert_eq!(q.pop().unwrap().event, 'x');
        assert!(q.pop().is_none());
        assert_eq!(q.scheduled_count(), 3);
        assert_eq!(q.peak_len(), 2);
        assert!(q.is_empty());
    }

    #[test]
    fn peek_time_matches_pop_and_is_stable() {
        let mut q = CalendarQueue::new();
        q.push(2.5, ());
        q.push(1.5, ());
        assert_eq!(q.peek_time(), Some(1.5));
        assert_eq!(q.peek_time(), Some(1.5), "peek must not consume");
        assert_eq!(q.pop().unwrap().time, 1.5);
        assert_eq!(q.peek_time(), Some(2.5));
    }

    #[test]
    fn past_push_rewinds_the_cursor() {
        // Standalone queues accept any non-negative time regardless of
        // pop history (the heap does too); popping a far-future event
        // advances the cursor, and a subsequent earlier push must still
        // come out first.
        let mut q = CalendarQueue::with_gap_hint(0.5);
        q.push(100.0, "far");
        assert_eq!(q.pop().unwrap().event, "far");
        q.push(1.0, "early");
        q.push(50.0, "mid");
        assert_eq!(q.pop().unwrap().event, "early");
        assert_eq!(q.pop().unwrap().event, "mid");
    }

    #[test]
    fn grows_and_shrinks_with_occupancy() {
        let mut q = CalendarQueue::with_gap_hint(1.0);
        // Deterministic scattered times with collisions.
        for i in 0..4096u32 {
            q.push(f64::from((i * 37) % 501), i);
        }
        assert!(
            q.buckets.len() > MIN_BUCKETS,
            "4096 pending events must have grown the array, got {}",
            q.buckets.len()
        );
        let mut last = (-1.0, 0u8, 0u64);
        let mut popped = 0;
        while let Some(e) = q.pop() {
            let key = (e.time, e.class, e.seq);
            assert!(last < key, "pop order regressed: {last:?} -> {key:?}");
            last = key;
            popped += 1;
        }
        assert_eq!(popped, 4096);
        assert_eq!(
            q.buckets.len(),
            MIN_BUCKETS,
            "draining must shrink the array back"
        );
        assert_eq!(q.peak_len(), 4096);
    }

    #[test]
    fn sparse_fallback_jumps_empty_laps() {
        // With a tiny width, consecutive events sit millions of days
        // apart: every pop exercises the direct-scan fallback.
        let mut q = CalendarQueue::with_gap_hint(1e-6);
        q.push(900.0, "c");
        q.push(0.5, "a");
        q.push(40_000.0, "d");
        q.push(7.0, "b");
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|e| e.event)).collect();
        assert_eq!(order, vec!["a", "b", "c", "d"]);
    }

    #[test]
    fn degenerate_width_hints_fall_back() {
        for hint in [0.0, -3.0, f64::NAN, f64::INFINITY] {
            let mut q = CalendarQueue::with_gap_hint(hint);
            assert!(q.width.is_finite() && q.width > 0.0);
            q.push(2.0, "b");
            q.push(1.0, "a");
            assert_eq!(q.pop().unwrap().event, "a");
            assert_eq!(q.pop().unwrap().event, "b");
        }
    }

    #[test]
    #[should_panic(expected = "finite")]
    fn rejects_nan_time() {
        let mut q = CalendarQueue::new();
        q.push(f64::NAN, ());
    }

    #[test]
    #[should_panic(expected = "non-negative")]
    fn rejects_negative_time() {
        let mut q = CalendarQueue::new();
        q.push(-1.0, ());
    }
}
