//! HFSP — the Hadoop Fair Sojourn Protocol (§3 of the paper).
//!
//! A hierarchical, size-based preemptive scheduler:
//!
//! * the **top-level scheduler** (this module's [`HfspScheduler`]) splits
//!   cluster resources between the [`training`] module (job size
//!   estimation) and the job scheduler (§3.1.1);
//! * the **job scheduler** orders jobs by their projected finish time in
//!   the [`virtual_cluster`] (a max-min-fair PS fluid simulation — that
//!   ordering *is* the Fair Sojourn Protocol) and focuses real slots on
//!   the earliest-finishing job;
//! * **preemption** takes running slots from jobs that project to finish
//!   later and gives them to jobs that project to finish earlier, using
//!   SUSPEND/RESUME (or WAIT/KILL, [`preemption`]), with resume pinned to
//!   the node holding the suspended context (§3.3);
//! * MAP placement uses **delay scheduling** for data locality (§3.1).
//!
//! The MAP and REDUCE phases are scheduled independently (separate
//! virtual clusters over the separate slot pools), per §3.1.

pub mod estimator;
pub mod preemption;
pub mod training;
pub mod virtual_cluster;
pub mod xla_estimator;

pub use preemption::{PreemptionPrimitive, SuspensionGuard};

use self::estimator::{MeanEstimator, NativeEstimator, SizeEstimator};
use self::training::{TrainingModule, TrainingUpdate};
use crate::faults::ErrorModel;
use self::virtual_cluster::{MaxMinBackend, NativeMaxMin, VirtualCluster};
use super::delay::{pick_reduce, DelayTimer, LocalityIndex};
use super::{Action, SchedView, Scheduler};
use crate::job::task::NodeId;
use crate::job::{Job, JobId, Phase, TaskRef};
use std::collections::{HashMap, HashSet};
use std::path::PathBuf;

/// Which size-estimator implementation the Training module uses.
#[derive(Clone, Debug, Default)]
pub enum EstimatorKind {
    /// Pure-rust least-squares quantile estimator (reference).
    #[default]
    Native,
    /// First-order statistics only (ablation baseline).
    Mean,
    /// The AOT-compiled JAX/Pallas estimator, executed via PJRT.
    /// Panics at construction if the artifact is missing — run
    /// `make artifacts` first.
    Xla { artifact_dir: PathBuf },
}

/// Which max-min backend the virtual cluster uses.
#[derive(Clone, Debug, Default)]
pub enum MaxMinKind {
    #[default]
    Native,
    /// AOT-compiled water-filling kernel via PJRT.
    Xla { artifact_dir: PathBuf },
}

/// HFSP configuration (defaults = the paper's experimental setup, §4.1).
#[derive(Clone, Debug)]
pub struct HfspConfig {
    /// Sample-set size for MAP and REDUCE estimation (paper: 5).
    pub sample_set: usize,
    /// Confidence parameter ξ ∈ [1, ∞) weighting initial estimates
    /// (paper: 1).
    pub xi: f64,
    /// Delay-scheduling locality timeout, seconds.
    pub locality_timeout_s: f64,
    /// Preemption primitive (paper default: eager suspension).
    pub preemption: PreemptionPrimitive,
    /// Cluster-wide suspended-task hysteresis thresholds (§3.3 "Finite
    /// machine resources").
    pub suspend_hi: usize,
    pub suspend_lo: usize,
    /// Cap on slots the top-level scheduler grants the Training module
    /// (paper: all slots).
    pub max_training_slots: usize,
    /// Minimum projected-finish-time gap (seconds) between the preempting
    /// job and its victim before preemption fires. Guards against
    /// mutual-preemption thrash when two jobs' size estimates are nearly
    /// equal (their PS finish order flips on every estimate update).
    pub preempt_threshold_s: f64,
    /// Fig. 6 artificial estimation error α (0 disables).
    pub error_alpha: f64,
    /// Log-normal (median-1) estimation-error σ from the fault
    /// subsystem's robustness model (0 disables; takes precedence over
    /// `error_alpha` when both are set).
    pub error_sigma: f64,
    pub error_seed: u64,
    pub estimator: EstimatorKind,
    pub maxmin: MaxMinKind,
}

impl Default for HfspConfig {
    fn default() -> Self {
        Self {
            sample_set: 5,
            xi: 1.0,
            locality_timeout_s: 5.0,
            preemption: PreemptionPrimitive::Suspend,
            suspend_hi: 600,
            suspend_lo: 300,
            max_training_slots: usize::MAX,
            preempt_threshold_s: 20.0,
            error_alpha: 0.0,
            error_sigma: 0.0,
            error_seed: 0,
            estimator: EstimatorKind::Native,
            maxmin: MaxMinKind::Native,
        }
    }
}

impl HfspConfig {
    fn build_estimator(&self) -> Box<dyn SizeEstimator> {
        match &self.estimator {
            EstimatorKind::Native => Box::new(NativeEstimator::new()),
            EstimatorKind::Mean => Box::new(MeanEstimator),
            EstimatorKind::Xla { artifact_dir } => Box::new(
                xla_estimator::XlaSizeEstimator::load(artifact_dir)
                    .expect("loading XLA estimator artifact (run `make artifacts`)"),
            ),
        }
    }

    fn build_maxmin(&self) -> Box<dyn MaxMinBackend> {
        match &self.maxmin {
            MaxMinKind::Native => Box::new(NativeMaxMin),
            MaxMinKind::Xla { artifact_dir } => Box::new(
                xla_estimator::XlaMaxMin::load(artifact_dir)
                    .expect("loading XLA maxmin artifact (run `make artifacts`)"),
            ),
        }
    }
}

/// Cached FSP priority view derived from a virtual cluster projection,
/// keyed by the VC's generation counter (recomputing rank/finish maps on
/// every heartbeat dominated the hot path — §Perf iteration 2).
#[derive(Default)]
struct OrderCache {
    generation: u64,
    valid: bool,
    order: Vec<JobId>,
    rank: HashMap<JobId, usize>,
    finish: HashMap<JobId, f64>,
}

impl OrderCache {
    fn refresh(&mut self, vc: &mut VirtualCluster) {
        if self.valid && self.generation == vc.generation() {
            return;
        }
        let projected = vc.projected_finish_order();
        self.order.clear();
        self.rank.clear();
        self.finish.clear();
        for (r, &(id, t)) in projected.iter().enumerate() {
            self.order.push(id);
            self.rank.insert(id, r);
            self.finish.insert(id, t);
        }
        self.generation = vc.generation();
        self.valid = true;
    }
}

/// The HFSP scheduler.
pub struct HfspScheduler {
    cfg: HfspConfig,
    vc_map: VirtualCluster,
    vc_reduce: VirtualCluster,
    training: TrainingModule,
    index: LocalityIndex,
    delay: DelayTimer,
    guard: SuspensionGuard,
    /// Jobs whose reduce phase has been registered in `vc_reduce`.
    reduce_started: HashSet<JobId>,
    order_map: OrderCache,
    order_reduce: OrderCache,
    /// Lazily sized from the first view (cluster capacity per phase).
    sized: bool,
}

impl HfspScheduler {
    pub fn new(cfg: HfspConfig) -> Self {
        let error = if cfg.error_sigma > 0.0 {
            Some(ErrorModel::log_normal(cfg.error_sigma, cfg.error_seed))
        } else if cfg.error_alpha > 0.0 {
            Some(ErrorModel::uniform(cfg.error_alpha, cfg.error_seed))
        } else {
            None
        };
        let training =
            TrainingModule::new(cfg.sample_set, cfg.xi, cfg.build_estimator(), error);
        let guard = SuspensionGuard::new(cfg.suspend_hi, cfg.suspend_lo);
        let delay = DelayTimer::new(cfg.locality_timeout_s);
        // Placeholder capacities; resized on first view.
        let vc_map = VirtualCluster::with_backend(1, cfg.build_maxmin());
        let vc_reduce = VirtualCluster::with_backend(1, cfg.build_maxmin());
        Self {
            cfg,
            vc_map,
            vc_reduce,
            training,
            index: LocalityIndex::new(),
            delay,
            guard,
            reduce_started: HashSet::new(),
            order_map: OrderCache::default(),
            order_reduce: OrderCache::default(),
            sized: false,
        }
    }

    fn ensure_sized(&mut self, view: &SchedView) {
        if !self.sized {
            let map_slots = view.cluster.total_slots(Phase::Map).max(1);
            let red_slots = view.cluster.total_slots(Phase::Reduce).max(1);
            self.vc_map = VirtualCluster::with_backend(map_slots, self.cfg.build_maxmin());
            self.vc_reduce = VirtualCluster::with_backend(red_slots, self.cfg.build_maxmin());
            self.sized = true;
        }
    }

    fn vc(&mut self, phase: Phase) -> &mut VirtualCluster {
        match phase {
            Phase::Map => &mut self.vc_map,
            Phase::Reduce => &mut self.vc_reduce,
        }
    }

    /// Register a job's reduce phase in the reduce virtual cluster (at
    /// arrival for map-less jobs, else when the map phase completes).
    fn start_reduce_phase(&mut self, view: &SchedView, id: JobId) {
        if !self.reduce_started.insert(id) {
            return;
        }
        let n = view.jobs[&id].spec.n_reduces();
        if n == 0 {
            return;
        }
        let initial = self.training.start_phase(id, Phase::Reduce, n);
        self.vc_reduce.add_job(id, initial, n, view.now);
    }

    /// Pick a map task for `job` on `node` under delay scheduling.
    fn pick_map(
        &mut self,
        view: &SchedView,
        job: &Job,
        node: NodeId,
        picked: &HashSet<TaskRef>,
    ) -> Option<(TaskRef, bool)> {
        if let Some(t) = self.index.pick_local(job, node, picked) {
            self.delay.clear(job.id());
            return Some((t, true));
        }
        if job.pending_tasks(Phase::Map) == 0 {
            return None;
        }
        if self.delay.skip_and_check(job.id(), view.now) {
            if let Some(t) = self.index.pick_any(job, picked) {
                self.delay.clear(job.id());
                return Some((t, false));
            }
        }
        None
    }

    /// Pick any schedulable task of `job`/`phase` for `node`.
    fn pick_task(
        &mut self,
        view: &SchedView,
        job: &Job,
        phase: Phase,
        node: NodeId,
        picked: &HashSet<TaskRef>,
    ) -> Option<(TaskRef, bool)> {
        match phase {
            Phase::Map => self.pick_map(view, job, node, picked),
            Phase::Reduce => pick_reduce(job, picked).map(|t| (t, true)),
        }
    }

    /// A suspended task of `job` parked on `node` not yet resumed in this
    /// batch.
    fn suspended_here(
        view: &SchedView,
        job: JobId,
        phase: Phase,
        node: NodeId,
        resumed: &HashSet<TaskRef>,
    ) -> Option<TaskRef> {
        view.cluster
            .node(node)
            .suspended_tasks()
            .find(|t| t.job == job && t.phase == phase && !resumed.contains(t))
    }

    /// Assignment + preemption for one phase on one heartbeat.
    fn assign_phase(
        &mut self,
        view: &SchedView,
        node: NodeId,
        phase: Phase,
        actions: &mut Vec<Action>,
        ctx_budget: &mut usize,
    ) {
        // FSP priority order: projected PS finish times, ascending
        // (cached across heartbeats until the projection changes); taken
        // out of `self` for the duration of the call so the borrow
        // checker allows `&mut self` pickers (§Perf iteration 3: cloning
        // the rank/finish maps per heartbeat was measurable).
        match phase {
            Phase::Map => self.order_map.refresh(&mut self.vc_map),
            Phase::Reduce => self.order_reduce.refresh(&mut self.vc_reduce),
        }
        let cache = match phase {
            Phase::Map => std::mem::take(&mut self.order_map),
            Phase::Reduce => std::mem::take(&mut self.order_reduce),
        };
        self.assign_phase_inner(view, node, phase, actions, ctx_budget, &cache);
        match phase {
            Phase::Map => self.order_map = cache,
            Phase::Reduce => self.order_reduce = cache,
        }
    }

    #[allow(clippy::too_many_lines)]
    fn assign_phase_inner(
        &mut self,
        view: &SchedView,
        node: NodeId,
        phase: Phase,
        actions: &mut Vec<Action>,
        ctx_budget: &mut usize,
        cache: &OrderCache,
    ) {
        let mut free = view.cluster.node(node).free_slots(phase);
        let mut picked: HashSet<TaskRef> = HashSet::new();
        let mut resumed: HashSet<TaskRef> = HashSet::new();
        let order = &cache.order;
        let rank = &cache.rank;
        let finish = &cache.finish;
        if node == 0 && phase == Phase::Map && log::log_enabled!(log::Level::Trace) {
            let head: Vec<String> = order
                .iter()
                .take(4)
                .map(|id| {
                    let j = &view.jobs[id];
                    format!(
                        "j{id}(fin={:.0},rem_vc={:.0},pend={},run={})",
                        finish.get(id).copied().unwrap_or(-1.0),
                        self.vc_map.remaining(*id).unwrap_or(-1.0),
                        j.pending_tasks(Phase::Map),
                        j.running_tasks(Phase::Map)
                    )
                })
                .collect();
            log::trace!("t={:.0} map order: {}", view.now, head.join(" "));
        }

        // -- Stage 0: training-priority assignments (§3.1.1) ------------
        // Jobs still collecting samples get their sample set scheduled
        // with priority, ordered by fewer remaining tasks, subject to the
        // global training-slot cap.
        let mut training_jobs: Vec<&Job> = view
            .active_jobs()
            .filter(|j| {
                self.training.is_training(j.id(), phase)
                    && (phase == Phase::Map || j.map_phase_done())
                    && j.pending_tasks(phase) > 0
            })
            .collect();
        training_jobs.sort_by_key(|j| (j.remaining_tasks(phase), j.id()));
        let mut training_running: usize = view
            .active_jobs()
            .filter(|j| self.training.is_training(j.id(), phase))
            .map(|j| j.running_tasks(phase))
            .sum();
        for job in training_jobs {
            if free == 0 || training_running >= self.cfg.max_training_slots {
                break;
            }
            let mut want = self.training.wanted_training_slots(
                job.id(),
                phase,
                job.running_tasks(phase),
            );
            while want > 0
                && free > 0
                && *ctx_budget > 0
                && training_running < self.cfg.max_training_slots
            {
                let Some((task, local)) = self.pick_task(view, job, phase, node, &picked)
                else {
                    break;
                };
                picked.insert(task);
                actions.push(Action::Launch { task, node, local });
                free -= 1;
                want -= 1;
                *ctx_budget -= 1;
                training_running += 1;
            }
        }

        // -- Stage 1: fill free slots in FSP order ------------------------
        for &id in order {
            if free == 0 {
                break;
            }
            let job = &view.jobs[&id];
            if phase == Phase::Reduce && !job.map_phase_done() {
                continue;
            }
            // Resume-first: suspended tasks parked on this node (§3.3
            // "Impact on data locality": resume on the same machine).
            while free > 0 {
                let Some(t) = Self::suspended_here(view, id, phase, node, &resumed) else {
                    break;
                };
                resumed.insert(t);
                actions.push(Action::Resume { task: t });
                free -= 1;
            }
            // Then pending launches.
            while free > 0 && *ctx_budget > 0 {
                let Some((task, local)) = self.pick_task(view, job, phase, node, &picked)
                else {
                    break;
                };
                picked.insert(task);
                actions.push(Action::Launch { task, node, local });
                free -= 1;
                *ctx_budget -= 1;
            }
        }

        // -- Stage 2: preemption (§3.3) -----------------------------------
        if self.cfg.preemption == PreemptionPrimitive::Wait {
            return;
        }
        // Preemption is a last resort: the paper suspends running tasks so
        // that an earlier-finishing job "obtains resources" (§3.3). Count
        // the cluster-wide free slots once: a job whose unmet demand fits
        // in them will be served by those nodes' next heartbeats without
        // taking busy slots.
        let cluster_free = view.cluster.free_slots(phase);
        // Victims: running tasks on this node, worst priority first ("the
        // scheduler selects for suspension the tasks of jobs sorted in
        // decreasing order of their size").
        let mut victims: Vec<TaskRef> = view
            .cluster
            .node(node)
            .running(phase)
            .to_vec();
        victims.sort_by_key(|t| std::cmp::Reverse(rank.get(&t.job).copied().unwrap_or(0)));
        let mut victim_iter = victims.into_iter().peekable();
        let mut suspended_total = view.cluster.suspended_count();

        for &id in order {
            let job = &view.jobs[&id];
            if phase == Phase::Reduce && !job.map_phase_done() {
                continue;
            }
            let my_rank = rank[&id];
            let my_finish = finish.get(&id).copied().unwrap_or(0.0);
            // Pending tasks can be absorbed by free slots anywhere in the
            // cluster; contexts suspended on THIS node can only resume
            // here, so they always justify preemption.
            let suspended_here_cnt = view
                .cluster
                .node(node)
                .suspended_tasks()
                .filter(|t| t.job == id && t.phase == phase)
                .count();
            let pending_unmet = job.pending_tasks(phase) > cluster_free;
            if suspended_here_cnt == 0 && !pending_unmet {
                continue; // free slots elsewhere will serve this job
            }
            loop {
                // Is there a victim strictly lower-priority than us, with a
                // projected finish far enough after ours to justify the
                // preemption (thrash guard)?
                let Some(&victim) = victim_iter.peek() else {
                    return;
                };
                let victim_rank = rank.get(&victim.job).copied().unwrap_or(usize::MAX);
                if victim_rank <= my_rank {
                    break; // no victim is worse than this job; next job
                }
                let victim_finish = finish
                    .get(&victim.job)
                    .copied()
                    .unwrap_or(f64::INFINITY);
                if victim_finish - my_finish < self.cfg.preempt_threshold_s {
                    break; // near-tie: let the victim run (avoid flapping)
                }
                // Check primitive availability BEFORE picking a placement:
                // `pick_task` consumes locality-index entries, so it must
                // only run when the launch will actually be emitted.
                let resume_cand = Self::suspended_here(view, id, phase, node, &resumed);
                if resume_cand.is_none() && !pending_unmet {
                    break; // remaining pending demand fits in free slots
                }
                let preempt_action = match self.cfg.preemption {
                    PreemptionPrimitive::Kill => Some(Action::Kill { task: victim }),
                    PreemptionPrimitive::Suspend => {
                        // A resume-backfill is context-neutral; a
                        // launch-backfill needs context budget.
                        let have_ctx = resume_cand.is_some() || *ctx_budget >= 1;
                        if have_ctx && self.guard.allow_suspend(suspended_total) {
                            Some(Action::Suspend { task: victim })
                        } else {
                            None // out of context memory: WAIT instead
                        }
                    }
                    PreemptionPrimitive::Wait => unreachable!(),
                };
                let Some(preempt_action) = preempt_action else {
                    return; // suspension pressure: stop preempting entirely
                };
                let placement: Option<Action> = match resume_cand {
                    Some(t) => Some(Action::Resume { task: t }),
                    None => self
                        .pick_task(view, job, phase, node, &picked)
                        .map(|(task, local)| Action::Launch { task, node, local }),
                };
                let Some(placement) = placement else {
                    break; // nothing to place; next job
                };
                let _ = victim_iter.next();
                if matches!(preempt_action, Action::Suspend { .. }) {
                    suspended_total += 1;
                }
                actions.push(preempt_action);
                match placement {
                    Action::Resume { task } => {
                        resumed.insert(task);
                    }
                    Action::Launch { task, .. } => {
                        picked.insert(task);
                        *ctx_budget = ctx_budget.saturating_sub(1);
                    }
                    _ => {}
                }
                actions.push(placement);
            }
        }
    }
}

impl Scheduler for HfspScheduler {
    fn name(&self) -> &'static str {
        "HFSP"
    }

    fn on_job_arrival(&mut self, view: &SchedView, id: JobId) {
        self.ensure_sized(view);
        let job = &view.jobs[&id];
        self.index.add_job(job, view.hdfs);
        let n_maps = job.spec.n_maps();
        if n_maps > 0 {
            let initial = self.training.start_phase(id, Phase::Map, n_maps);
            self.vc_map.add_job(id, initial, n_maps, view.now);
        } else {
            // Map-less job: the reduce phase is immediately eligible.
            self.start_reduce_phase(view, id);
        }
    }

    fn on_task_completed(&mut self, view: &SchedView, task: TaskRef, observed: f64) {
        let id = task.job;
        let job = &view.jobs[&id];
        let phase = task.phase;
        let tasks_done = match phase {
            Phase::Map => job.maps_done,
            Phase::Reduce => job.reduces_done,
        };
        // Feed the estimator.
        match self
            .training
            .observe_completion(id, phase, observed, tasks_done)
        {
            TrainingUpdate::Estimated { total } => {
                self.vc(phase).set_total(id, total, view.now);
            }
            TrainingUpdate::Pending | TrainingUpdate::NotTraining => {}
        }
        // Real phase completion retires the job from the PS reference;
        // virtual progress in between is the reference's own business
        // (the PS world is deliberately decoupled from real progress).
        if job.remaining_tasks(phase) == 0 {
            let now = view.now;
            self.vc(phase).remove_job(id, now);
        }
        // Map phase completion opens the reduce phase (§2.2: reducers are
        // scheduled once intermediate data is available).
        if phase == Phase::Map && job.map_phase_done() {
            self.start_reduce_phase(view, id);
        }
    }

    fn on_reduce_progress(&mut self, view: &SchedView, task: TaskRef, delta: f64, progress: f64) {
        if progress <= 0.0 {
            return;
        }
        if let TrainingUpdate::Estimated { total } =
            self.training.observe_progress(task.job, delta, progress)
        {
            self.vc_reduce.set_total(task.job, total, view.now);
        }
    }

    fn on_job_finished(&mut self, view: &SchedView, id: JobId) {
        self.vc_map.remove_job(id, view.now);
        self.vc_reduce.remove_job(id, view.now);
        self.training.remove_job(id);
        self.index.remove_job(id);
        self.delay.remove_job(id);
        self.reduce_started.remove(&id);
    }

    fn on_heartbeat(&mut self, view: &SchedView, node: NodeId) -> Vec<Action> {
        self.ensure_sized(view);
        // Job aging: advance the PS reference simulation to now (§3.1).
        self.vc_map.age_to(view.now);
        self.vc_reduce.age_to(view.now);
        let mut actions = Vec::new();
        // Context-memory budget shared by both phases: every launch adds a
        // JVM context on the node; suspensions park one. The budget keeps
        // a heartbeat batch within RAM + swap capacity (§3.3).
        let mut ctx_budget = view.cluster.node(node).context_headroom();
        self.assign_phase(view, node, Phase::Map, &mut actions, &mut ctx_budget);
        self.assign_phase(view, node, Phase::Reduce, &mut actions, &mut ctx_budget);
        actions
    }
}
