//! The Hadoop Fair Scheduler ("FAIR", §2.2 of the paper), with delay
//! scheduling (Zaharia et al.).
//!
//! FAIR groups jobs into pools with guaranteed minimum shares; the paper's
//! experiments use the default configuration — a **single pool** with no
//! minimum share — so the discipline reduces to: split slots evenly among
//! runnable jobs, scheduling each free slot to the job *furthest below its
//! fair share* (Hadoop's "deficit" ordering; we use the
//! running-tasks-per-weight ordering of the fair scheduler's task
//! assignment, with submission-time tie-break). Map placement follows
//! delay scheduling with a configurable locality timeout.

use super::delay::{pick_reduce, DelayTimer, LocalityIndex};
use super::{Action, SchedView, Scheduler};
use crate::job::task::NodeId;
use crate::job::{Job, JobId, Phase, TaskRef};
use crate::util::fxmap::{FastMap, FastSet};

/// FAIR configuration.
#[derive(Clone, Debug)]
pub struct FairConfig {
    /// Delay-scheduling locality timeout, seconds (the original delay
    /// scheduler's W; 5 s ≈ 1.5 heartbeats works well at FB scale).
    pub locality_timeout_s: f64,
    /// Per-job weight (single pool, uniform weights by default).
    pub default_weight: f64,
}

impl Default for FairConfig {
    fn default() -> Self {
        Self {
            locality_timeout_s: 5.0,
            default_weight: 1.0,
        }
    }
}

pub struct FairScheduler {
    cfg: FairConfig,
    index: LocalityIndex,
    delay: DelayTimer,
    /// Weights (extension point for pools; uniform in the paper's setup).
    weights: FastMap<JobId, f64>,
    /// Reusable per-heartbeat working sets (the picked-task set and the
    /// deficit ordering's extra-launch counters; the deficit re-sort
    /// itself still builds its candidate list per pick).
    picked: FastSet<TaskRef>,
    extra: FastMap<JobId, usize>,
}

impl FairScheduler {
    pub fn new(cfg: FairConfig) -> Self {
        let delay = DelayTimer::new(cfg.locality_timeout_s);
        Self {
            cfg,
            index: LocalityIndex::new(),
            delay,
            weights: FastMap::default(),
            picked: FastSet::default(),
            extra: FastMap::default(),
        }
    }

    fn weight(&self, job: JobId) -> f64 {
        self.weights
            .get(&job)
            .copied()
            .unwrap_or(self.cfg.default_weight)
    }

    /// Jobs with schedulable work in `phase`, ordered by deficit: fewest
    /// running-tasks-per-weight first (the job furthest below its fair
    /// share), submission order as tie-break. `extra` counts tasks picked
    /// earlier in this same heartbeat.
    fn deficit_order<'b>(
        &self,
        view: &'b SchedView,
        phase: Phase,
        extra: &FastMap<JobId, usize>,
    ) -> Vec<&'b Job> {
        let mut jobs: Vec<&Job> = view
            .active_jobs()
            .filter(|j| {
                let eligible = phase == Phase::Map || j.map_phase_done();
                eligible && j.pending_tasks(phase) > 0
            })
            .collect();
        jobs.sort_by(|a, b| {
            let ra = (a.running_tasks(phase) + extra.get(&a.id()).copied().unwrap_or(0)) as f64
                / self.weight(a.id());
            let rb = (b.running_tasks(phase) + extra.get(&b.id()).copied().unwrap_or(0)) as f64
                / self.weight(b.id());
            ra.total_cmp(&rb).then_with(|| a.id().cmp(&b.id()))
        });
        jobs
    }

    fn assign_maps(
        &mut self,
        view: &SchedView,
        node: NodeId,
        actions: &mut Vec<Action>,
        picked: &mut FastSet<TaskRef>,
        extra: &mut FastMap<JobId, usize>,
    ) {
        let mut free = view.cluster.node(node).free_slots(Phase::Map);
        extra.clear();
        while free > 0 {
            // Re-sort after each pick so shares stay balanced.
            let order = self.deficit_order(view, Phase::Map, extra);
            let mut launched = false;
            for job in order {
                // Delay scheduling: prefer a local task; allow non-local
                // only after the job has been skipped past the timeout.
                if let Some(task) = self.index.pick_local(job, node, picked) {
                    self.delay.clear(job.id());
                    picked.insert(task);
                    actions.push(Action::Launch {
                        task,
                        node,
                        local: true,
                    });
                    *extra.entry(job.id()).or_insert(0) += 1;
                    free -= 1;
                    launched = true;
                    break;
                }
                if self.delay.skip_and_check(job.id(), view.now) {
                    if let Some(task) = self.index.pick_any(job, picked) {
                        self.delay.clear(job.id());
                        picked.insert(task);
                        actions.push(Action::Launch {
                            task,
                            node,
                            local: false,
                        });
                        *extra.entry(job.id()).or_insert(0) += 1;
                        free -= 1;
                        launched = true;
                        break;
                    }
                }
            }
            if !launched {
                break;
            }
        }
    }

    fn assign_reduces(
        &mut self,
        view: &SchedView,
        node: NodeId,
        actions: &mut Vec<Action>,
        picked: &mut FastSet<TaskRef>,
        extra: &mut FastMap<JobId, usize>,
    ) {
        let mut free = view.cluster.node(node).free_slots(Phase::Reduce);
        extra.clear();
        while free > 0 {
            let order = self.deficit_order(view, Phase::Reduce, extra);
            let Some(task) = order.iter().find_map(|job| pick_reduce(job, picked)) else {
                break;
            };
            picked.insert(task);
            actions.push(Action::Launch {
                task,
                node,
                local: true,
            });
            *extra.entry(task.job).or_insert(0) += 1;
            free -= 1;
        }
    }
}

impl Scheduler for FairScheduler {
    fn name(&self) -> &'static str {
        "FAIR"
    }

    fn on_job_arrival(&mut self, view: &SchedView, job: JobId) {
        self.index.add_job(&view.jobs[&job], view.hdfs);
        self.weights.insert(job, self.cfg.default_weight);
    }

    fn on_task_completed(&mut self, _view: &SchedView, _task: TaskRef, _observed: f64) {}

    fn on_job_finished(&mut self, _view: &SchedView, job: JobId) {
        self.index.remove_job(job);
        self.delay.remove_job(job);
        self.weights.remove(&job);
    }

    fn on_heartbeat(&mut self, view: &SchedView, node: NodeId, actions: &mut Vec<Action>) {
        let mut picked = std::mem::take(&mut self.picked);
        let mut extra = std::mem::take(&mut self.extra);
        picked.clear();
        self.assign_maps(view, node, actions, &mut picked, &mut extra);
        self.assign_reduces(view, node, actions, &mut picked, &mut extra);
        self.picked = picked;
        self.extra = extra;
    }
}
