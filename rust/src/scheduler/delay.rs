//! Delay scheduling (Zaharia et al., EuroSys'10) + locality index.
//!
//! Both FAIR and HFSP launch map tasks with the delay-scheduling rule
//! (§3.1 "Data locality"): when the job at the head of the schedule has no
//! *local* pending task for the node offering a slot, the job is skipped
//! (the slot goes to another job) — but only up to a timeout, after which
//! the job is allowed a non-local launch so it cannot starve.
//!
//! [`LocalityIndex`] is the supporting data structure: a per-job,
//! per-node inverted index from HDFS replica placement to pending map
//! tasks, so "find a local pending task for job J on node N" is O(1)
//! amortized instead of a scan over up to ~3000 tasks per heartbeat.

use crate::cluster::Hdfs;
use crate::job::{Job, JobId, Phase, TaskRef};
use crate::job::task::NodeId;
use crate::sim::Time;
use crate::util::fxmap::{FastMap, FastSet};

/// Per-job inverted index: node → map-task indices with a local replica.
struct JobLocal {
    per_node: FastMap<NodeId, Vec<u32>>,
    /// Cursor for non-local picks (tasks mostly launch in index order).
    cursor: u32,
}

/// Locality index over all active jobs.
#[derive(Default)]
pub struct LocalityIndex {
    jobs: FastMap<JobId, JobLocal>,
}

impl LocalityIndex {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a job's map tasks from HDFS placement (call at arrival).
    pub fn add_job(&mut self, job: &Job, hdfs: &Hdfs) {
        let mut per_node: FastMap<NodeId, Vec<u32>> = FastMap::default();
        for i in 0..job.spec.n_maps() as u32 {
            for &node in hdfs.replicas(job.id(), i) {
                per_node.entry(node).or_default().push(i);
            }
        }
        self.jobs.insert(
            job.id(),
            JobLocal {
                per_node,
                cursor: 0,
            },
        );
    }

    pub fn remove_job(&mut self, id: JobId) {
        self.jobs.remove(&id);
    }

    /// Pop a pending map task of `job` whose block is local to `node`.
    /// `picked` holds tasks already chosen in this heartbeat batch (the
    /// view is stale until the driver applies the actions).
    pub fn pick_local(
        &mut self,
        job: &Job,
        node: NodeId,
        picked: &FastSet<TaskRef>,
    ) -> Option<TaskRef> {
        let entry = self.jobs.get_mut(&job.id())?;
        let list = entry.per_node.get_mut(&node)?;
        log::trace!("pick_local job={} node={node} list_len={} pending={}",
            job.id(), list.len(), job.pending_tasks(Phase::Map));
        while let Some(&idx) = list.last() {
            let t = TaskRef {
                job: job.id(),
                phase: Phase::Map,
                index: idx,
            };
            if job.task(t).state.is_pending() && !picked.contains(&t) {
                list.pop();
                return Some(t);
            }
            // Launched/done elsewhere (or picked non-locally): drop lazily.
            if job.task(t).state.is_pending() {
                // Pending but picked in this batch: keep it in the index
                // for later heartbeats, give up on this node for now.
                return None;
            }
            list.pop();
        }
        None
    }

    /// Pick any pending map task of `job` (non-local fallback).
    pub fn pick_any(&mut self, job: &Job, picked: &FastSet<TaskRef>) -> Option<TaskRef> {
        let n = job.spec.n_maps() as u32;
        let entry = self.jobs.get_mut(&job.id())?;
        // Fast path: advance the cursor.
        let scan = |from: u32, to: u32| -> Option<u32> {
            (from..to).find(|&i| {
                let t = TaskRef {
                    job: job.id(),
                    phase: Phase::Map,
                    index: i,
                };
                job.task(t).state.is_pending() && !picked.contains(&t)
            })
        };
        if let Some(i) = scan(entry.cursor, n) {
            entry.cursor = i + 1;
            return Some(TaskRef {
                job: job.id(),
                phase: Phase::Map,
                index: i,
            });
        }
        // Slow path: killed tasks re-enter pending behind the cursor.
        if let Some(i) = scan(0, entry.cursor) {
            return Some(TaskRef {
                job: job.id(),
                phase: Phase::Map,
                index: i,
            });
        }
        None
    }
}

/// Pick a pending reduce task (reduces have no input locality, §3.1).
pub fn pick_reduce(job: &Job, picked: &FastSet<TaskRef>) -> Option<TaskRef> {
    job.reduces.iter().enumerate().find_map(|(i, t)| {
        let tr = TaskRef {
            job: job.id(),
            phase: Phase::Reduce,
            index: i as u32,
        };
        (t.state.is_pending() && !picked.contains(&tr)).then_some(tr)
    })
}

/// Delay-scheduling timers: per job, when it first had to be skipped for
/// lack of a local task.
pub struct DelayTimer {
    timeout_s: f64,
    skipped_since: FastMap<JobId, Time>,
}

impl DelayTimer {
    pub fn new(timeout_s: f64) -> Self {
        Self {
            timeout_s,
            skipped_since: FastMap::default(),
        }
    }

    /// The job found a local task (or has none pending): reset its timer.
    pub fn clear(&mut self, job: JobId) {
        self.skipped_since.remove(&job);
    }

    /// The job had pending work but no local task on the offered node.
    /// Returns `true` if it has now been skipped long enough that a
    /// non-local launch is allowed.
    pub fn skip_and_check(&mut self, job: JobId, now: Time) -> bool {
        let since = *self.skipped_since.entry(job).or_insert(now);
        now - since >= self.timeout_s
    }

    pub fn remove_job(&mut self, job: JobId) {
        self.skipped_since.remove(&job);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Hdfs;
    use crate::job::{Job, JobClass, JobSpec, TenantId};
    use crate::util::rng::{Pcg64, SeedableRng};

    fn mk_job(id: JobId, n_maps: usize) -> Job {
        Job::new(JobSpec {
            id,
            name: format!("j{id}"),
            class: JobClass::Medium,
            tenant: TenantId::default(),
            submit_time: 0.0,
            map_durations: vec![10.0; n_maps],
            reduce_durations: vec![20.0; 2],
        })
    }

    fn setup(n_nodes: usize, n_maps: usize) -> (Job, Hdfs, LocalityIndex) {
        let mut hdfs = Hdfs::new(n_nodes, 3, Pcg64::seed_from_u64(5));
        let job = mk_job(1, n_maps);
        hdfs.place_job(1, n_maps);
        let mut idx = LocalityIndex::new();
        idx.add_job(&job, &hdfs);
        (job, hdfs, idx)
    }

    #[test]
    fn pick_local_returns_replica_holder_tasks() {
        let (job, hdfs, mut idx) = setup(10, 30);
        let picked = FastSet::default();
        for node in 0..10 {
            while let Some(t) = idx.pick_local(&job, node, &picked) {
                assert!(hdfs.is_local(node, t), "picked task must be local");
                // Simulate the launch so it is no longer pending.
                // (Can't mutate `job` inside the loop borrow; just check a
                // few and break.)
                break;
            }
        }
    }

    #[test]
    fn pick_local_skips_non_pending() {
        let (mut job, hdfs, mut idx) = setup(4, 8);
        // Launch every task somewhere; index entries become stale.
        for i in 0..8u32 {
            let t = TaskRef {
                job: 1,
                phase: Phase::Map,
                index: i,
            };
            job.task_mut(t).launch(0, 0.0, hdfs.is_local(0, t), 1.0);
        }
        let picked = FastSet::default();
        for node in 0..4 {
            assert!(idx.pick_local(&job, node, &picked).is_none());
        }
    }

    #[test]
    fn pick_any_respects_picked_set() {
        let (job, _hdfs, mut idx) = setup(4, 3);
        let mut picked = FastSet::default();
        let a = idx.pick_any(&job, &picked).unwrap();
        picked.insert(a);
        let b = idx.pick_any(&job, &picked).unwrap();
        assert_ne!(a, b);
        picked.insert(b);
        let c = idx.pick_any(&job, &picked).unwrap();
        picked.insert(c);
        assert!(idx.pick_any(&job, &picked).is_none());
    }

    #[test]
    fn pick_any_finds_requeued_task_behind_cursor() {
        let (mut job, _hdfs, mut idx) = setup(4, 3);
        let picked = FastSet::default();
        // Advance the cursor past all tasks.
        for _ in 0..3 {
            let t = idx.pick_any(&job, &picked).unwrap();
            job.task_mut(t).launch(0, 0.0, false, 1.0);
        }
        assert!(idx.pick_any(&job, &picked).is_none());
        // Kill task 0: it becomes pending again, behind the cursor.
        let t0 = TaskRef {
            job: 1,
            phase: Phase::Map,
            index: 0,
        };
        job.task_mut(t0).kill(1.0);
        assert_eq!(idx.pick_any(&job, &picked), Some(t0));
    }

    #[test]
    fn pick_reduce_in_order() {
        let job = mk_job(1, 1);
        let picked = FastSet::default();
        let r = pick_reduce(&job, &picked).unwrap();
        assert_eq!(r.index, 0);
        let mut picked = FastSet::default();
        picked.insert(r);
        assert_eq!(pick_reduce(&job, &picked).unwrap().index, 1);
    }

    #[test]
    fn delay_timer_allows_after_timeout() {
        let mut d = DelayTimer::new(5.0);
        assert!(!d.skip_and_check(1, 10.0), "first skip starts the clock");
        assert!(!d.skip_and_check(1, 14.0));
        assert!(d.skip_and_check(1, 15.0), "timeout reached");
        d.clear(1);
        assert!(!d.skip_and_check(1, 16.0), "cleared: clock restarts");
    }
}
