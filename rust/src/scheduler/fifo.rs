//! Hadoop's default FIFO scheduler (§2.2 of the paper).
//!
//! Task assignment on each heartbeat scans jobs in (priority, submission
//! time) order — we model a single priority level, so submission (= job
//! id) order — and hands every free slot to the first job with a pending
//! task of the required type. For MAP tasks, the scheduler "selects
//! greedily the more suitable task to achieve data locality": a local
//! pending task if one exists, otherwise any pending task immediately
//! (FIFO does **not** use delay scheduling).

use super::delay::{pick_reduce, LocalityIndex};
use super::{Action, SchedView, Scheduler};
use crate::job::task::NodeId;
use crate::job::{JobId, Phase, TaskRef};
use crate::util::fxmap::FastSet;

pub struct FifoScheduler {
    index: LocalityIndex,
    /// Reusable per-heartbeat picked set (hot path allocates nothing).
    picked: FastSet<TaskRef>,
}

impl FifoScheduler {
    pub fn new() -> Self {
        Self {
            index: LocalityIndex::new(),
            picked: FastSet::default(),
        }
    }

    fn assign_phase(
        &mut self,
        view: &SchedView,
        node: NodeId,
        phase: Phase,
        actions: &mut Vec<Action>,
        picked: &mut FastSet<TaskRef>,
    ) {
        let mut free = view.cluster.node(node).free_slots(phase);
        if free == 0 {
            return;
        }
        // Jobs in submission order (ids are assigned in arrival order).
        for job in view.active_jobs() {
            if free == 0 {
                break;
            }
            match phase {
                Phase::Map => {
                    while free > 0 {
                        let local = self.index.pick_local(job, node, picked);
                        let task = match local {
                            Some(t) => Some((t, true)),
                            None => self.index.pick_any(job, picked).map(|t| (t, false)),
                        };
                        let Some((task, local)) = task else { break };
                        picked.insert(task);
                        actions.push(Action::Launch { task, node, local });
                        free -= 1;
                    }
                }
                Phase::Reduce => {
                    if !job.map_phase_done() {
                        continue;
                    }
                    while free > 0 {
                        let Some(task) = pick_reduce(job, picked) else {
                            break;
                        };
                        picked.insert(task);
                        actions.push(Action::Launch {
                            task,
                            node,
                            local: true,
                        });
                        free -= 1;
                    }
                }
            }
        }
    }
}

impl Default for FifoScheduler {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheduler for FifoScheduler {
    fn name(&self) -> &'static str {
        "FIFO"
    }

    fn on_job_arrival(&mut self, view: &SchedView, job: JobId) {
        self.index.add_job(&view.jobs[&job], view.hdfs);
    }

    fn on_task_completed(&mut self, _view: &SchedView, _task: TaskRef, _observed: f64) {}

    fn on_job_finished(&mut self, _view: &SchedView, job: JobId) {
        self.index.remove_job(job);
    }

    fn on_heartbeat(&mut self, view: &SchedView, node: NodeId, actions: &mut Vec<Action>) {
        let mut picked = std::mem::take(&mut self.picked);
        picked.clear();
        self.assign_phase(view, node, Phase::Map, actions, &mut picked);
        self.assign_phase(view, node, Phase::Reduce, actions, &mut picked);
        self.picked = picked;
    }
}
