//! FSP — the Fair Sojourn Protocol ordering (HFSP's policy, §3.1).
//!
//! Jobs are ordered by their **projected completion time in a max-min-
//! fair processor-sharing reference simulation** (one
//! [`VirtualCluster`] per phase, over that phase's slot pool). The
//! priority key handed to the mechanism is the projected PS finish time
//! in simulated seconds, so the preemption threshold compares absolute
//! finish-time gaps — exactly the pre-split HFSP behaviour, bit for bit.

use crate::job::{JobId, Phase};
use crate::scheduler::core::virtual_cluster::VirtualCluster;
use crate::scheduler::core::{Discipline, MaxMinKind};
use crate::sim::Time;

/// The FSP discipline: two fluid PS reference simulations (map and
/// reduce slot pools), aged on every heartbeat.
pub struct FspDiscipline {
    maxmin: MaxMinKind,
    vc_map: VirtualCluster,
    vc_reduce: VirtualCluster,
}

impl FspDiscipline {
    pub fn new(maxmin: MaxMinKind) -> Self {
        // Placeholder capacities; replaced by `bind_capacity`.
        let vc_map = VirtualCluster::with_backend(1, maxmin.build());
        let vc_reduce = VirtualCluster::with_backend(1, maxmin.build());
        Self {
            maxmin,
            vc_map,
            vc_reduce,
        }
    }

    fn vc(&mut self, phase: Phase) -> &mut VirtualCluster {
        match phase {
            Phase::Map => &mut self.vc_map,
            Phase::Reduce => &mut self.vc_reduce,
        }
    }
}

impl Discipline for FspDiscipline {
    fn bind_capacity(&mut self, map_slots: usize, reduce_slots: usize) {
        self.vc_map = VirtualCluster::with_backend(map_slots, self.maxmin.build());
        self.vc_reduce = VirtualCluster::with_backend(reduce_slots, self.maxmin.build());
    }

    fn phase_started(
        &mut self,
        id: JobId,
        phase: Phase,
        initial_size: f64,
        n_tasks: usize,
        now: Time,
    ) {
        self.vc(phase).add_job(id, initial_size, n_tasks, now);
    }

    fn size_estimated(&mut self, id: JobId, phase: Phase, total: f64, now: Time) {
        self.vc(phase).set_total(id, total, now);
    }

    fn service_observed(&mut self, _id: JobId, _phase: Phase, _observed: f64, _now: Time) {
        // The PS reference is deliberately decoupled from real progress
        // (§3.1 "Virtual width"): attained service does not feed it.
    }

    fn phase_completed(&mut self, id: JobId, phase: Phase, now: Time) {
        self.vc(phase).remove_job(id, now);
    }

    fn job_removed(&mut self, id: JobId, now: Time) {
        self.vc_map.remove_job(id, now);
        self.vc_reduce.remove_job(id, now);
    }

    fn advance(&mut self, now: Time) {
        // Job aging: advance both PS reference simulations to now in one
        // batched max-min backend call (§3.1; bit-identical to the
        // former per-phase `age_to` loop — pinned by test).
        VirtualCluster::age_pair_to(&mut self.vc_map, &mut self.vc_reduce, now);
    }

    fn generation(&self, phase: Phase) -> u64 {
        match phase {
            Phase::Map => self.vc_map.generation(),
            Phase::Reduce => self.vc_reduce.generation(),
        }
    }

    fn order(&mut self, phase: Phase) -> &[(JobId, f64)] {
        // Borrow of the virtual cluster's cached projection — no clone;
        // the mechanism copies it at most once per generation.
        self.vc(phase).projected_finish_order()
    }

    fn remaining(&self, id: JobId, phase: Phase) -> Option<f64> {
        match phase {
            Phase::Map => self.vc_map.remaining(id),
            Phase::Reduce => self.vc_reduce.remaining(id),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fsp_orders_by_projected_ps_finish() {
        let mut d = FspDiscipline::new(MaxMinKind::Native);
        d.bind_capacity(1, 1);
        // Fig. 1 scenario: sizes 30/10/10 on one slot, arrivals 0/10/15
        // → PS completion order j2, j3, j1.
        d.phase_started(1, Phase::Map, 30.0, 10, 0.0);
        d.phase_started(2, Phase::Map, 10.0, 10, 10.0);
        d.phase_started(3, Phase::Map, 10.0, 10, 15.0);
        let ids: Vec<JobId> = d.order(Phase::Map).iter().map(|&(id, _)| id).collect();
        assert_eq!(ids, vec![2, 3, 1]);
    }

    #[test]
    fn estimate_updates_reorder() {
        let mut d = FspDiscipline::new(MaxMinKind::Native);
        d.bind_capacity(1, 1);
        d.phase_started(1, Phase::Map, 10.0, 1, 0.0);
        d.phase_started(2, Phase::Map, 20.0, 1, 0.0);
        assert_eq!(d.order(Phase::Map)[0].0, 1);
        d.size_estimated(2, Phase::Map, 1.0, 0.0);
        assert_eq!(d.order(Phase::Map)[0].0, 2);
    }
}
