//! SRPT — preemptive shortest-remaining-estimated-size.
//!
//! The classic mean-sojourn-optimal single-server discipline, applied to
//! estimated MapReduce phase sizes: a job's priority key is its
//! **estimated serialized size minus attained service** (both in
//! serialized seconds), so the preemption threshold compares
//! remaining-work gaps. Before training completes the key rests on the
//! training module's initial history-based estimate, exactly like HFSP's
//! virtual cluster does; estimate revisions re-key the job in place.
//!
//! Compared in the PSBS line of work (arXiv 1410.6122, 1403.5996) as the
//! upper-bound reference that is *most* sensitive to estimation error —
//! under-estimated large jobs camp at the head of the queue.
//!
//! State is per-phase ([`FastMap`] keyed by job id) with a lazily
//! rebuilt `OrderedCache`: [`Discipline::order`] hands out a slice,
//! only re-sorting after a lifecycle event dirtied the phase.

use super::OrderedCache;
use crate::job::{JobId, Phase};
use crate::scheduler::core::Discipline;
use crate::sim::Time;
use crate::util::fxmap::FastMap;

struct JobState {
    estimated_total: f64,
    attained: f64,
}

impl JobState {
    fn remaining(&self) -> f64 {
        (self.estimated_total - self.attained).max(0.0)
    }
}

/// The SRPT discipline.
#[derive(Default)]
pub struct SrptDiscipline {
    /// Per-phase job state ([map, reduce]).
    jobs: [FastMap<JobId, JobState>; 2],
    /// Per-phase order version: a map-phase event must not invalidate
    /// the mechanism's cached reduce order.
    generation: [u64; 2],
    cache: [OrderedCache; 2],
}

pub(super) fn phase_idx(phase: Phase) -> usize {
    match phase {
        Phase::Map => 0,
        Phase::Reduce => 1,
    }
}

impl SrptDiscipline {
    pub fn new() -> Self {
        Self::default()
    }

    fn bump(&mut self, phase: Phase) {
        let i = phase_idx(phase);
        self.generation[i] += 1;
        self.cache[i].invalidate();
    }
}

impl Discipline for SrptDiscipline {
    fn bind_capacity(&mut self, _map_slots: usize, _reduce_slots: usize) {}

    fn phase_started(
        &mut self,
        id: JobId,
        phase: Phase,
        initial_size: f64,
        _n_tasks: usize,
        _now: Time,
    ) {
        self.jobs[phase_idx(phase)].insert(
            id,
            JobState {
                estimated_total: initial_size,
                attained: 0.0,
            },
        );
        self.bump(phase);
    }

    fn size_estimated(&mut self, id: JobId, phase: Phase, total: f64, _now: Time) {
        if let Some(j) = self.jobs[phase_idx(phase)].get_mut(&id) {
            j.estimated_total = total.max(0.0);
            self.bump(phase);
        }
    }

    fn service_observed(&mut self, id: JobId, phase: Phase, observed: f64, _now: Time) {
        if let Some(j) = self.jobs[phase_idx(phase)].get_mut(&id) {
            j.attained += observed;
            self.bump(phase);
        }
    }

    fn phase_completed(&mut self, id: JobId, phase: Phase, _now: Time) {
        if self.jobs[phase_idx(phase)].remove(&id).is_some() {
            self.bump(phase);
        }
    }

    fn job_removed(&mut self, id: JobId, _now: Time) {
        for phase in [Phase::Map, Phase::Reduce] {
            if self.jobs[phase_idx(phase)].remove(&id).is_some() {
                self.bump(phase);
            }
        }
    }

    fn advance(&mut self, _now: Time) {}

    fn generation(&self, phase: Phase) -> u64 {
        self.generation[phase_idx(phase)]
    }

    fn order(&mut self, phase: Phase) -> &[(JobId, f64)] {
        let i = phase_idx(phase);
        self.cache[i].get_or_rebuild(self.jobs[i].iter().map(|(&id, j)| (id, j.remaining())))
    }

    fn remaining(&self, id: JobId, phase: Phase) -> Option<f64> {
        self.jobs[phase_idx(phase)].get(&id).map(JobState::remaining)
    }
}
