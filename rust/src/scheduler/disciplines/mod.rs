//! Size-based ordering **policies** plugged into the shared mechanism
//! ([`crate::scheduler::core::SizeBasedScheduler`]).
//!
//! Each discipline answers one question — *in which order should jobs be
//! served?* — through the [`Discipline`](crate::scheduler::core::Discipline)
//! trait; everything else (estimation, training slots, preemption,
//! locality) is the mechanism's job. Four disciplines ship:
//!
//! | kind | label | orders by | estimates? |
//! |------|-------|-----------|------------|
//! | [`Fsp`](DisciplineKind::Fsp) | `HFSP` | projected finish in the max-min-fair PS reference (§3.1) | yes |
//! | [`Srpt`](DisciplineKind::Srpt) | `SRPT` | shortest remaining estimated size | yes |
//! | [`Las`](DisciplineKind::Las) | `LAS` | least attained service (size-oblivious FB scheduling) | no |
//! | [`Psbs`](DisciplineKind::Psbs) | `PSBS` | late-binding virtual-time finish tags (à la PSBS, arXiv 1410.6122) | yes |
//!
//! This is the scenario space of *PSBS: Practical Size-Based Scheduling*
//! and of the estimation-error sensitivity study in *Revisiting
//! Size-Based Scheduling with Estimated Job Sizes* (arXiv 1403.5996) —
//! see `benches/fig_disciplines.rs`.

pub mod fsp;
pub mod las;
pub mod psbs;
pub mod srpt;

pub use fsp::FspDiscipline;
pub use las::LasDiscipline;
pub use psbs::PsbsDiscipline;
pub use srpt::SrptDiscipline;

use super::core::{Discipline, SizeBasedConfig};
use crate::job::JobId;

/// Lazily rebuilt `(job, priority key)` order cache shared by the
/// map-backed disciplines (SRPT, LAS, PSBS): one per phase, marked
/// stale by every lifecycle hook that bumps the discipline's
/// generation, rebuilt at most once per [`Discipline::order`] call.
/// Ascending key, ties by job id; [`f64::total_cmp`] so a pathological
/// key stream can never panic the comparator. Keeping the
/// dirty-flag/rebuild protocol in ONE place means an invalidation fix
/// cannot silently diverge between disciplines.
#[derive(Default)]
pub(crate) struct OrderedCache {
    entries: Vec<(JobId, f64)>,
    dirty: bool,
}

impl OrderedCache {
    /// Mark the cached order stale (pair with every generation bump).
    pub(crate) fn invalidate(&mut self) {
        self.dirty = true;
    }

    /// The cached order, rebuilt from `entries` when stale. No
    /// allocation and no sort when the order is unchanged.
    pub(crate) fn get_or_rebuild(
        &mut self,
        entries: impl Iterator<Item = (JobId, f64)>,
    ) -> &[(JobId, f64)] {
        if self.dirty {
            self.entries.clear();
            self.entries.extend(entries);
            self.entries
                .sort_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
            self.dirty = false;
        }
        &self.entries
    }
}

/// Which ordering policy a [`SizeBasedConfig`] selects.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum DisciplineKind {
    /// Fair Sojourn Protocol: HFSP's ordering (the default).
    #[default]
    Fsp,
    /// Preemptive shortest-remaining-estimated-size.
    Srpt,
    /// Least attained service (foreground/background); size-oblivious.
    Las,
    /// PSBS-style late-binding virtual-time ordering.
    Psbs,
}

impl DisciplineKind {
    /// Report/table label ([`SimOutcome::scheduler`]
    /// (crate::cluster::driver::SimOutcome) and sweep group keys).
    pub const fn label(self) -> &'static str {
        match self {
            DisciplineKind::Fsp => "HFSP",
            DisciplineKind::Srpt => "SRPT",
            DisciplineKind::Las => "LAS",
            DisciplineKind::Psbs => "PSBS",
        }
    }

    /// Canonical CLI token (`--scheduler` / sweep axis value).
    pub const fn cli_name(self) -> &'static str {
        match self {
            DisciplineKind::Fsp => "hfsp",
            DisciplineKind::Srpt => "srpt",
            DisciplineKind::Las => "las",
            DisciplineKind::Psbs => "psbs",
        }
    }

    /// Whether the discipline consumes size estimates. `false` disables
    /// the training module entirely (no sample sets, no estimator, no
    /// training-priority slots) — the mechanism's optional-training
    /// path, exercised by LAS.
    pub const fn uses_estimates(self) -> bool {
        !matches!(self, DisciplineKind::Las)
    }

    pub const ALL: [DisciplineKind; 4] = [
        DisciplineKind::Fsp,
        DisciplineKind::Srpt,
        DisciplineKind::Las,
        DisciplineKind::Psbs,
    ];
}

/// Instantiate the discipline a config selects.
pub fn build(cfg: &SizeBasedConfig) -> Box<dyn Discipline> {
    match cfg.discipline {
        DisciplineKind::Fsp => Box::new(FspDiscipline::new(cfg.maxmin.clone())),
        DisciplineKind::Srpt => Box::new(SrptDiscipline::new()),
        DisciplineKind::Las => Box::new(LasDiscipline::new()),
        DisciplineKind::Psbs => Box::new(PsbsDiscipline::new()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::Phase;
    use crate::scheduler::core::Discipline;

    /// Exercise the shared Discipline contract on every non-FSP
    /// implementation (FSP's ordering is covered by the virtual-cluster
    /// suite): membership tracks phase_started/phase_completed/
    /// job_removed, order is deterministic, generation moves with it.
    fn contract(mut d: Box<dyn Discipline>) {
        d.bind_capacity(4, 2);
        d.phase_started(1, Phase::Map, 100.0, 10, 0.0);
        d.phase_started(2, Phase::Map, 10.0, 2, 1.0);
        d.advance(2.0);
        let order = d.order(Phase::Map).to_vec();
        assert_eq!(order.len(), 2, "both registered jobs present");
        assert!(order.windows(2).all(|w| w[0].1 <= w[1].1), "keys ascending");
        let again = d.order(Phase::Map).to_vec();
        assert_eq!(
            order.iter().map(|&(id, _)| id).collect::<Vec<_>>(),
            again.iter().map(|&(id, _)| id).collect::<Vec<_>>(),
            "deterministic"
        );
        assert!(d.order(Phase::Reduce).is_empty(), "phases are independent");
        let g = d.generation(Phase::Map);
        let gr = d.generation(Phase::Reduce);
        d.phase_completed(1, Phase::Map, 3.0);
        assert_ne!(d.generation(Phase::Map), g, "removal bumps generation");
        assert_eq!(
            d.generation(Phase::Reduce),
            gr,
            "a map-phase event must not invalidate the reduce order cache"
        );
        assert_eq!(d.order(Phase::Map).len(), 1);
        d.job_removed(2, 4.0);
        assert!(d.order(Phase::Map).is_empty());
    }

    #[test]
    fn srpt_las_psbs_honour_the_contract() {
        contract(Box::new(SrptDiscipline::new()));
        contract(Box::new(LasDiscipline::new()));
        contract(Box::new(PsbsDiscipline::new()));
    }

    #[test]
    fn srpt_prefers_smaller_remaining() {
        let mut d = SrptDiscipline::new();
        d.phase_started(1, Phase::Map, 100.0, 10, 0.0);
        d.phase_started(2, Phase::Map, 50.0, 5, 0.0);
        assert_eq!(d.order(Phase::Map)[0].0, 2);
        // Job 1 attains 80 s of service: remaining 20 < 50 flips the order.
        d.service_observed(1, Phase::Map, 80.0, 1.0);
        assert_eq!(d.order(Phase::Map)[0].0, 1);
        // A revised (larger) estimate flips it back.
        d.size_estimated(1, Phase::Map, 500.0, 2.0);
        assert_eq!(d.order(Phase::Map)[0].0, 2);
    }

    #[test]
    fn las_prefers_least_attained_and_ignores_estimates() {
        let mut d = LasDiscipline::new();
        d.phase_started(1, Phase::Map, 0.0, 10, 0.0);
        d.phase_started(2, Phase::Map, 0.0, 10, 0.0);
        // Tie at zero attained: job-id order.
        assert_eq!(d.order(Phase::Map)[0].0, 1);
        d.service_observed(1, Phase::Map, 30.0, 1.0);
        assert_eq!(d.order(Phase::Map)[0].0, 2, "fresh job first under LAS");
        // Estimates must not perturb the order (size-oblivious).
        let before = d.order(Phase::Map).to_vec();
        d.size_estimated(2, Phase::Map, 1e6, 2.0);
        assert_eq!(before, d.order(Phase::Map));
    }

    #[test]
    fn psbs_late_binding_rebinds_against_current_virtual_time() {
        let mut d = PsbsDiscipline::new();
        d.phase_started(1, Phase::Map, 100.0, 10, 0.0);
        // Virtual time advances while job 1 is alone (rate 1/1).
        d.advance(50.0);
        // Job 2 arrives with a small initial estimate: tag = vnow + 10,
        // well before job 1's tag of 100... but only because binding
        // happens against the *current* virtual time.
        d.phase_started(2, Phase::Map, 10.0, 1, 50.0);
        assert_eq!(d.order(Phase::Map)[0].0, 2);
        // Job 2's estimate is revised upward at a later virtual instant:
        // the tag re-binds and job 1 regains priority.
        d.advance(60.0);
        d.size_estimated(2, Phase::Map, 200.0, 60.0);
        assert_eq!(d.order(Phase::Map)[0].0, 1);
    }

    #[test]
    fn kind_metadata_is_consistent() {
        for kind in DisciplineKind::ALL {
            assert!(!kind.label().is_empty());
            assert!(!kind.cli_name().is_empty());
            assert_eq!(kind.cli_name(), kind.cli_name().to_ascii_lowercase());
        }
        assert!(DisciplineKind::Fsp.uses_estimates());
        assert!(DisciplineKind::Srpt.uses_estimates());
        assert!(DisciplineKind::Psbs.uses_estimates());
        assert!(!DisciplineKind::Las.uses_estimates());
        assert_eq!(DisciplineKind::default(), DisciplineKind::Fsp);
    }

    #[test]
    fn build_respects_the_kind() {
        for kind in DisciplineKind::ALL {
            let cfg = SizeBasedConfig {
                discipline: kind,
                ..Default::default()
            };
            // Smoke: a built discipline accepts the basic lifecycle.
            let mut d = build(&cfg);
            d.bind_capacity(2, 2);
            d.phase_started(7, Phase::Map, 5.0, 1, 0.0);
            assert_eq!(d.order(Phase::Map).len(), 1);
            d.job_removed(7, 1.0);
            assert!(d.order(Phase::Map).is_empty());
        }
    }
}
