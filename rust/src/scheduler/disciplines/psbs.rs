//! PSBS-style late-binding virtual-time ordering.
//!
//! A practical simplification of FSP in the spirit of *PSBS: Practical
//! Size-Based Scheduling* (arXiv 1410.6122): instead of running a full
//! fluid PS reference simulation per phase, keep one **virtual clock**
//! per phase that advances at rate `1 / active jobs` (the per-job
//! processor-sharing service rate) and give every job a **finish tag**
//!
//! ```text
//! tag = v(t_bind) + remaining estimated size at t_bind
//! ```
//!
//! Jobs are served in ascending tag order. The defining *late-binding*
//! property: the tag is (re-)bound against the **current** virtual time
//! whenever the size estimate changes — a job that trains late, or whose
//! estimate is revised, is queued where a job of that size arriving *at
//! the revision instant* would be, rather than inheriting priority from
//! a stale guess. This keeps ordinal order largely correct under
//! estimation error (the property the PSBS paper's robustness results
//! rest on) at O(1) bookkeeping per event, versus the fluid projection's
//! O(n² log n) worst case.
//!
//! The priority key is a virtual timestamp; the preemption threshold
//! therefore compares virtual-time gaps. Tag storage is a [`FastMap`]
//! per phase with a lazily rebuilt `OrderedCache` served by slice.

use crate::job::{JobId, Phase};
use crate::scheduler::core::Discipline;
use crate::sim::Time;
use crate::util::fxmap::FastMap;

struct TaggedJob {
    /// Virtual finish tag (bound at arrival, re-bound on estimates).
    tag: f64,
    /// Attained serialized service (discounts re-binds).
    attained: f64,
}

/// Virtual clock + tagged jobs of one phase.
#[derive(Default)]
struct PhaseQueue {
    vnow: f64,
    last: Time,
    jobs: FastMap<JobId, TaggedJob>,
    cache: OrderedCache,
}

impl PhaseQueue {
    /// Advance the virtual clock to `now` at the PS per-job rate.
    fn tick(&mut self, now: Time) {
        let dt = now - self.last;
        if dt > 0.0 {
            if !self.jobs.is_empty() {
                self.vnow += dt / self.jobs.len() as f64;
            }
            self.last = now;
        }
    }
}

use super::srpt::phase_idx;
use super::OrderedCache;

/// The PSBS-style discipline.
#[derive(Default)]
pub struct PsbsDiscipline {
    map: PhaseQueue,
    reduce: PhaseQueue,
    /// Per-phase order version ([map, reduce]).
    generation: [u64; 2],
}

impl PsbsDiscipline {
    pub fn new() -> Self {
        Self::default()
    }

    fn queue(&mut self, phase: Phase) -> &mut PhaseQueue {
        match phase {
            Phase::Map => &mut self.map,
            Phase::Reduce => &mut self.reduce,
        }
    }

    fn bump(&mut self, phase: Phase) {
        self.generation[phase_idx(phase)] += 1;
        self.queue(phase).cache.invalidate();
    }
}

impl Discipline for PsbsDiscipline {
    fn bind_capacity(&mut self, _map_slots: usize, _reduce_slots: usize) {}

    fn phase_started(
        &mut self,
        id: JobId,
        phase: Phase,
        initial_size: f64,
        _n_tasks: usize,
        now: Time,
    ) {
        let q = self.queue(phase);
        // Tick with the pre-arrival job count, then bind the tag.
        q.tick(now);
        let tag = q.vnow + initial_size.max(0.0);
        q.jobs.insert(
            id,
            TaggedJob {
                tag,
                attained: 0.0,
            },
        );
        self.bump(phase);
    }

    fn size_estimated(&mut self, id: JobId, phase: Phase, total: f64, now: Time) {
        let q = self.queue(phase);
        q.tick(now);
        let vnow = q.vnow;
        let rebound = if let Some(j) = q.jobs.get_mut(&id) {
            // Late binding: re-queue at the position a job with this
            // remaining size would get if it arrived right now.
            j.tag = vnow + (total - j.attained).max(0.0);
            true
        } else {
            false
        };
        if rebound {
            self.bump(phase);
        }
    }

    fn service_observed(&mut self, id: JobId, phase: Phase, observed: f64, _now: Time) {
        // Attained service only discounts future re-binds; the current
        // tag (and hence the order) is unchanged.
        if let Some(j) = self.queue(phase).jobs.get_mut(&id) {
            j.attained += observed;
        }
    }

    fn phase_completed(&mut self, id: JobId, phase: Phase, now: Time) {
        let q = self.queue(phase);
        q.tick(now);
        if q.jobs.remove(&id).is_some() {
            self.bump(phase);
        }
    }

    fn job_removed(&mut self, id: JobId, now: Time) {
        for phase in [Phase::Map, Phase::Reduce] {
            let q = self.queue(phase);
            q.tick(now);
            if q.jobs.remove(&id).is_some() {
                self.bump(phase);
            }
        }
    }

    fn advance(&mut self, now: Time) {
        self.map.tick(now);
        self.reduce.tick(now);
    }

    fn generation(&self, phase: Phase) -> u64 {
        self.generation[phase_idx(phase)]
    }

    fn order(&mut self, phase: Phase) -> &[(JobId, f64)] {
        let q = self.queue(phase);
        let jobs = &q.jobs;
        q.cache.get_or_rebuild(jobs.iter().map(|(&id, j)| (id, j.tag)))
    }

    fn remaining(&self, id: JobId, phase: Phase) -> Option<f64> {
        let q = match phase {
            Phase::Map => &self.map,
            Phase::Reduce => &self.reduce,
        };
        q.jobs.get(&id).map(|j| (j.tag - q.vnow).max(0.0))
    }
}
