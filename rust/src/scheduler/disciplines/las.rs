//! LAS/FB — least attained service (foreground/background).
//!
//! The size-*oblivious* member of the discipline family: jobs are
//! ordered by the serialized work they have **already received**,
//! ascending — fresh jobs run first, long-running jobs fall to the
//! background. Under the heavy-tailed job-size distributions of
//! MapReduce traces LAS approximates SRPT without ever knowing a size,
//! which makes it the natural baseline for the estimation-error
//! sensitivity study (arXiv 1403.5996): its curve is flat in σ by
//! construction.
//!
//! LAS reports [`DisciplineKind::uses_estimates`]
//! (crate::scheduler::disciplines::DisciplineKind::uses_estimates) =
//! `false`, so the mechanism runs **without a training module** — no
//! sample sets, no training-priority slots, no estimator — exercising
//! the core's optional-training path.
//!
//! The priority key is attained serialized seconds; ties (e.g. a batch
//! of fresh jobs at 0) break by job id, i.e. FIFO, and the preemption
//! threshold doubles as the scheduler's quantum: a fresh job only
//! preempts a victim that has attained at least
//! `preempt_threshold_s` more service.
//!
//! Storage mirrors SRPT: per-phase [`FastMap`] state plus a lazily
//! rebuilt `OrderedCache` served by slice.

use crate::job::{JobId, Phase};
use crate::scheduler::core::Discipline;
use crate::sim::Time;
use crate::util::fxmap::FastMap;

use super::srpt::phase_idx;
use super::OrderedCache;

/// The LAS discipline.
#[derive(Default)]
pub struct LasDiscipline {
    /// Per-phase attained service ([map, reduce]).
    attained: [FastMap<JobId, f64>; 2],
    /// Per-phase order version ([map, reduce]).
    generation: [u64; 2],
    cache: [OrderedCache; 2],
}

impl LasDiscipline {
    pub fn new() -> Self {
        Self::default()
    }

    fn bump(&mut self, phase: Phase) {
        let i = phase_idx(phase);
        self.generation[i] += 1;
        self.cache[i].invalidate();
    }
}

impl Discipline for LasDiscipline {
    fn bind_capacity(&mut self, _map_slots: usize, _reduce_slots: usize) {}

    fn phase_started(
        &mut self,
        id: JobId,
        phase: Phase,
        _initial_size: f64,
        _n_tasks: usize,
        _now: Time,
    ) {
        self.attained[phase_idx(phase)].insert(id, 0.0);
        self.bump(phase);
    }

    fn size_estimated(&mut self, _id: JobId, _phase: Phase, _total: f64, _now: Time) {
        // Size-oblivious: never called (no training module), and inert
        // by contract if it ever were.
    }

    fn service_observed(&mut self, id: JobId, phase: Phase, observed: f64, _now: Time) {
        if let Some(a) = self.attained[phase_idx(phase)].get_mut(&id) {
            *a += observed;
            self.bump(phase);
        }
    }

    fn phase_completed(&mut self, id: JobId, phase: Phase, _now: Time) {
        if self.attained[phase_idx(phase)].remove(&id).is_some() {
            self.bump(phase);
        }
    }

    fn job_removed(&mut self, id: JobId, _now: Time) {
        for phase in [Phase::Map, Phase::Reduce] {
            if self.attained[phase_idx(phase)].remove(&id).is_some() {
                self.bump(phase);
            }
        }
    }

    fn advance(&mut self, _now: Time) {}

    fn generation(&self, phase: Phase) -> u64 {
        self.generation[phase_idx(phase)]
    }

    fn order(&mut self, phase: Phase) -> &[(JobId, f64)] {
        let i = phase_idx(phase);
        self.cache[i].get_or_rebuild(self.attained[i].iter().map(|(&id, &a)| (id, a)))
    }
}
