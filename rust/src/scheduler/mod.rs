//! Job schedulers: the `Scheduler` trait, the size-based
//! mechanism/policy split, and the discipline registry.
//!
//! ## Contract
//!
//! Schedulers are **heartbeat-driven**, exactly like Hadoop's JobTracker
//! (§2.2): all task placement and preemption decisions are emitted from
//! [`Scheduler::on_heartbeat`] in response to a single TaskTracker's
//! heartbeat, as an ordered list of [`Action`]s. The driver applies the
//! actions in order, validating each against live cluster state (a
//! `Suspend` earlier in the batch frees the slot a later `Launch` in the
//! same batch uses).
//!
//! Schedulers never see ground-truth task durations — only completion
//! observations ([`Scheduler::on_task_completed`]) and the Δ-progress
//! reports used by the reduce estimator
//! ([`Scheduler::on_reduce_progress`], §3.2.1 of the paper).
//!
//! ## Mechanism vs policy
//!
//! Two schedulers are self-contained ([`fifo`], [`fair`]); every
//! size-based discipline instead runs on the shared **mechanism** in
//! [`core`] (estimation, training, virtual time, preemption) with a
//! pluggable ordering **policy** from [`disciplines`] (FSP = HFSP,
//! SRPT, LAS, PSBS). The [`REGISTRY`] table is the single source of
//! truth for scheduler names, labels and construction — the CLI help,
//! `from_name` parsing and the "unknown scheduler" error are all derived
//! from it.

pub mod core;
pub mod delay;
pub mod disciplines;
pub mod fair;
pub mod fifo;
pub mod hierarchy;

/// Back-compat facade: HFSP is the size-based [`core`] driven by the
/// FSP discipline. Historical import paths (`scheduler::hfsp::training`,
/// `scheduler::hfsp::HfspConfig`, …) resolve here.
///
/// Deprecated: import from [`core`] / [`disciplines`] directly, and
/// drive runs through the [`Simulation`](crate::session::Simulation)
/// builder.
#[deprecated(
    since = "0.1.0",
    note = "use scheduler::core / scheduler::disciplines (and the session::Simulation builder) instead"
)]
pub mod hfsp {
    //! HFSP — the Hadoop Fair Sojourn Protocol (§3 of the paper), as a
    //! facade over [`super::core`] + [`super::disciplines::fsp`].
    pub use super::core::{estimator, preemption, training, virtual_cluster, xla_estimator};
    pub use super::core::{
        EstimatorKind, HfspConfig, MaxMinKind, PreemptionPrimitive, SizeBasedConfig,
        SuspensionGuard,
    };

    /// HFSP = the size-based mechanism with [`FspDiscipline`]
    /// (`SizeBasedConfig::default()` selects it).
    pub type HfspScheduler = super::core::SizeBasedScheduler;
    pub use super::disciplines::FspDiscipline;
}

use crate::cluster::{Cluster, Hdfs};
use crate::job::task::NodeId;
use crate::job::{Job, JobId, JobTable, TaskRef};
use crate::sim::Time;
use self::disciplines::DisciplineKind;
use std::sync::OnceLock;

/// Read-only view of the world handed to schedulers.
///
/// `jobs` is the driver's arena-backed [`JobTable`]: id lookups are O(1)
/// hashing into dense slab storage, iteration is id (= submission)
/// order — the per-event hot path never walks a tree.
pub struct SchedView<'a> {
    pub jobs: &'a JobTable,
    pub cluster: &'a Cluster,
    pub hdfs: &'a Hdfs,
    pub now: Time,
}

impl<'a> SchedView<'a> {
    /// Jobs still in the system (arrived, not finished), in id
    /// (= submission) order.
    pub fn active_jobs(&self) -> impl Iterator<Item = &Job> {
        self.jobs.values().filter(|j| !j.is_finished())
    }

    /// Whether a map task would read local data on `node`.
    pub fn is_local(&self, node: NodeId, task: TaskRef) -> bool {
        self.hdfs.is_local(node, task)
    }
}

/// Compact per-shard scheduling-demand summary, shipped from shard
/// workers to the coordinator at every window boundary of a sharded run
/// (fast merge mode). The coordinator routes new arrivals from the
/// *merged* digests — it never touches a shard's live `SchedView` — so
/// the hot path stays lock-free: digests are plain `Copy` data moved
/// through the window MPSC channels.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DemandDigest {
    /// Jobs arrived and not yet finished on the shard.
    pub live_jobs: usize,
    /// Map tasks waiting for a slot.
    pub pending_maps: usize,
    /// Reduce tasks waiting for a slot.
    pub pending_reduces: usize,
    /// Free map slots on the shard's nodes.
    pub free_map_slots: usize,
    /// Free reduce slots on the shard's nodes.
    pub free_reduce_slots: usize,
    /// Live jobs the shard could donate at the next barrier: still
    /// completely untouched (no task of either phase ever launched), so
    /// moving one to another shard carries no per-shard state. The
    /// coordinator's work-stealing pass sizes its requests from this.
    pub stealable_jobs: usize,
}

impl DemandDigest {
    /// Snapshot the digest from a shard's live state.
    pub fn snapshot(jobs: &JobTable, cluster: &Cluster) -> Self {
        use crate::job::Phase;
        let mut d = DemandDigest {
            free_map_slots: cluster.free_slots(Phase::Map),
            free_reduce_slots: cluster.free_slots(Phase::Reduce),
            ..Default::default()
        };
        for job in jobs.values() {
            if job.is_finished() {
                continue;
            }
            d.live_jobs += 1;
            d.pending_maps += job.pending_tasks(Phase::Map);
            d.pending_reduces += job.pending_tasks(Phase::Reduce);
            if job.is_untouched() {
                d.stealable_jobs += 1;
            }
        }
        d
    }

    /// Fold another shard's digest into this one (the coordinator's
    /// cluster-wide view is the sum over shards).
    pub fn merge(&mut self, other: &DemandDigest) {
        self.live_jobs += other.live_jobs;
        self.pending_maps += other.pending_maps;
        self.pending_reduces += other.pending_reduces;
        self.free_map_slots += other.free_map_slots;
        self.free_reduce_slots += other.free_reduce_slots;
        self.stealable_jobs += other.stealable_jobs;
    }

    /// Whether the shard is overloaded: queued map work with no free map
    /// slot. The coordinator prefers routing new jobs away from (and
    /// accepting spillover from) such shards.
    pub fn saturated(&self) -> bool {
        self.free_map_slots == 0 && self.pending_maps > 0
    }
}

/// A scheduling decision applied by the driver.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Action {
    /// Launch a pending task on a node (occupies one slot of the task's
    /// phase). `local` is the scheduler's locality determination — recorded
    /// in metrics; the driver asserts it matches HDFS for map tasks.
    Launch { task: TaskRef, node: NodeId, local: bool },
    /// SIGSTOP a running task (frees its slot, parks the context).
    Suspend { task: TaskRef },
    /// SIGCONT a suspended task on the node holding its context.
    Resume { task: TaskRef },
    /// Kill a running or suspended task: all its work is lost and it
    /// returns to the pending queue.
    Kill { task: TaskRef },
}

/// Scheduler interface implemented by FIFO, FAIR and the size-based
/// core.
pub trait Scheduler {
    fn name(&self) -> &'static str;

    /// A job was submitted.
    fn on_job_arrival(&mut self, view: &SchedView, job: JobId);

    /// A task attempt completed. `observed_duration` is the measured task
    /// runtime (serialized work — what Hadoop's counters report).
    fn on_task_completed(&mut self, view: &SchedView, task: TaskRef, observed_duration: f64);

    /// Progress report from a reduce task that has executed for Δ seconds:
    /// `progress` is the fraction of its input processed (available once
    /// all maps finished, §3.2.1). Default: ignored.
    fn on_reduce_progress(&mut self, view: &SchedView, task: TaskRef, delta: f64, progress: f64) {
        let _ = (view, task, delta, progress);
    }

    /// A job's last task completed.
    fn on_job_finished(&mut self, view: &SchedView, job: JobId) {
        let _ = (view, job);
    }

    /// Heartbeat from `node`: push actions to apply, in order, onto
    /// `actions` (a cleared, reusable buffer owned by the driver — the
    /// hot path allocates no per-heartbeat `Vec`).
    fn on_heartbeat(&mut self, view: &SchedView, node: NodeId, actions: &mut Vec<Action>);
}

/// Factory enum used by the CLI, benches and examples.
#[derive(Clone, Debug)]
pub enum SchedulerKind {
    Fifo,
    Fair(fair::FairConfig),
    /// Any size-based discipline on the shared mechanism
    /// ([`core::SizeBasedScheduler`]); `cfg.discipline` selects which.
    SizeBased(core::SizeBasedConfig),
    /// Multi-tenant pools → users → jobs tree
    /// ([`hierarchy::HierarchicalScheduler`]); a single-leaf topology
    /// lowers to the flat size-based scheduler at build time, so its
    /// outcomes are byte-identical to [`SchedulerKind::SizeBased`].
    Hierarchical(hierarchy::HierarchyConfig),
}

/// One row of the scheduler [`REGISTRY`].
pub struct SchedulerEntry {
    /// Canonical CLI token (`--scheduler`, sweep axis values).
    pub name: &'static str,
    /// Report/table label (sweep group keys, `SimOutcome::scheduler`).
    pub label: &'static str,
    /// One-line description (CLI help).
    pub about: &'static str,
    make: fn() -> SchedulerKind,
}

impl SchedulerEntry {
    /// Build the scheduler kind with its default configuration.
    pub fn make(&self) -> SchedulerKind {
        (self.make)()
    }
}

fn make_fifo() -> SchedulerKind {
    SchedulerKind::Fifo
}
fn make_fair() -> SchedulerKind {
    SchedulerKind::Fair(fair::FairConfig::default())
}
fn make_hfsp() -> SchedulerKind {
    SchedulerKind::size_based(DisciplineKind::Fsp)
}
fn make_srpt() -> SchedulerKind {
    SchedulerKind::size_based(DisciplineKind::Srpt)
}
fn make_las() -> SchedulerKind {
    SchedulerKind::size_based(DisciplineKind::Las)
}
fn make_psbs() -> SchedulerKind {
    SchedulerKind::size_based(DisciplineKind::Psbs)
}
fn make_hier() -> SchedulerKind {
    SchedulerKind::Hierarchical(hierarchy::HierarchyConfig::default())
}

/// The single source of truth for registered schedulers: drives
/// [`SchedulerKind::from_name`], the CLI help ([`SchedulerKind::cli_help`])
/// and the "unknown scheduler" error message. Adding a discipline means
/// adding one row here (plus its `disciplines` implementation) — no
/// hand-maintained name/label/error triplication.
pub static REGISTRY: &[SchedulerEntry] = &[
    SchedulerEntry {
        name: "fifo",
        label: "FIFO",
        about: "Hadoop's default FIFO queue (no preemption)",
        make: make_fifo,
    },
    SchedulerEntry {
        name: "fair",
        label: "FAIR",
        about: "Hadoop Fair Scheduler with delay scheduling",
        make: make_fair,
    },
    SchedulerEntry {
        name: DisciplineKind::Fsp.cli_name(),
        label: DisciplineKind::Fsp.label(),
        about: "size-based core + FSP ordering (the paper's HFSP)",
        make: make_hfsp,
    },
    SchedulerEntry {
        name: DisciplineKind::Srpt.cli_name(),
        label: DisciplineKind::Srpt.label(),
        about: "size-based core + shortest-remaining-estimated-size",
        make: make_srpt,
    },
    SchedulerEntry {
        name: DisciplineKind::Las.cli_name(),
        label: DisciplineKind::Las.label(),
        about: "size-based core + least attained service (size-oblivious)",
        make: make_las,
    },
    SchedulerEntry {
        name: DisciplineKind::Psbs.cli_name(),
        label: DisciplineKind::Psbs.label(),
        about: "size-based core + PSBS-style late-binding virtual time",
        make: make_psbs,
    },
    SchedulerEntry {
        name: "hier",
        label: "HIER",
        about: "hierarchical pools → users → jobs (weighted fair tree, per-pool disciplines)",
        make: make_hier,
    },
];

impl SchedulerKind {
    /// A size-based kind with default mechanism parameters and the given
    /// ordering discipline.
    pub fn size_based(discipline: DisciplineKind) -> SchedulerKind {
        SchedulerKind::SizeBased(core::SizeBasedConfig {
            discipline,
            ..Default::default()
        })
    }

    /// HFSP with default configuration (= `size_based(Fsp)`).
    pub fn hfsp() -> SchedulerKind {
        Self::size_based(DisciplineKind::Fsp)
    }

    pub fn build(&self) -> Box<dyn Scheduler> {
        match self {
            SchedulerKind::Fifo => Box::new(fifo::FifoScheduler::new()),
            SchedulerKind::Fair(cfg) => Box::new(fair::FairScheduler::new(cfg.clone())),
            SchedulerKind::SizeBased(cfg) => {
                Box::new(core::SizeBasedScheduler::new(cfg.clone()))
            }
            SchedulerKind::Hierarchical(cfg) => match cfg.flat_equivalent() {
                // Degenerate single-pool tree: build the flat scheduler
                // itself, so the outcome (label included) is the flat
                // outcome, byte for byte.
                Some(flat) => Box::new(core::SizeBasedScheduler::new(flat)),
                None => Box::new(hierarchy::HierarchicalScheduler::new(cfg.clone())),
            },
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            SchedulerKind::Fifo => "FIFO",
            SchedulerKind::Fair(_) => "FAIR",
            SchedulerKind::SizeBased(cfg) => cfg.discipline.label(),
            SchedulerKind::Hierarchical(cfg) => match cfg.flat_equivalent() {
                Some(flat) => flat.discipline.label(),
                None => "HIER",
            },
        }
    }

    /// Wire a fault scenario's size-estimation error (log-normal σ) into
    /// a size-based kind, seeded deterministically from the run seed —
    /// the error model applies to *every* size-based discipline, not
    /// just HFSP (size-oblivious LAS carries no estimator, so the
    /// setting is inert there). No-op for FIFO/FAIR, for σ = 0, and when
    /// the config already carries an explicit error setting (e.g. the
    /// Fig. 6 bench).
    pub fn apply_fault_error(&mut self, sigma: f64, seed: u64) {
        if sigma <= 0.0 {
            return;
        }
        let cfg = match self {
            SchedulerKind::SizeBased(cfg) => cfg,
            // The hierarchy's leaves inherit the base mechanism config,
            // so the error model reaches every pool's estimator.
            SchedulerKind::Hierarchical(h) => &mut h.base,
            _ => return,
        };
        if cfg.error_alpha == 0.0 && cfg.error_sigma == 0.0 {
            cfg.error_sigma = sigma;
            // Fixed tweak decorrelates the error stream from the
            // workload/placement streams derived from the same seed.
            cfg.error_seed = seed ^ 0xE57A_11FE;
        }
    }

    /// Registered CLI names, in registry order.
    pub fn names() -> impl Iterator<Item = &'static str> {
        REGISTRY.iter().map(|e| e.name)
    }

    /// `"fifo | fair | hfsp | srpt | las | psbs"` — registry-derived CLI
    /// help fragment, built once into a process-lifetime static (flag
    /// specs need `&'static str`).
    pub fn cli_help() -> &'static str {
        static HELP: OnceLock<String> = OnceLock::new();
        HELP.get_or_init(|| Self::names().collect::<Vec<_>>().join(" | "))
            .as_str()
    }

    /// `"comma-separated scheduler list: fifo,fair,hfsp,srpt,las,psbs"`
    /// — help text for list-valued flags (sweep `--schedulers`).
    pub fn cli_help_list() -> &'static str {
        static HELP: OnceLock<String> = OnceLock::new();
        HELP.get_or_init(|| {
            format!(
                "comma-separated scheduler list: {}",
                Self::names().collect::<Vec<_>>().join(",")
            )
        })
        .as_str()
    }

    /// Parse from a CLI string. The error lists every registered
    /// scheduler, straight from [`REGISTRY`].
    pub fn from_name(name: &str) -> anyhow::Result<SchedulerKind> {
        let lower = name.to_ascii_lowercase();
        for entry in REGISTRY {
            if entry.name == lower {
                return Ok(entry.make());
            }
        }
        anyhow::bail!(
            "unknown scheduler {name:?} (expected one of: {})",
            Self::names().collect::<Vec<_>>().join(", ")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn demand_digest_merges_and_flags_saturation() {
        let mut total = DemandDigest::default();
        assert!(!total.saturated(), "an idle shard is not saturated");
        let a = DemandDigest {
            live_jobs: 2,
            pending_maps: 5,
            pending_reduces: 1,
            free_map_slots: 0,
            free_reduce_slots: 2,
            stealable_jobs: 1,
        };
        let b = DemandDigest {
            live_jobs: 1,
            pending_maps: 0,
            pending_reduces: 0,
            free_map_slots: 4,
            free_reduce_slots: 2,
            stealable_jobs: 0,
        };
        assert!(a.saturated());
        assert!(!b.saturated());
        total.merge(&a);
        total.merge(&b);
        assert_eq!(total.live_jobs, 3);
        assert_eq!(total.pending_maps, 5);
        assert_eq!(total.free_map_slots, 4);
        assert_eq!(total.free_reduce_slots, 4);
        assert_eq!(total.stealable_jobs, 1);
    }

    #[test]
    fn registry_names_parse_to_matching_labels() {
        // The registry is the single source of truth: every row's name
        // must parse, and the built kind's label must equal the row's.
        for entry in REGISTRY {
            let kind = SchedulerKind::from_name(entry.name).expect("registered name parses");
            assert_eq!(kind.label(), entry.label, "label mismatch for {}", entry.name);
            assert_eq!(entry.make().label(), entry.label);
            assert!(!entry.about.is_empty());
        }
    }

    #[test]
    fn from_name_is_case_insensitive_and_lists_all_on_error() {
        assert_eq!(SchedulerKind::from_name("HFSP").unwrap().label(), "HFSP");
        assert_eq!(SchedulerKind::from_name("Srpt").unwrap().label(), "SRPT");
        let err = SchedulerKind::from_name("bogus").unwrap_err().to_string();
        for entry in REGISTRY {
            assert!(
                err.contains(entry.name),
                "error message must list {:?}: {err}",
                entry.name
            );
        }
    }

    #[test]
    fn cli_help_covers_the_registry() {
        for help in [SchedulerKind::cli_help(), SchedulerKind::cli_help_list()] {
            for entry in REGISTRY {
                assert!(help.contains(entry.name), "{help:?} misses {}", entry.name);
            }
        }
        assert!(SchedulerKind::cli_help_list().starts_with("comma-separated"));
    }

    #[test]
    fn hfsp_default_is_the_fsp_discipline() {
        let SchedulerKind::SizeBased(cfg) = SchedulerKind::from_name("hfsp").unwrap() else {
            panic!("hfsp must be size-based");
        };
        assert_eq!(cfg.discipline, DisciplineKind::Fsp);
        assert_eq!(SchedulerKind::hfsp().label(), "HFSP");
    }

    #[test]
    fn hierarchical_label_lowers_for_single_pool_topologies() {
        let single =
            SchedulerKind::Hierarchical(hierarchy::HierarchyConfig::single(DisciplineKind::Las));
        assert_eq!(single.label(), "LAS", "degenerate tree reports its leaf");
        assert_eq!(SchedulerKind::from_name("hier").unwrap().label(), "HIER");
    }

    #[test]
    fn fault_error_reaches_the_hierarchy_base_config() {
        let mut k = SchedulerKind::Hierarchical(hierarchy::HierarchyConfig::default());
        k.apply_fault_error(0.5, 42);
        let SchedulerKind::Hierarchical(h) = &k else { unreachable!() };
        assert_eq!(h.base.error_sigma, 0.5);
        assert_eq!(h.base.error_seed, 42 ^ 0xE57A_11FE);
    }

    #[test]
    fn fault_error_applies_to_every_size_based_discipline() {
        for kind in DisciplineKind::ALL {
            let mut k = SchedulerKind::size_based(kind);
            k.apply_fault_error(0.5, 42);
            let SchedulerKind::SizeBased(cfg) = &k else { unreachable!() };
            assert_eq!(cfg.error_sigma, 0.5, "{kind:?}");
            assert_eq!(cfg.error_seed, 42 ^ 0xE57A_11FE);
        }
        // Explicit settings win; FIFO/FAIR are no-ops.
        let mut k = SchedulerKind::SizeBased(core::SizeBasedConfig {
            error_alpha: 0.3,
            ..Default::default()
        });
        k.apply_fault_error(0.5, 1);
        let SchedulerKind::SizeBased(cfg) = &k else { unreachable!() };
        assert_eq!(cfg.error_sigma, 0.0);
        let mut f = SchedulerKind::Fifo;
        f.apply_fault_error(0.5, 1);
        assert_eq!(f.label(), "FIFO");
    }
}
