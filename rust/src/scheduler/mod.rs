//! Job schedulers: the `Scheduler` trait plus the three disciplines the
//! paper evaluates — FIFO (Hadoop's default), FAIR (the Hadoop Fair
//! Scheduler with delay scheduling) and HFSP (the paper's contribution).
//!
//! ## Contract
//!
//! Schedulers are **heartbeat-driven**, exactly like Hadoop's JobTracker
//! (§2.2): all task placement and preemption decisions are emitted from
//! [`Scheduler::on_heartbeat`] in response to a single TaskTracker's
//! heartbeat, as an ordered list of [`Action`]s. The driver applies the
//! actions in order, validating each against live cluster state (a
//! `Suspend` earlier in the batch frees the slot a later `Launch` in the
//! same batch uses).
//!
//! Schedulers never see ground-truth task durations — only completion
//! observations ([`Scheduler::on_task_completed`]) and the Δ-progress
//! reports used by the reduce estimator
//! ([`Scheduler::on_reduce_progress`], §3.2.1 of the paper).

pub mod delay;
pub mod fair;
pub mod fifo;
pub mod hfsp;

use crate::cluster::{Cluster, Hdfs};
use crate::job::{Job, JobId, TaskRef};
use crate::job::task::NodeId;
use crate::sim::Time;
use std::collections::BTreeMap;

/// Read-only view of the world handed to schedulers.
pub struct SchedView<'a> {
    pub jobs: &'a BTreeMap<JobId, Job>,
    pub cluster: &'a Cluster,
    pub hdfs: &'a Hdfs,
    pub now: Time,
}

impl<'a> SchedView<'a> {
    /// Jobs still in the system (arrived, not finished), in id
    /// (= submission) order.
    pub fn active_jobs(&self) -> impl Iterator<Item = &Job> {
        self.jobs.values().filter(|j| !j.is_finished())
    }

    /// Whether a map task would read local data on `node`.
    pub fn is_local(&self, node: NodeId, task: TaskRef) -> bool {
        self.hdfs.is_local(node, task)
    }
}

/// A scheduling decision applied by the driver.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Action {
    /// Launch a pending task on a node (occupies one slot of the task's
    /// phase). `local` is the scheduler's locality determination — recorded
    /// in metrics; the driver asserts it matches HDFS for map tasks.
    Launch { task: TaskRef, node: NodeId, local: bool },
    /// SIGSTOP a running task (frees its slot, parks the context).
    Suspend { task: TaskRef },
    /// SIGCONT a suspended task on the node holding its context.
    Resume { task: TaskRef },
    /// Kill a running or suspended task: all its work is lost and it
    /// returns to the pending queue.
    Kill { task: TaskRef },
}

/// Scheduler interface implemented by FIFO, FAIR and HFSP.
pub trait Scheduler {
    fn name(&self) -> &'static str;

    /// A job was submitted.
    fn on_job_arrival(&mut self, view: &SchedView, job: JobId);

    /// A task attempt completed. `observed_duration` is the measured task
    /// runtime (serialized work — what Hadoop's counters report).
    fn on_task_completed(&mut self, view: &SchedView, task: TaskRef, observed_duration: f64);

    /// Progress report from a reduce task that has executed for Δ seconds:
    /// `progress` is the fraction of its input processed (available once
    /// all maps finished, §3.2.1). Default: ignored.
    fn on_reduce_progress(&mut self, view: &SchedView, task: TaskRef, delta: f64, progress: f64) {
        let _ = (view, task, delta, progress);
    }

    /// A job's last task completed.
    fn on_job_finished(&mut self, view: &SchedView, job: JobId) {
        let _ = (view, job);
    }

    /// Heartbeat from `node`: return actions to apply, in order.
    fn on_heartbeat(&mut self, view: &SchedView, node: NodeId) -> Vec<Action>;
}

/// Factory enum used by the CLI, benches and examples.
#[derive(Clone, Debug)]
pub enum SchedulerKind {
    Fifo,
    Fair(fair::FairConfig),
    Hfsp(hfsp::HfspConfig),
}

impl SchedulerKind {
    pub fn build(&self) -> Box<dyn Scheduler> {
        match self {
            SchedulerKind::Fifo => Box::new(fifo::FifoScheduler::new()),
            SchedulerKind::Fair(cfg) => Box::new(fair::FairScheduler::new(cfg.clone())),
            SchedulerKind::Hfsp(cfg) => Box::new(hfsp::HfspScheduler::new(cfg.clone())),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            SchedulerKind::Fifo => "FIFO",
            SchedulerKind::Fair(_) => "FAIR",
            SchedulerKind::Hfsp(_) => "HFSP",
        }
    }

    /// Wire a fault scenario's size-estimation error (log-normal σ) into
    /// an HFSP kind, seeded deterministically from the run seed. No-op
    /// for other schedulers, for σ = 0, and when the config already
    /// carries an explicit error setting (e.g. the Fig. 6 bench).
    pub fn apply_fault_error(&mut self, sigma: f64, seed: u64) {
        if sigma <= 0.0 {
            return;
        }
        if let SchedulerKind::Hfsp(cfg) = self {
            if cfg.error_alpha == 0.0 && cfg.error_sigma == 0.0 {
                cfg.error_sigma = sigma;
                // Fixed tweak decorrelates the error stream from the
                // workload/placement streams derived from the same seed.
                cfg.error_seed = seed ^ 0xE57A_11FE;
            }
        }
    }

    /// Parse from a CLI string (`fifo`, `fair`, `hfsp`).
    pub fn from_name(name: &str) -> anyhow::Result<SchedulerKind> {
        match name.to_ascii_lowercase().as_str() {
            "fifo" => Ok(SchedulerKind::Fifo),
            "fair" => Ok(SchedulerKind::Fair(fair::FairConfig::default())),
            "hfsp" => Ok(SchedulerKind::Hfsp(hfsp::HfspConfig::default())),
            other => anyhow::bail!("unknown scheduler {other:?} (fifo|fair|hfsp)"),
        }
    }
}
