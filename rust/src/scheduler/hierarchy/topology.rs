//! Pool topology: the static shape of the fair-share tree.
//!
//! A topology is a forest of named, weighted pools attached to a
//! synthetic root (node 0). Interior pools only split capacity between
//! their children; **leaf** pools run a scheduling discipline over the
//! jobs routed to them. Tenants ([`crate::job::TenantId`]) are mapped to
//! leaves by `pool_id % n_leaves`, so a workload generator can address
//! pools without knowing their names.
//!
//! Topologies come from three places, all funnelled through
//! [`Topology::from_arg`]: the built-in `"single"` (one HFSP pool — the
//! degenerate hierarchy, byte-identical to the flat scheduler), the
//! built-in `"example"` (3 pools, weights 3/2/1, three disciplines) and
//! a JSON file:
//!
//! ```json
//! {"pools": [
//!   {"name": "prod",  "weight": 3.0, "discipline": "hfsp"},
//!   {"name": "batch", "weight": 2.0, "discipline": "srpt"},
//!   {"name": "adhoc", "parent": "batch", "weight": 1.0}
//! ]}
//! ```
//!
//! `parent` is optional (defaults to the root); `discipline` is optional
//! on leaves (defaults to `hfsp`) and **rejected** on interior pools.
//! Malformed input — unknown parent, non-positive weight, duplicate
//! name, parent cycle — is a hard [`anyhow`] error surfaced through the
//! CLI; there are no silent defaults and no panics.

use crate::scheduler::disciplines::DisciplineKind;
use anyhow::{bail, Context};

/// Index of the synthetic root in [`Topology::nodes`].
pub const ROOT: usize = 0;

/// One pool in the tree (the synthetic root is a `PoolNode` too, with an
/// empty name and weight 1).
#[derive(Clone, Debug)]
pub struct PoolNode {
    pub name: String,
    /// Parent node index (the root points at itself).
    pub parent: usize,
    /// Fair-share weight relative to siblings (> 0, finite).
    pub weight: f64,
    /// Child node indices, in declaration order.
    pub children: Vec<usize>,
    /// Leaf discipline; `None` for interior pools and the root.
    pub discipline: Option<DisciplineKind>,
    /// Dense leaf ordinal (`None` for interior pools and the root).
    pub leaf_index: Option<usize>,
}

/// A validated pool tree. Construction (from JSON or the builders) is
/// the only way to obtain one, so every `Topology` in the program
/// satisfies the structural invariants: unique names, positive finite
/// weights, acyclic parent links, at least one leaf.
#[derive(Clone, Debug)]
pub struct Topology {
    nodes: Vec<PoolNode>,
    /// Node index of each leaf, in declaration order.
    leaves: Vec<usize>,
}

impl Topology {
    /// The degenerate hierarchy: one pool (weight 1) running `discipline`.
    /// [`crate::scheduler::SchedulerKind::build`] lowers it to the flat
    /// [`crate::scheduler::core::SizeBasedScheduler`], so outcomes are
    /// byte-identical to the non-hierarchical scheduler.
    pub fn single_pool(discipline: DisciplineKind) -> Topology {
        Self::from_pools(vec![PoolDecl {
            name: "default".into(),
            parent: None,
            weight: 1.0,
            discipline: Some(discipline),
        }])
        .expect("the single-pool topology is statically valid")
    }

    /// The built-in 3-pool example: `prod` (weight 3, HFSP), `batch`
    /// (weight 2, SRPT), `adhoc` (weight 1, LAS) — one leaf per
    /// discipline family, weights matching the ISSUE's convergence
    /// scenario.
    pub fn example() -> Topology {
        Self::from_pools(vec![
            PoolDecl {
                name: "prod".into(),
                parent: None,
                weight: 3.0,
                discipline: Some(DisciplineKind::Fsp),
            },
            PoolDecl {
                name: "batch".into(),
                parent: None,
                weight: 2.0,
                discipline: Some(DisciplineKind::Srpt),
            },
            PoolDecl {
                name: "adhoc".into(),
                parent: None,
                weight: 1.0,
                discipline: Some(DisciplineKind::Las),
            },
        ])
        .expect("the example topology is statically valid")
    }

    /// Resolve a CLI `--pools` argument: the builtin names `"single"`
    /// and `"example"`, or a path to a topology JSON file.
    pub fn from_arg(arg: &str) -> anyhow::Result<Topology> {
        match arg {
            "single" => Ok(Self::single_pool(DisciplineKind::Fsp)),
            "example" => Ok(Self::example()),
            path => {
                let text = std::fs::read_to_string(path)
                    .with_context(|| format!("reading pool topology file {path:?}"))?;
                Self::from_json_str(&text)
                    .with_context(|| format!("parsing pool topology file {path:?}"))
            }
        }
    }

    /// Parse and validate a topology from its JSON document.
    pub fn from_json_str(text: &str) -> anyhow::Result<Topology> {
        let doc = crate::util::json::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let pools = doc
            .get("pools")
            .and_then(|p| p.as_arr())
            .ok_or_else(|| anyhow::anyhow!("topology must be an object with a \"pools\" array"))?;
        let mut decls = Vec::with_capacity(pools.len());
        for (i, p) in pools.iter().enumerate() {
            let name = p
                .get("name")
                .and_then(|n| n.as_str())
                .ok_or_else(|| anyhow::anyhow!("pool #{i} is missing a string \"name\""))?
                .to_string();
            let weight = match p.get("weight") {
                Some(w) => w
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("pool {name:?}: \"weight\" must be a number"))?,
                None => 1.0,
            };
            let parent = match p.get("parent") {
                Some(v) => Some(
                    v.as_str()
                        .ok_or_else(|| {
                            anyhow::anyhow!("pool {name:?}: \"parent\" must be a string")
                        })?
                        .to_string(),
                ),
                None => None,
            };
            let discipline = match p.get("discipline") {
                Some(v) => {
                    let s = v.as_str().ok_or_else(|| {
                        anyhow::anyhow!("pool {name:?}: \"discipline\" must be a string")
                    })?;
                    Some(parse_discipline(&name, s)?)
                }
                None => None,
            };
            decls.push(PoolDecl {
                name,
                parent,
                weight,
                discipline,
            });
        }
        Self::from_pools(decls)
    }

    /// Build and validate from declarations. All the hard-error cases the
    /// ISSUE names live here: unknown parent, non-positive weight,
    /// duplicate name, parent cycle.
    pub fn from_pools(decls: Vec<PoolDecl>) -> anyhow::Result<Topology> {
        if decls.is_empty() {
            bail!("topology has no pools");
        }
        // Pool i lives at node index i + 1 (the root occupies 0).
        let mut nodes = vec![PoolNode {
            name: String::new(),
            parent: ROOT,
            weight: 1.0,
            children: Vec::new(),
            discipline: None,
            leaf_index: None,
        }];
        let mut by_name = std::collections::BTreeMap::new();
        for (i, d) in decls.iter().enumerate() {
            if d.name.is_empty() {
                bail!("pool #{i} has an empty name");
            }
            if by_name.insert(d.name.clone(), i + 1).is_some() {
                bail!("duplicate pool name {:?}", d.name);
            }
            if !(d.weight > 0.0 && d.weight.is_finite()) {
                bail!(
                    "pool {:?} has non-positive weight {} (weights must be > 0)",
                    d.name,
                    d.weight
                );
            }
        }
        for d in &decls {
            let parent = match &d.parent {
                None => ROOT,
                Some(p) => *by_name.get(p).ok_or_else(|| {
                    anyhow::anyhow!("pool {:?} names unknown parent {p:?}", d.name)
                })?,
            };
            nodes.push(PoolNode {
                name: d.name.clone(),
                parent,
                weight: d.weight,
                children: Vec::new(),
                discipline: d.discipline,
                leaf_index: None,
            });
        }
        // Cycle check: every pool must reach the root within n hops.
        let n = nodes.len();
        for start in 1..n {
            let mut cur = start;
            let mut hops = 0;
            while cur != ROOT {
                cur = nodes[cur].parent;
                hops += 1;
                if hops > n {
                    bail!(
                        "pool {:?} is part of a parent cycle (never reaches the root)",
                        nodes[start].name
                    );
                }
            }
        }
        // Wire children; classify leaves.
        for i in 1..n {
            let parent = nodes[i].parent;
            nodes[parent].children.push(i);
        }
        let mut leaves = Vec::new();
        for i in 1..n {
            if nodes[i].children.is_empty() {
                nodes[i].leaf_index = Some(leaves.len());
                if nodes[i].discipline.is_none() {
                    nodes[i].discipline = Some(DisciplineKind::default());
                }
                leaves.push(i);
            } else if nodes[i].discipline.is_some() {
                bail!(
                    "pool {:?} has children but also names a discipline \
                     (disciplines run on leaf pools only)",
                    nodes[i].name
                );
            }
        }
        Ok(Topology { nodes, leaves })
    }

    /// All nodes, root first. Indices returned by [`PoolNode::parent`] /
    /// [`PoolNode::children`] index into this slice.
    pub fn nodes(&self) -> &[PoolNode] {
        &self.nodes
    }

    pub fn n_leaves(&self) -> usize {
        self.leaves.len()
    }

    /// The leaf pool with dense ordinal `leaf` (`0..n_leaves`).
    pub fn leaf(&self, leaf: usize) -> &PoolNode {
        &self.nodes[self.leaves[leaf]]
    }

    /// Node index of leaf ordinal `leaf`.
    pub fn leaf_node(&self, leaf: usize) -> usize {
        self.leaves[leaf]
    }

    /// Route a tenant's pool id to a leaf ordinal (`pool % n_leaves`,
    /// so any u32 pool id from a workload generator lands somewhere).
    pub fn leaf_for_pool(&self, pool: u32) -> usize {
        (pool as usize) % self.leaves.len()
    }
}

/// One pool as declared (pre-validation) — the programmatic equivalent
/// of one entry in the JSON `"pools"` array.
#[derive(Clone, Debug)]
pub struct PoolDecl {
    pub name: String,
    /// Parent pool name; `None` attaches to the synthetic root.
    pub parent: Option<String>,
    pub weight: f64,
    /// Leaf discipline; `None` defaults to HFSP on leaves.
    pub discipline: Option<DisciplineKind>,
}

fn parse_discipline(pool: &str, s: &str) -> anyhow::Result<DisciplineKind> {
    let lower = s.to_ascii_lowercase();
    for kind in DisciplineKind::ALL {
        if kind.cli_name() == lower {
            return Ok(kind);
        }
    }
    bail!(
        "pool {pool:?} names unknown discipline {s:?} (expected one of: {})",
        DisciplineKind::ALL
            .iter()
            .map(|k| k.cli_name())
            .collect::<Vec<_>>()
            .join(", ")
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn example_topology_shape() {
        let t = Topology::example();
        assert_eq!(t.n_leaves(), 3);
        assert_eq!(t.leaf(0).name, "prod");
        assert_eq!(t.leaf(0).weight, 3.0);
        assert_eq!(t.leaf(0).discipline, Some(DisciplineKind::Fsp));
        assert_eq!(t.leaf(1).discipline, Some(DisciplineKind::Srpt));
        assert_eq!(t.leaf(2).discipline, Some(DisciplineKind::Las));
        // All three hang off the root.
        assert_eq!(t.nodes()[ROOT].children.len(), 3);
        // Pool-id routing wraps.
        assert_eq!(t.leaf_for_pool(0), 0);
        assert_eq!(t.leaf_for_pool(4), 1);
    }

    #[test]
    fn single_pool_defaults() {
        let t = Topology::single_pool(DisciplineKind::Srpt);
        assert_eq!(t.n_leaves(), 1);
        assert_eq!(t.leaf(0).discipline, Some(DisciplineKind::Srpt));
        assert_eq!(t.leaf_for_pool(917), 0);
    }

    #[test]
    fn parses_nested_json_with_defaults() {
        let t = Topology::from_json_str(
            r#"{"pools": [
                {"name": "org", "weight": 2},
                {"name": "etl", "parent": "org", "discipline": "srpt"},
                {"name": "ml",  "parent": "org", "weight": 3},
                {"name": "misc"}
            ]}"#,
        )
        .unwrap();
        assert_eq!(t.n_leaves(), 3, "org is interior; etl/ml/misc are leaves");
        assert_eq!(t.leaf(0).name, "etl");
        assert_eq!(t.leaf(0).weight, 1.0, "weight defaults to 1");
        assert_eq!(t.leaf(0).discipline, Some(DisciplineKind::Srpt));
        assert_eq!(t.leaf(1).discipline, Some(DisciplineKind::Fsp), "leaf discipline defaults to hfsp");
        let org = t.nodes().iter().position(|n| n.name == "org").unwrap();
        assert_eq!(t.nodes()[org].children.len(), 2);
        assert_eq!(t.nodes()[t.leaf_node(2)].parent, ROOT);
    }

    #[test]
    fn unknown_parent_is_an_error() {
        let err = Topology::from_json_str(
            r#"{"pools": [{"name": "a", "parent": "ghost", "weight": 1}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("unknown parent"), "{err}");
        assert!(err.contains("ghost"), "{err}");
    }

    #[test]
    fn non_positive_weight_is_an_error() {
        for w in ["0", "-2.5"] {
            let err = Topology::from_json_str(&format!(
                r#"{{"pools": [{{"name": "a", "weight": {w}}}]}}"#
            ))
            .unwrap_err()
            .to_string();
            assert!(err.contains("non-positive weight"), "{w}: {err}");
        }
    }

    #[test]
    fn duplicate_pool_name_is_an_error() {
        let err = Topology::from_json_str(
            r#"{"pools": [{"name": "a", "weight": 1}, {"name": "a", "weight": 2}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("duplicate pool name"), "{err}");
    }

    #[test]
    fn parent_cycle_is_an_error() {
        let err = Topology::from_json_str(
            r#"{"pools": [
                {"name": "a", "parent": "b", "weight": 1},
                {"name": "b", "parent": "a", "weight": 1}
            ]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("cycle"), "{err}");
    }

    #[test]
    fn discipline_on_interior_pool_is_an_error() {
        let err = Topology::from_json_str(
            r#"{"pools": [
                {"name": "org", "discipline": "las"},
                {"name": "child", "parent": "org"}
            ]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("leaf pools only"), "{err}");
    }

    #[test]
    fn unknown_discipline_and_empty_list_are_errors() {
        let err = Topology::from_json_str(
            r#"{"pools": [{"name": "a", "discipline": "edf"}]}"#,
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("unknown discipline"), "{err}");
        assert!(err.contains("hfsp"), "{err}");
        let err = Topology::from_json_str(r#"{"pools": []}"#).unwrap_err().to_string();
        assert!(err.contains("no pools"), "{err}");
        assert!(Topology::from_json_str("not json").is_err());
        assert!(Topology::from_json_str(r#"{"nope": 1}"#).is_err());
    }

    #[test]
    fn from_arg_resolves_builtins_and_rejects_missing_files() {
        assert_eq!(Topology::from_arg("single").unwrap().n_leaves(), 1);
        assert_eq!(Topology::from_arg("example").unwrap().n_leaves(), 3);
        let err = Topology::from_arg("/nonexistent/pools.json").unwrap_err();
        assert!(format!("{err:#}").contains("reading pool topology"));
    }
}
