//! Hierarchical multi-tenant scheduling: pools → users → jobs.
//!
//! This subsystem composes the repo's two fairness mechanisms into a
//! tree. A [`Topology`] declares weighted pools (interior nodes split
//! capacity by **weighted max-min** over their *active* children —
//! [`tree::ShareTree`]); each **leaf** pool runs any registered
//! size-based [`Discipline`] over the jobs routed to it (HFSP in one
//! pool, SRPT or LAS in another); **below** each leaf an unweighted
//! max-min layer shares the leaf's slots between its active users
//! (reusing [`maxmin_waterfill_into`], the same kernel as the FSP
//! virtual cluster). Jobs are routed by their [`TenantId`]:
//! `tenant.pool % n_leaves` selects the leaf, `tenant.user` the user
//! bucket within it.
//!
//! ## Relation to the flat scheduler
//!
//! The per-leaf machinery is the flat
//! [`SizeBasedScheduler`](crate::scheduler::core::SizeBasedScheduler)'s
//! mechanism re-hosted: one [`Discipline`] + training module +
//! [`OrderCache`] per leaf, with the locality index, delay timer and
//! suspension guard shared across the tree (they model cluster-level
//! facts, not policy). Two deliberate simplifications against the flat
//! heartbeat loop, both documented here because the degenerate case
//! side-steps them entirely:
//!
//! * no training-priority stage — training samples still accrue from
//!   ordinary completions, they just don't get dedicated slots;
//! * preemption operates at pool granularity (an under-served pool
//!   suspends the worst-ranked task of the most over-served pool)
//!   rather than per-job rank gaps.
//!
//! A **single-leaf** topology has nothing to split, so
//! [`SchedulerKind::build`](crate::scheduler::SchedulerKind::build)
//! lowers it to the flat `SizeBasedScheduler` via
//! [`HierarchyConfig::flat_equivalent`] — outcomes are *structurally*
//! byte-identical to the non-hierarchical scheduler, not merely close
//! (asserted across the scenario matrix by `tests/hierarchy.rs`).
//!
//! ## Share computation per heartbeat
//!
//! 1. per-leaf demand = pending + running tasks of gated jobs, plus
//!    suspended tasks parked anywhere (they must resume eventually);
//! 2. [`ShareTree::allocate`] splits the phase's total slots top-down
//!    into per-leaf targets;
//! 3. within each leaf, per-user targets via unweighted water-filling;
//! 4. the node's free slots go one at a time to the leaf with the
//!    largest `target − usage` deficit (ties: lower virtual time, then
//!    lower leaf index), inside it to the max-deficit user, inside that
//!    to the first job in the leaf discipline's order — resume-first,
//!    then delay-scheduled launches;
//! 5. pool-level preemption (if the base config allows) swaps slots
//!    from pools over target by ≥ 1 to pools under target by ≥ 1 with
//!    unmet demand on this node.

pub mod topology;
pub mod tree;

pub use topology::{PoolDecl, PoolNode, Topology};
pub use tree::ShareTree;

use super::core::{Discipline, OrderCache, SizeBasedConfig, SuspensionGuard};
use super::core::training::{TrainingModule, TrainingUpdate};
use super::core::virtual_cluster::maxmin_waterfill_into;
use super::delay::{pick_reduce, DelayTimer, LocalityIndex};
use super::disciplines::{self, DisciplineKind};
use super::{Action, SchedView, Scheduler};
use crate::faults::ErrorModel;
use crate::job::task::NodeId;
use crate::job::{Job, JobId, Phase, TaskRef};
use crate::scheduler::core::PreemptionPrimitive;
use crate::sim::Time;
use crate::util::fxmap::{FastMap, FastSet};

/// Configuration of the hierarchical scheduler: the pool tree plus the
/// base mechanism parameters every leaf inherits (each leaf overrides
/// only `base.discipline` with its own).
#[derive(Clone, Debug)]
pub struct HierarchyConfig {
    pub topology: Topology,
    pub base: SizeBasedConfig,
}

impl Default for HierarchyConfig {
    /// The built-in 3-pool example topology over default mechanism
    /// parameters.
    fn default() -> Self {
        Self {
            topology: Topology::example(),
            base: SizeBasedConfig::default(),
        }
    }
}

impl HierarchyConfig {
    pub fn with_topology(topology: Topology) -> Self {
        Self {
            topology,
            base: SizeBasedConfig::default(),
        }
    }

    /// The degenerate single-pool hierarchy running `discipline`.
    pub fn single(discipline: DisciplineKind) -> Self {
        Self::with_topology(Topology::single_pool(discipline))
    }

    /// For a single-leaf topology: the flat [`SizeBasedConfig`] the
    /// hierarchy collapses to (the tree has nothing to split, the user
    /// layer nothing to share). `None` for real hierarchies.
    pub fn flat_equivalent(&self) -> Option<SizeBasedConfig> {
        if self.topology.n_leaves() != 1 {
            return None;
        }
        let mut cfg = self.base.clone();
        cfg.discipline = self.topology.leaf(0).discipline.unwrap_or_default();
        Some(cfg)
    }
}

/// Per-leaf scheduling state: the flat mechanism's policy-side pieces,
/// one set per pool.
struct LeafPool {
    discipline: Box<dyn Discipline>,
    /// `None` for size-oblivious leaf disciplines (LAS).
    training: Option<TrainingModule>,
    order_map: OrderCache,
    order_reduce: OrderCache,
    reduce_started: FastSet<JobId>,
}

impl LeafPool {
    fn new(base: &SizeBasedConfig, discipline: DisciplineKind, leaf: usize) -> Self {
        let cfg = SizeBasedConfig {
            discipline,
            ..base.clone()
        };
        let training = if discipline.uses_estimates() {
            let error = if cfg.error_sigma > 0.0 {
                // Per-leaf seed tweak: error draws in one pool must not
                // shift the error stream another pool sees.
                Some(ErrorModel::log_normal(
                    cfg.error_sigma,
                    cfg.error_seed.wrapping_add(leaf as u64),
                ))
            } else if cfg.error_alpha > 0.0 {
                Some(ErrorModel::uniform(
                    cfg.error_alpha,
                    cfg.error_seed.wrapping_add(leaf as u64),
                ))
            } else {
                None
            };
            Some(TrainingModule::new(
                cfg.sample_set,
                cfg.xi,
                cfg.estimator.build(),
                error,
            ))
        } else {
            None
        };
        Self {
            discipline: disciplines::build(&cfg),
            training,
            order_map: OrderCache::default(),
            order_reduce: OrderCache::default(),
            reduce_started: FastSet::default(),
        }
    }

    fn initial_estimate(&mut self, id: JobId, phase: Phase, n_tasks: usize) -> f64 {
        match &mut self.training {
            Some(t) => t.start_phase(id, phase, n_tasks),
            None => 0.0,
        }
    }

    fn start_reduce(&mut self, view: &SchedView, id: JobId) {
        if !self.reduce_started.insert(id) {
            return;
        }
        let n = view.jobs[&id].spec.n_reduces();
        if n == 0 {
            return;
        }
        let initial = self.initial_estimate(id, Phase::Reduce, n);
        self.discipline
            .phase_started(id, Phase::Reduce, initial, n, view.now);
    }
}

/// One user's standing inside a leaf's unweighted max-min layer for the
/// current heartbeat.
#[derive(Clone, Copy, Debug)]
struct UserShare {
    user: u32,
    demand: f64,
    target: f64,
    usage: f64,
    /// No placeable candidate on this node right now.
    blocked: bool,
}

enum Placed {
    Launch,
    Resume,
}

/// The pools → users → jobs tree scheduler. See the module docs for the
/// share-computation walkthrough.
pub struct HierarchicalScheduler {
    cfg: HierarchyConfig,
    tree: ShareTree,
    leaves: Vec<LeafPool>,
    index: LocalityIndex,
    delay: DelayTimer,
    guard: SuspensionGuard,
    /// job → (leaf ordinal, user id) — fixed at arrival from the spec's
    /// [`crate::job::TenantId`].
    job_leaf: FastMap<JobId, (usize, u32)>,
    sized: bool,
    /// Last virtual-time advance (one advance per distinct heartbeat
    /// instant, not per node).
    vtime_now: Time,
    // -- reusable per-heartbeat buffers --
    demand: Vec<f64>,
    usage: Vec<f64>,
    target: Vec<f64>,
    active: Vec<bool>,
    blocked: Vec<bool>,
    user_plan: Vec<Vec<UserShare>>,
    user_demands: Vec<f64>,
    user_alloc: Vec<f64>,
    wf_order: Vec<usize>,
    scratch_caches: Vec<OrderCache>,
    scratch_picked: FastSet<TaskRef>,
    scratch_resumed: FastSet<TaskRef>,
}

impl HierarchicalScheduler {
    pub fn new(cfg: HierarchyConfig) -> Self {
        let n = cfg.topology.n_leaves();
        let leaves = (0..n)
            .map(|l| {
                let d = cfg.topology.leaf(l).discipline.unwrap_or_default();
                LeafPool::new(&cfg.base, d, l)
            })
            .collect();
        let tree = ShareTree::new(&cfg.topology);
        let guard = SuspensionGuard::new(cfg.base.suspend_hi, cfg.base.suspend_lo);
        let delay = DelayTimer::new(cfg.base.locality_timeout_s);
        Self {
            cfg,
            tree,
            leaves,
            index: LocalityIndex::new(),
            delay,
            guard,
            job_leaf: FastMap::default(),
            sized: false,
            vtime_now: 0.0,
            demand: Vec::new(),
            usage: Vec::new(),
            target: Vec::new(),
            active: Vec::new(),
            blocked: Vec::new(),
            user_plan: (0..n).map(|_| Vec::new()).collect(),
            user_demands: Vec::new(),
            user_alloc: Vec::new(),
            wf_order: Vec::new(),
            scratch_caches: Vec::new(),
            scratch_picked: FastSet::default(),
            scratch_resumed: FastSet::default(),
        }
    }

    fn ensure_sized(&mut self, view: &SchedView) {
        if !self.sized {
            // Every leaf's reference world sees the full cluster; the
            // tree enforces shares at placement time, not inside the
            // disciplines' fluid simulations.
            let map_slots = view.cluster.total_slots(Phase::Map).max(1);
            let red_slots = view.cluster.total_slots(Phase::Reduce).max(1);
            for leaf in &mut self.leaves {
                leaf.discipline.bind_capacity(map_slots, red_slots);
            }
            self.sized = true;
        }
    }

    /// Pick a map task for `job` on `node` under delay scheduling
    /// (identical to the flat mechanism's picker — the timer and index
    /// are cluster-level state shared by all pools).
    fn pick_map(
        &mut self,
        view: &SchedView,
        job: &Job,
        node: NodeId,
        picked: &FastSet<TaskRef>,
    ) -> Option<(TaskRef, bool)> {
        if let Some(t) = self.index.pick_local(job, node, picked) {
            self.delay.clear(job.id());
            return Some((t, true));
        }
        if job.pending_tasks(Phase::Map) == 0 {
            return None;
        }
        if self.delay.skip_and_check(job.id(), view.now) {
            if let Some(t) = self.index.pick_any(job, picked) {
                self.delay.clear(job.id());
                return Some((t, false));
            }
        }
        None
    }

    fn pick_task(
        &mut self,
        view: &SchedView,
        job: &Job,
        phase: Phase,
        node: NodeId,
        picked: &FastSet<TaskRef>,
    ) -> Option<(TaskRef, bool)> {
        match phase {
            Phase::Map => self.pick_map(view, job, node, picked),
            Phase::Reduce => pick_reduce(job, picked).map(|t| (t, true)),
        }
    }

    /// A suspended task of `job` parked on `node` not yet resumed in
    /// this batch.
    fn suspended_here(
        view: &SchedView,
        job: JobId,
        phase: Phase,
        node: NodeId,
        resumed: &FastSet<TaskRef>,
    ) -> Option<TaskRef> {
        view.cluster
            .node(node)
            .suspended_tasks()
            .find(|t| t.job == job && t.phase == phase && !resumed.contains(t))
    }

    /// Advance every node's virtual time to `view.now` using per-leaf
    /// slot usage across both phases (the clock measures normalized
    /// service, so phases pool together).
    fn advance_vtime(&mut self, view: &SchedView) {
        let dt = view.now - self.vtime_now;
        if dt <= 0.0 {
            return;
        }
        let n = self.leaves.len();
        self.usage.clear();
        self.usage.resize(n, 0.0);
        self.active.clear();
        self.active.resize(n, false);
        for job in view.active_jobs() {
            let Some(&(l, _)) = self.job_leaf.get(&job.id()) else {
                continue;
            };
            self.usage[l] +=
                (job.running_tasks(Phase::Map) + job.running_tasks(Phase::Reduce)) as f64;
            self.active[l] = true;
        }
        self.tree.advance(dt, &self.usage, &self.active);
        self.vtime_now = view.now;
    }

    /// Compute per-leaf demand/usage and per-leaf-per-user plans for
    /// `phase`, then tree targets. `caches` are the leaves' refreshed
    /// order caches (taken out of `self` by the caller).
    fn compute_shares(&mut self, view: &SchedView, phase: Phase, caches: &[OrderCache]) {
        let n = self.leaves.len();
        self.demand.clear();
        self.demand.resize(n, 0.0);
        self.usage.clear();
        self.usage.resize(n, 0.0);
        for (l, cache) in caches.iter().enumerate() {
            let users = &mut self.user_plan[l];
            users.clear();
            for &(id, _) in &cache.order {
                let job = &view.jobs[&id];
                if phase == Phase::Reduce && !job.map_phase_done() {
                    continue;
                }
                let pending = job.pending_tasks(phase) as f64;
                let running = job.running_tasks(phase) as f64;
                self.demand[l] += pending + running;
                self.usage[l] += running;
                let user = self.job_leaf.get(&id).map(|&(_, u)| u).unwrap_or(0);
                users.push(UserShare {
                    user,
                    demand: pending + running,
                    target: 0.0,
                    usage: running,
                    blocked: false,
                });
            }
        }
        // Suspended tasks parked anywhere are demand too: a pool whose
        // tasks are all suspended must keep a non-zero claim or it would
        // never be allotted the slot needed to resume them.
        for nd in view.cluster.nodes() {
            for t in nd.suspended_tasks() {
                if t.phase != phase {
                    continue;
                }
                if let Some(&(l, u)) = self.job_leaf.get(&t.job) {
                    self.demand[l] += 1.0;
                    if let Some(us) = self.user_plan[l].iter_mut().find(|us| us.user == u) {
                        us.demand += 1.0;
                    } else {
                        self.user_plan[l].push(UserShare {
                            user: u,
                            demand: 1.0,
                            target: 0.0,
                            usage: 0.0,
                            blocked: false,
                        });
                    }
                }
            }
        }
        let capacity = view.cluster.total_slots(phase) as f64;
        self.tree.allocate(&self.demand, capacity, &mut self.target);
        // Intra-leaf user layer: merge per-job rows into per-user rows,
        // then unweighted max-min of the leaf's target over user demands
        // — the same water-filling kernel the FSP virtual cluster uses.
        for l in 0..n {
            let users = &mut self.user_plan[l];
            users.sort_by_key(|us| us.user);
            let mut w = 0;
            for r in 0..users.len() {
                if w > 0 && users[w - 1].user == users[r].user {
                    users[w - 1].demand += users[r].demand;
                    users[w - 1].usage += users[r].usage;
                } else {
                    users[w] = users[r];
                    w += 1;
                }
            }
            users.truncate(w);
            self.user_demands.clear();
            self.user_demands.extend(users.iter().map(|us| us.demand));
            maxmin_waterfill_into(
                &self.user_demands,
                self.target[l],
                &mut self.user_alloc,
                &mut self.wf_order,
            );
            for (us, &t) in users.iter_mut().zip(&self.user_alloc) {
                us.target = t;
            }
        }
    }

    /// Place one task from leaf `l` on `node`: max-deficit user first,
    /// within the user the leaf discipline's order; resume-first, then
    /// a delay-scheduled launch. Returns `None` when nothing of this
    /// leaf is placeable here right now.
    #[allow(clippy::too_many_arguments)]
    fn place_one(
        &mut self,
        view: &SchedView,
        node: NodeId,
        phase: Phase,
        cache: &OrderCache,
        users: &mut [UserShare],
        picked: &mut FastSet<TaskRef>,
        resumed: &mut FastSet<TaskRef>,
        ctx_budget: &mut usize,
        actions: &mut Vec<Action>,
    ) -> Option<Placed> {
        loop {
            let mut best: Option<usize> = None;
            for (i, us) in users.iter().enumerate() {
                if us.blocked || us.demand - us.usage <= 0.0 {
                    continue;
                }
                let better = match best {
                    None => true,
                    Some(b) => {
                        let d = us.target - us.usage;
                        let db = users[b].target - users[b].usage;
                        d > db + 1e-9
                    }
                };
                if better {
                    best = Some(i);
                }
            }
            let ui = best?;
            let user = users[ui].user;
            for &(id, _) in &cache.order {
                let routed = self.job_leaf.get(&id).map(|&(_, u)| u);
                if routed != Some(user) {
                    continue;
                }
                let job = &view.jobs[&id];
                if phase == Phase::Reduce && !job.map_phase_done() {
                    continue;
                }
                if let Some(t) = Self::suspended_here(view, id, phase, node, resumed) {
                    resumed.insert(t);
                    actions.push(Action::Resume { task: t });
                    users[ui].usage += 1.0;
                    return Some(Placed::Resume);
                }
                if *ctx_budget > 0 {
                    if let Some((task, local)) = self.pick_task(view, job, phase, node, picked) {
                        picked.insert(task);
                        actions.push(Action::Launch { task, node, local });
                        *ctx_budget -= 1;
                        users[ui].usage += 1.0;
                        return Some(Placed::Launch);
                    }
                }
            }
            users[ui].blocked = true;
        }
    }

    /// Fill + preempt for one phase on one node heartbeat.
    #[allow(clippy::too_many_lines)]
    fn assign_phase(
        &mut self,
        view: &SchedView,
        node: NodeId,
        phase: Phase,
        actions: &mut Vec<Action>,
        ctx_budget: &mut usize,
    ) {
        let n = self.leaves.len();
        for leaf in &mut self.leaves {
            let cache = match phase {
                Phase::Map => &mut leaf.order_map,
                Phase::Reduce => &mut leaf.order_reduce,
            };
            cache.refresh(leaf.discipline.as_mut(), phase);
        }
        // Caches and scratch sets move out of `self` so the `&mut self`
        // pickers stay callable (same dance as the flat scheduler).
        let mut caches = std::mem::take(&mut self.scratch_caches);
        caches.clear();
        caches.extend(self.leaves.iter_mut().map(|leaf| match phase {
            Phase::Map => std::mem::take(&mut leaf.order_map),
            Phase::Reduce => std::mem::take(&mut leaf.order_reduce),
        }));
        let mut picked = std::mem::take(&mut self.scratch_picked);
        let mut resumed = std::mem::take(&mut self.scratch_resumed);
        picked.clear();
        resumed.clear();

        self.compute_shares(view, phase, &caches);
        let mut user_plan = std::mem::take(&mut self.user_plan);

        // -- Fill: one slot at a time to the worst-off pool --------------
        let mut free = view.cluster.node(node).free_slots(phase);
        self.blocked.clear();
        self.blocked.resize(n, false);
        while free > 0 {
            let mut best: Option<usize> = None;
            for l in 0..n {
                if self.blocked[l] {
                    continue;
                }
                if self.demand[l] - self.usage[l] <= 0.0 {
                    self.blocked[l] = true;
                    continue;
                }
                let better = match best {
                    None => true,
                    Some(b) => {
                        let d = self.target[l] - self.usage[l];
                        let db = self.target[b] - self.usage[b];
                        d > db + 1e-9
                            || ((d - db).abs() <= 1e-9
                                && self.tree.leaf_vtime(l) < self.tree.leaf_vtime(b))
                    }
                };
                if better {
                    best = Some(l);
                }
            }
            let Some(l) = best else { break };
            match self.place_one(
                view,
                node,
                phase,
                &caches[l],
                &mut user_plan[l],
                &mut picked,
                &mut resumed,
                ctx_budget,
                actions,
            ) {
                Some(_) => {
                    free -= 1;
                    self.usage[l] += 1.0;
                }
                None => self.blocked[l] = true,
            }
        }

        // -- Pool-level preemption ----------------------------------------
        if self.cfg.base.preemption != PreemptionPrimitive::Wait {
            self.preempt_phase(
                view,
                node,
                phase,
                &caches,
                &mut user_plan,
                &mut picked,
                &mut resumed,
                ctx_budget,
                actions,
            );
        }

        self.user_plan = user_plan;
        for (leaf, cache) in self.leaves.iter_mut().zip(caches.drain(..)) {
            match phase {
                Phase::Map => leaf.order_map = cache,
                Phase::Reduce => leaf.order_reduce = cache,
            }
        }
        self.scratch_caches = caches;
        self.scratch_picked = picked;
        self.scratch_resumed = resumed;
    }

    /// Swap slots from pools over target to pools under target. A
    /// claimant must be a full slot under its target with demand this
    /// node can serve (a suspended task parked here, or pending tasks
    /// exceeding the cluster's free slots); the victim is the worst-
    /// ranked running task (in its own pool's order) of the most
    /// over-served pool. The ≥ 1-slot gap on both sides is the thrash
    /// guard: after each swap both gaps shrink, so the loop terminates
    /// and near-balanced pools never flap.
    #[allow(clippy::too_many_arguments)]
    fn preempt_phase(
        &mut self,
        view: &SchedView,
        node: NodeId,
        phase: Phase,
        caches: &[OrderCache],
        user_plan: &mut [Vec<UserShare>],
        picked: &mut FastSet<TaskRef>,
        resumed: &mut FastSet<TaskRef>,
        ctx_budget: &mut usize,
        actions: &mut Vec<Action>,
    ) {
        let n = self.leaves.len();
        let cluster_free = view.cluster.free_slots(phase);
        let mut suspended_total = view.cluster.suspended_count();
        let mut preempted: Vec<TaskRef> = Vec::new();
        loop {
            // Claimant: most under-served pool, at least one slot short.
            let claimant = (0..n)
                .filter(|&l| self.target[l] - self.usage[l] >= 1.0 - 1e-9)
                .max_by(|&a, &b| {
                    (self.target[a] - self.usage[a]).total_cmp(&(self.target[b] - self.usage[b]))
                });
            let Some(cl) = claimant else { return };
            // Victim pool: most over-served, at least one slot over, with
            // a running task on this node we haven't already preempted.
            let victim_task = (0..n)
                .filter(|&l| l != cl && self.usage[l] - self.target[l] >= 1.0 - 1e-9)
                .max_by(|&a, &b| {
                    (self.usage[a] - self.target[a]).total_cmp(&(self.usage[b] - self.target[b]))
                })
                .and_then(|vl| {
                    view.cluster
                        .node(node)
                        .running(phase)
                        .iter()
                        .filter(|t| {
                            !preempted.contains(t)
                                && self.job_leaf.get(&t.job).map(|&(l, _)| l) == Some(vl)
                        })
                        .max_by_key(|t| caches[vl].rank_of(t.job).unwrap_or(0))
                        .copied()
                        .map(|t| (vl, t))
                });
            let Some((vl, victim)) = victim_task else { return };
            // Does this node actually help the claimant?
            let resume_cand = user_plan[cl]
                .iter()
                .filter(|us| !us.blocked)
                .find_map(|us| {
                    caches[cl].order.iter().find_map(|&(id, _)| {
                        (self.job_leaf.get(&id).map(|&(_, u)| u) == Some(us.user))
                            .then(|| Self::suspended_here(view, id, phase, node, resumed))
                            .flatten()
                    })
                });
            let pending_unmet = caches[cl].order.iter().any(|&(id, _)| {
                let job = &view.jobs[&id];
                (phase == Phase::Map || job.map_phase_done())
                    && job.pending_tasks(phase) > cluster_free
            });
            if resume_cand.is_none() && !pending_unmet {
                return;
            }
            let preempt_action = match self.cfg.base.preemption {
                PreemptionPrimitive::Kill => Some(Action::Kill { task: victim }),
                PreemptionPrimitive::Suspend => {
                    let have_ctx = resume_cand.is_some() || *ctx_budget >= 1;
                    if have_ctx && self.guard.allow_suspend(suspended_total) {
                        Some(Action::Suspend { task: victim })
                    } else {
                        None
                    }
                }
                PreemptionPrimitive::Wait => unreachable!(),
            };
            let Some(preempt_action) = preempt_action else { return };
            let placement = match resume_cand {
                Some(t) => Some(Action::Resume { task: t }),
                None => {
                    // First launchable job of the claimant pool, in
                    // discipline order.
                    let mut found = None;
                    for &(id, _) in &caches[cl].order {
                        let job = &view.jobs[&id];
                        if phase == Phase::Reduce && !job.map_phase_done() {
                            continue;
                        }
                        if *ctx_budget == 0 {
                            break;
                        }
                        if let Some((task, local)) =
                            self.pick_task(view, job, phase, node, picked)
                        {
                            found = Some(Action::Launch { task, node, local });
                            break;
                        }
                    }
                    found
                }
            };
            let Some(placement) = placement else { return };
            if matches!(preempt_action, Action::Suspend { .. }) {
                suspended_total += 1;
            }
            preempted.push(victim);
            actions.push(preempt_action);
            match placement {
                Action::Resume { task } => {
                    resumed.insert(task);
                }
                Action::Launch { task, .. } => {
                    picked.insert(task);
                    *ctx_budget = ctx_budget.saturating_sub(1);
                }
                _ => {}
            }
            actions.push(placement);
            self.usage[vl] -= 1.0;
            self.usage[cl] += 1.0;
        }
    }
}

impl Scheduler for HierarchicalScheduler {
    fn name(&self) -> &'static str {
        "HIER"
    }

    fn on_job_arrival(&mut self, view: &SchedView, id: JobId) {
        self.ensure_sized(view);
        let job = &view.jobs[&id];
        self.index.add_job(job, view.hdfs);
        let l = self.cfg.topology.leaf_for_pool(job.spec.tenant.pool);
        self.job_leaf.insert(id, (l, job.spec.tenant.user));
        let n_maps = job.spec.n_maps();
        let leaf = &mut self.leaves[l];
        if n_maps > 0 {
            let initial = leaf.initial_estimate(id, Phase::Map, n_maps);
            leaf.discipline
                .phase_started(id, Phase::Map, initial, n_maps, view.now);
        } else {
            leaf.start_reduce(view, id);
        }
    }

    fn on_task_completed(&mut self, view: &SchedView, task: TaskRef, observed: f64) {
        let id = task.job;
        let Some(&(l, _)) = self.job_leaf.get(&id) else {
            return;
        };
        let leaf = &mut self.leaves[l];
        let job = &view.jobs[&id];
        let phase = task.phase;
        let tasks_done = match phase {
            Phase::Map => job.maps_done,
            Phase::Reduce => job.reduces_done,
        };
        leaf.discipline.service_observed(id, phase, observed, view.now);
        if let Some(training) = &mut leaf.training {
            if let TrainingUpdate::Estimated { total } =
                training.observe_completion(id, phase, observed, tasks_done)
            {
                leaf.discipline.size_estimated(id, phase, total, view.now);
            }
        }
        if job.remaining_tasks(phase) == 0 {
            leaf.discipline.phase_completed(id, phase, view.now);
        }
        if phase == Phase::Map && job.map_phase_done() {
            leaf.start_reduce(view, id);
        }
    }

    fn on_reduce_progress(&mut self, view: &SchedView, task: TaskRef, delta: f64, progress: f64) {
        if progress <= 0.0 {
            return;
        }
        let Some(&(l, _)) = self.job_leaf.get(&task.job) else {
            return;
        };
        let leaf = &mut self.leaves[l];
        if let Some(training) = &mut leaf.training {
            if let TrainingUpdate::Estimated { total } =
                training.observe_progress(task.job, delta, progress)
            {
                leaf.discipline
                    .size_estimated(task.job, Phase::Reduce, total, view.now);
            }
        }
    }

    fn on_job_finished(&mut self, view: &SchedView, id: JobId) {
        if let Some((l, _)) = self.job_leaf.remove(&id) {
            let leaf = &mut self.leaves[l];
            leaf.discipline.job_removed(id, view.now);
            if let Some(training) = &mut leaf.training {
                training.remove_job(id);
            }
            leaf.reduce_started.remove(&id);
        }
        self.index.remove_job(id);
        self.delay.remove_job(id);
    }

    fn on_heartbeat(&mut self, view: &SchedView, node: NodeId, actions: &mut Vec<Action>) {
        self.ensure_sized(view);
        for leaf in &mut self.leaves {
            leaf.discipline.advance(view.now);
        }
        self.advance_vtime(view);
        let mut ctx_budget = view.cluster.node(node).context_headroom();
        self.assign_phase(view, node, Phase::Map, actions, &mut ctx_budget);
        self.assign_phase(view, node, Phase::Reduce, actions, &mut ctx_budget);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::driver::{run_simulation, SimConfig};
    use crate::cluster::ClusterConfig;
    use crate::scheduler::SchedulerKind;

    #[test]
    fn flat_equivalent_exists_only_for_single_leaf_topologies() {
        let single = HierarchyConfig::single(DisciplineKind::Srpt);
        let flat = single.flat_equivalent().expect("one leaf collapses");
        assert_eq!(flat.discipline, DisciplineKind::Srpt);
        assert!(HierarchyConfig::default().flat_equivalent().is_none());
    }

    #[test]
    fn hierarchical_example_completes_a_batch_workload() {
        // All jobs carry the default tenant → pool 0 (prod / HFSP); the
        // other two pools stay empty. Everything must finish with no
        // rejected actions.
        let wl = crate::workload::synthetic::uniform_batch(6, 4, 10.0);
        let cfg = SimConfig {
            cluster: ClusterConfig {
                nodes: 3,
                ..Default::default()
            },
            ..Default::default()
        };
        let o = run_simulation(
            &cfg,
            SchedulerKind::Hierarchical(HierarchyConfig::default()),
            &wl,
        );
        assert_eq!(o.scheduler, "HIER");
        assert_eq!(o.sojourn.len(), 6);
        assert_eq!(o.counters.rejected_actions, 0);
    }

    #[test]
    fn tenants_spread_across_pools_all_complete() {
        use crate::job::{JobClass, JobSpec, TenantId};
        let jobs = (0..9u64)
            .map(|i| JobSpec {
                id: i + 1,
                name: format!("t{i}"),
                class: JobClass::Small,
                tenant: TenantId::new((i % 3) as u32, (i % 4) as u32),
                submit_time: 0.25 * i as f64,
                map_durations: vec![4.0; 3],
                reduce_durations: vec![6.0],
            })
            .collect();
        let wl = crate::workload::Workload::new("spread", jobs).unwrap();
        let cfg = SimConfig {
            cluster: ClusterConfig {
                nodes: 2,
                ..Default::default()
            },
            ..Default::default()
        };
        let o = run_simulation(
            &cfg,
            SchedulerKind::Hierarchical(HierarchyConfig::default()),
            &wl,
        );
        assert_eq!(o.sojourn.len(), 9, "all tenants' jobs complete");
        assert_eq!(o.counters.rejected_actions, 0);
    }
}
