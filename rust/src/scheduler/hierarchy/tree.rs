//! The fair-share tree: weighted max-min capacity splitting plus
//! FSP-style virtual-time decay at every node.
//!
//! Each heartbeat the scheduler feeds per-leaf slot *demands* into
//! [`ShareTree::allocate`]; demands aggregate bottom-up over the
//! topology, then the cluster's capacity is split top-down, each
//! interior node performing a **weighted** max-min division over its
//! children's subtree demands. When a node's children carry equal
//! weights the split delegates to the shared water-filling routine
//! ([`maxmin_waterfill_into`]) — the same kernel the FSP discipline's
//! virtual cluster uses — so the two fairness paths cannot drift apart.
//!
//! Separately, each node carries a **virtual time**: normalized service
//! `Σ usage·dt / weight` while active. The scheduler breaks allocation
//! ties toward the lowest virtual time, which is what makes weighted
//! sharing hold over time rather than per-instant. The decay rule keeps
//! the clock meaningful across idleness: an idle node's virtual time is
//! snapped **up** to the minimum among its active siblings, so a tenant
//! that slept for an hour wakes with the same standing as the
//! least-served active tenant — it is not starved (its clock never runs
//! ahead while idle), and it cannot starve others by cashing in an
//! hour-long backlog claim.

use crate::scheduler::core::virtual_cluster::maxmin_waterfill_into;
use super::topology::{Topology, ROOT};

/// Per-heartbeat share computation state over a fixed [`Topology`].
/// All buffers are reusable: steady-state [`ShareTree::allocate`] and
/// [`ShareTree::advance`] calls do not allocate.
pub struct ShareTree {
    parent: Vec<usize>,
    children: Vec<Vec<usize>>,
    weight: Vec<f64>,
    /// Node indices ordered so parents precede children (BFS from the
    /// root) — the traversal order for top-down splits; reversed for
    /// bottom-up aggregation.
    topo: Vec<usize>,
    /// Node index of each leaf ordinal.
    leaf_nodes: Vec<usize>,
    /// Normalized service clock per node (see module docs).
    vtime: Vec<f64>,
    // -- reusable working state --
    demand: Vec<f64>,
    target: Vec<f64>,
    usage: Vec<f64>,
    active: Vec<bool>,
    kid_demands: Vec<f64>,
    kid_alloc: Vec<f64>,
    kid_order: Vec<usize>,
    wf_order: Vec<usize>,
}

impl ShareTree {
    pub fn new(topology: &Topology) -> Self {
        let nodes = topology.nodes();
        let n = nodes.len();
        // BFS from the root: a node's parent always appears earlier.
        let mut topo = Vec::with_capacity(n);
        topo.push(ROOT);
        let mut head = 0;
        while head < topo.len() {
            let cur = topo[head];
            head += 1;
            topo.extend(nodes[cur].children.iter().copied());
        }
        debug_assert_eq!(topo.len(), n, "topology is connected");
        Self {
            parent: nodes.iter().map(|p| p.parent).collect(),
            children: nodes.iter().map(|p| p.children.clone()).collect(),
            weight: nodes.iter().map(|p| p.weight).collect(),
            topo,
            leaf_nodes: (0..topology.n_leaves()).map(|l| topology.leaf_node(l)).collect(),
            vtime: vec![0.0; n],
            demand: vec![0.0; n],
            target: vec![0.0; n],
            usage: vec![0.0; n],
            active: vec![false; n],
            kid_demands: Vec::new(),
            kid_alloc: Vec::new(),
            kid_order: Vec::new(),
            wf_order: Vec::new(),
        }
    }

    pub fn n_leaves(&self) -> usize {
        self.leaf_nodes.len()
    }

    /// Split `capacity` slots over the leaves given per-leaf demands
    /// (slot counts). Writes one target per leaf into `out` (cleared
    /// first). Targets are fractional: the scheduler compares them
    /// against integer usage as deficits.
    pub fn allocate(&mut self, leaf_demands: &[f64], capacity: f64, out: &mut Vec<f64>) {
        assert_eq!(leaf_demands.len(), self.leaf_nodes.len());
        self.demand.iter_mut().for_each(|d| *d = 0.0);
        for (l, &d) in leaf_demands.iter().enumerate() {
            debug_assert!(d >= 0.0 && d.is_finite());
            self.demand[self.leaf_nodes[l]] = d;
        }
        // Bottom-up: subtree demand.
        for i in (1..self.topo.len()).rev() {
            let n = self.topo[i];
            self.demand[self.parent[n]] += self.demand[n];
        }
        // Top-down: weighted max-min split of each node's target.
        self.target.iter_mut().for_each(|t| *t = 0.0);
        self.target[ROOT] = capacity.min(self.demand[ROOT]);
        for i in 0..self.topo.len() {
            let n = self.topo[i];
            if !self.children[n].is_empty() {
                self.split_node(n);
            }
        }
        out.clear();
        out.extend(self.leaf_nodes.iter().map(|&n| self.target[n]));
    }

    /// Weighted max-min over one node's children: sort by demand/weight
    /// ascending; a child whose demand fits under its weighted fair
    /// share of what remains is fully satisfied (its surplus raises the
    /// water level for the rest), otherwise it — and, by the sort order,
    /// every child after it — is capped at `w_i · remaining / Σw`.
    /// Uniform weights reduce to plain water-filling, so that case
    /// delegates to the shared [`maxmin_waterfill_into`] kernel.
    fn split_node(&mut self, node: usize) {
        let kids = &self.children[node];
        let cap = self.target[node];
        self.kid_demands.clear();
        self.kid_demands.extend(kids.iter().map(|&c| self.demand[c]));
        let uniform = kids
            .windows(2)
            .all(|w| self.weight[w[0]].total_cmp(&self.weight[w[1]]).is_eq());
        if uniform {
            maxmin_waterfill_into(
                &self.kid_demands,
                cap,
                &mut self.kid_alloc,
                &mut self.wf_order,
            );
            if self.kid_alloc.is_empty() {
                // The kernel's "everyone satisfied" fast path copies the
                // demands; an empty result only means zero children.
                return;
            }
        } else {
            let k = kids.len();
            self.kid_order.clear();
            self.kid_order.extend(0..k);
            let (demands, weights) = (&self.kid_demands, &self.weight);
            self.kid_order.sort_by(|&a, &b| {
                let ra = demands[a] / weights[kids[a]];
                let rb = demands[b] / weights[kids[b]];
                ra.total_cmp(&rb).then(a.cmp(&b))
            });
            self.kid_alloc.clear();
            self.kid_alloc.resize(k, 0.0);
            let mut remaining = cap;
            let mut wsum: f64 = kids.iter().map(|&c| self.weight[c]).sum();
            for &i in &self.kid_order {
                let w = self.weight[kids[i]];
                let fair = if wsum > 0.0 { w * remaining / wsum } else { 0.0 };
                let a = self.kid_demands[i].min(fair);
                self.kid_alloc[i] = a;
                remaining -= a;
                wsum -= w;
            }
        }
        for (i, &c) in kids.iter().enumerate() {
            self.target[c] = self.kid_alloc[i];
        }
    }

    /// Advance virtual time by `dt` given per-leaf slot usage and
    /// activity, then apply the idle-decay rule at every interior node.
    pub fn advance(&mut self, dt: f64, leaf_usage: &[f64], leaf_active: &[bool]) {
        assert_eq!(leaf_usage.len(), self.leaf_nodes.len());
        if dt <= 0.0 {
            return;
        }
        self.usage.iter_mut().for_each(|u| *u = 0.0);
        self.active.iter_mut().for_each(|a| *a = false);
        for (l, &n) in self.leaf_nodes.iter().enumerate() {
            self.usage[n] = leaf_usage[l];
            self.active[n] = leaf_active[l] || leaf_usage[l] > 0.0;
        }
        for i in (1..self.topo.len()).rev() {
            let n = self.topo[i];
            self.usage[self.parent[n]] += self.usage[n];
            if self.active[n] {
                self.active[self.parent[n]] = true;
            }
        }
        for n in 0..self.vtime.len() {
            if self.active[n] {
                self.vtime[n] += self.usage[n] * dt / self.weight[n];
            }
        }
        // Idle decay: snap idle children up to the least-served active
        // sibling (parents first, so a freshly snapped interior node is
        // in place before its own children are compared — though the
        // rule is local, this keeps clocks monotone down the tree).
        for &p in &self.topo {
            if self.children[p].is_empty() {
                continue;
            }
            let floor = self.children[p]
                .iter()
                .filter(|&&c| self.active[c])
                .map(|&c| self.vtime[c])
                .fold(f64::INFINITY, f64::min);
            if floor.is_finite() {
                for &c in &self.children[p] {
                    if !self.active[c] && self.vtime[c] < floor {
                        self.vtime[c] = floor;
                    }
                }
            }
        }
    }

    /// The virtual-time clock of a leaf ordinal (tie-break key: lower =
    /// less normalized service = serve first).
    pub fn leaf_vtime(&self, leaf: usize) -> f64 {
        self.vtime[self.leaf_nodes[leaf]]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::disciplines::DisciplineKind;
    use crate::scheduler::hierarchy::topology::PoolDecl;

    fn flat(weights: &[f64]) -> Topology {
        Topology::from_pools(
            weights
                .iter()
                .enumerate()
                .map(|(i, &w)| PoolDecl {
                    name: format!("p{i}"),
                    parent: None,
                    weight: w,
                    discipline: Some(DisciplineKind::Fsp),
                })
                .collect(),
        )
        .unwrap()
    }

    fn alloc(tree: &mut ShareTree, demands: &[f64], cap: f64) -> Vec<f64> {
        let mut out = Vec::new();
        tree.allocate(demands, cap, &mut out);
        out
    }

    #[test]
    fn saturated_demands_split_by_weight() {
        let mut tree = ShareTree::new(&flat(&[3.0, 2.0, 1.0]));
        let a = alloc(&mut tree, &[100.0, 100.0, 100.0], 12.0);
        assert!((a[0] - 6.0).abs() < 1e-9, "{a:?}");
        assert!((a[1] - 4.0).abs() < 1e-9, "{a:?}");
        assert!((a[2] - 2.0).abs() < 1e-9, "{a:?}");
    }

    #[test]
    fn satisfied_demand_surplus_flows_to_the_hungry() {
        let mut tree = ShareTree::new(&flat(&[3.0, 2.0, 1.0]));
        // prod wants almost nothing; its unused weighted share is
        // redistributed 2:1 between the saturated pools.
        let a = alloc(&mut tree, &[1.0, 100.0, 100.0], 13.0);
        assert!((a[0] - 1.0).abs() < 1e-9, "{a:?}");
        assert!((a[1] - 8.0).abs() < 1e-9, "{a:?}");
        assert!((a[2] - 4.0).abs() < 1e-9, "{a:?}");
    }

    #[test]
    fn allocation_is_bounded_and_conserving() {
        let mut tree = ShareTree::new(&flat(&[5.0, 1.0, 2.0, 2.0]));
        for (demands, cap) in [
            (vec![3.0, 0.0, 7.0, 2.0], 8.0),
            (vec![1.0, 1.0, 1.0, 1.0], 100.0),
            (vec![0.0, 0.0, 0.0, 0.0], 16.0),
            (vec![50.0, 50.0, 50.0, 50.0], 7.0),
        ] {
            let a = alloc(&mut tree, &demands, cap);
            for (x, d) in a.iter().zip(&demands) {
                assert!(*x >= -1e-12 && *x <= d + 1e-9, "{a:?} vs {demands:?}");
            }
            let total: f64 = a.iter().sum();
            let want = cap.min(demands.iter().sum());
            assert!((total - want).abs() < 1e-9, "{a:?}: {total} != {want}");
        }
    }

    #[test]
    fn uniform_weights_match_the_shared_waterfill_kernel() {
        let mut tree = ShareTree::new(&flat(&[2.0, 2.0, 2.0, 2.0]));
        let demands = [9.0, 1.0, 4.0, 6.0];
        let a = alloc(&mut tree, &demands, 12.0);
        let mut want = Vec::new();
        let mut scratch = Vec::new();
        maxmin_waterfill_into(&demands, 12.0, &mut want, &mut scratch);
        assert_eq!(a, want);
    }

    #[test]
    fn nested_split_composes() {
        // root -> org(2) {etl(1), ml(1)}, misc(1): org's 2/3 of capacity
        // splits evenly between its two leaves.
        let t = Topology::from_pools(vec![
            PoolDecl { name: "org".into(), parent: None, weight: 2.0, discipline: None },
            PoolDecl { name: "etl".into(), parent: Some("org".into()), weight: 1.0, discipline: None },
            PoolDecl { name: "ml".into(), parent: Some("org".into()), weight: 1.0, discipline: None },
            PoolDecl { name: "misc".into(), parent: None, weight: 1.0, discipline: None },
        ])
        .unwrap();
        assert_eq!(t.n_leaves(), 3);
        let mut tree = ShareTree::new(&t);
        let a = alloc(&mut tree, &[100.0, 100.0, 100.0], 12.0);
        assert!((a[0] - 4.0).abs() < 1e-9, "{a:?}");
        assert!((a[1] - 4.0).abs() < 1e-9, "{a:?}");
        assert!((a[2] - 4.0).abs() < 1e-9, "{a:?}");
        // With ml idle, etl absorbs org's whole 2/3.
        let a = alloc(&mut tree, &[100.0, 0.0, 100.0], 12.0);
        assert!((a[0] - 8.0).abs() < 1e-9, "{a:?}");
        assert!((a[2] - 4.0).abs() < 1e-9, "{a:?}");
    }

    #[test]
    fn vtime_tracks_normalized_service() {
        let mut tree = ShareTree::new(&flat(&[3.0, 1.0]));
        // Equal raw service: the weight-3 pool's clock runs 3x slower.
        tree.advance(10.0, &[6.0, 6.0], &[true, true]);
        assert!((tree.leaf_vtime(0) - 20.0).abs() < 1e-9);
        assert!((tree.leaf_vtime(1) - 60.0).abs() < 1e-9);
    }

    #[test]
    fn idle_pool_decays_to_the_least_served_active_sibling() {
        let mut tree = ShareTree::new(&flat(&[1.0, 1.0, 1.0]));
        // Pool 2 sleeps while 0 and 1 are served.
        tree.advance(10.0, &[4.0, 2.0, 0.0], &[true, true, false]);
        assert!((tree.leaf_vtime(0) - 40.0).abs() < 1e-9);
        assert!((tree.leaf_vtime(1) - 20.0).abs() < 1e-9);
        // Decay: the sleeper's clock snapped up to min(40, 20) = 20 — on
        // waking it ties with the least-served active pool instead of
        // holding a 20-unit starvation claim over everyone.
        assert!((tree.leaf_vtime(2) - 20.0).abs() < 1e-9);
        // ...and an idle clock never runs ahead of active ones.
        tree.advance(10.0, &[4.0, 2.0, 0.0], &[true, true, false]);
        assert!(tree.leaf_vtime(2) <= tree.leaf_vtime(0));
        assert!((tree.leaf_vtime(2) - 40.0).abs() < 1e-9, "snapped to new floor");
    }

    #[test]
    fn advance_ignores_nonpositive_dt_and_all_idle() {
        let mut tree = ShareTree::new(&flat(&[1.0, 1.0]));
        tree.advance(0.0, &[5.0, 5.0], &[true, true]);
        tree.advance(-1.0, &[5.0, 5.0], &[true, true]);
        assert_eq!(tree.leaf_vtime(0), 0.0);
        // All idle: clocks hold.
        tree.advance(10.0, &[0.0, 0.0], &[false, false]);
        assert_eq!(tree.leaf_vtime(0), 0.0);
        assert_eq!(tree.leaf_vtime(1), 0.0);
    }
}
