//! The virtual cluster: HFSP's processor-sharing reference simulation
//! (§3.1 of the paper).
//!
//! HFSP keeps, per phase, a *fluid* simulation of what a max-min-fair
//! processor-sharing scheduler would do with the same jobs on the same
//! slots. Each job is represented by its **serialized work** (sum of task
//! runtimes, slot-independent — §3.1 "the size of a job is expressed in a
//! serialized form") progressing **virtually**:
//!
//! * **Job aging** (§3.1 "Job aging"): on every real event, the elapsed
//!   time since the previous event is distributed to jobs in proportion
//!   to their current max-min fair slot allocation and accumulated as
//!   virtual progress.
//! * **Max-min fairness** (§3.1 "Resource allocation"): slots are
//!   allocated by water-filling — the analytic fixed point of the paper's
//!   "round-robin mechanism that starts allocating virtual cluster
//!   resources to small jobs".
//! * **Virtual width**: a job's parallelism bound is the number of tasks
//!   it still has *in the virtual simulation* — `ceil(remaining / τ)`
//!   with τ the estimated mean task duration, capped by the phase's task
//!   count. The reference system is **independent of real progress**:
//!   coupling the width to real remaining tasks would corrupt the PS
//!   reference (a job the real cluster serves fast would look narrow,
//!   projecting a *later* PS finish and losing its priority — breaking
//!   FSP's dominance property).
//! * **Projected finish order**: a fluid-forward simulation computes the
//!   PS completion times; the *real* cluster schedules jobs in that order
//!   (that is FSP).
//!
//! The only couplings to the real world are: job arrival, size
//! (re-)estimation from the Training module, and removal on real
//! completion.
//!
//! ## Hot-path layout (§Perf iteration 4)
//!
//! This structure sits on the heartbeat hot path, so its storage is
//! **dense and incremental** rather than map-shaped:
//!
//! * live jobs are two parallel vectors (`ids` sorted ascending,
//!   `vjobs`) — aging is one linear pass with no per-call id collection
//!   and no hashing, and lookups are a binary search over a contiguous
//!   id array;
//! * the projected finish order is **cached and returned by slice**
//!   ([`VirtualCluster::projected_finish_order`]); aging advances the
//!   system *along* the cached fluid trajectory, so only structural
//!   changes (add / remove / estimate revision) mark the cache dirty and
//!   bump [`VirtualCluster::generation`] — consumers key their own
//!   derived caches (rank maps etc.) off that counter;
//! * every buffer the aging step and the fluid projection need (demands,
//!   allocations, water-fill index order, the forward job set) is
//!   scratch space owned by the struct and reused across events — the
//!   steady-state event loop performs **zero allocations** here.
//!
//! All float comparators use [`f64::total_cmp`]: a pathological estimate
//! stream (overflow to `inf`, denormals) must degrade to a clamped-but-
//! total order, never to a comparator panic mid-simulation.
//!
//! The max-min allocation is pluggable ([`MaxMinBackend`]): the native
//! rust water-filling below, or the AOT-compiled XLA kernel
//! ([`crate::runtime`]) — they are cross-checked by integration tests.

use crate::job::JobId;
use crate::sim::Time;

/// Computes a max-min fair allocation of `capacity` slots over per-job
/// demands. Implementations must satisfy (tested by `testkit` properties):
///
/// 1. `0 ≤ alloc_i ≤ demand_i`;
/// 2. `Σ alloc = min(capacity, Σ demand)`;
/// 3. bottleneck fairness: if `alloc_i < demand_i` then `alloc_i ≥ alloc_j`
///    for every j (unsatisfied jobs all sit at the common water level).
pub trait MaxMinBackend {
    fn allocate(&mut self, demands: &[f64], capacity: f64) -> Vec<f64>;

    /// Allocation without the per-call `Vec`: write into `out`
    /// (cleared first). Hot-path callers use this with a reusable
    /// buffer; the default delegates to [`MaxMinBackend::allocate`] for
    /// backends without an in-place implementation.
    fn allocate_into(&mut self, demands: &[f64], capacity: f64, out: &mut Vec<f64>) {
        let alloc = self.allocate(demands, capacity);
        out.clear();
        out.extend_from_slice(&alloc);
    }

    /// Batched allocation over independent capacity pools in **one**
    /// backend call: `demands` is the concatenation of per-segment
    /// demand slices, `segments` their `(len, capacity)` layout, and
    /// `out` (cleared first) receives the concatenated allocations in
    /// the same layout. Each segment is max-min fair *within itself* —
    /// segments never share capacity. This is the per-heartbeat
    /// map+reduce aging pair collapsed into a single backend dispatch
    /// ([`VirtualCluster::age_pair_to`]); batching must not change the
    /// numbers, so every implementation must match the per-segment
    /// [`MaxMinBackend::allocate_into`] loop exactly (pinned by test).
    fn allocate_segments_into(
        &mut self,
        demands: &[f64],
        segments: &[(usize, f64)],
        out: &mut Vec<f64>,
    ) {
        out.clear();
        let mut start = 0;
        let mut tmp = Vec::new();
        for &(len, capacity) in segments {
            self.allocate_into(&demands[start..start + len], capacity, &mut tmp);
            out.extend_from_slice(&tmp);
            start += len;
        }
        debug_assert_eq!(start, demands.len(), "segment layout covers the demands");
    }
}

/// Native water-filling max-min allocation (with a reusable index-order
/// scratch buffer for the in-place entry point).
#[derive(Default)]
pub struct NativeMaxMin {
    order: Vec<usize>,
}

impl MaxMinBackend for NativeMaxMin {
    fn allocate(&mut self, demands: &[f64], capacity: f64) -> Vec<f64> {
        let mut out = Vec::new();
        self.allocate_into(demands, capacity, &mut out);
        out
    }

    fn allocate_into(&mut self, demands: &[f64], capacity: f64, out: &mut Vec<f64>) {
        maxmin_waterfill_into(demands, capacity, out, &mut self.order);
    }

    /// Allocation-free batching: water-fill each segment directly into
    /// `out` (no per-segment temporary — the default's `tmp` vec).
    fn allocate_segments_into(
        &mut self,
        demands: &[f64],
        segments: &[(usize, f64)],
        out: &mut Vec<f64>,
    ) {
        out.clear();
        let mut start = 0;
        for &(len, capacity) in segments {
            maxmin_waterfill_append(&demands[start..start + len], capacity, out, &mut self.order);
            start += len;
        }
        debug_assert_eq!(start, demands.len(), "segment layout covers the demands");
    }
}

/// Water-filling in O(n log n).
pub fn maxmin_waterfill(demands: &[f64], capacity: f64) -> Vec<f64> {
    let mut out = Vec::new();
    let mut order = Vec::new();
    maxmin_waterfill_into(demands, capacity, &mut out, &mut order);
    out
}

/// [`maxmin_waterfill`] writing into caller-owned buffers (`alloc` and
/// the index-sort scratch are cleared and refilled; nothing allocates
/// once they have grown to the working size).
pub fn maxmin_waterfill_into(
    demands: &[f64],
    capacity: f64,
    alloc: &mut Vec<f64>,
    order: &mut Vec<usize>,
) {
    alloc.clear();
    maxmin_waterfill_append(demands, capacity, alloc, order);
}

/// [`maxmin_waterfill_into`] without the clear: the allocation is
/// **appended** to `alloc`, so independent capacity pools can be water-
/// filled back to back into one buffer
/// ([`MaxMinBackend::allocate_segments_into`]).
pub fn maxmin_waterfill_append(
    demands: &[f64],
    capacity: f64,
    alloc: &mut Vec<f64>,
    order: &mut Vec<usize>,
) {
    let n = demands.len();
    if n == 0 {
        return;
    }
    debug_assert!(demands.iter().all(|d| *d >= 0.0 && d.is_finite()));
    let base = alloc.len();
    let total: f64 = demands.iter().sum();
    if total <= capacity {
        // Everyone satisfied.
        alloc.extend_from_slice(demands);
        return;
    }
    // Sort indices by demand ascending; fill the water level.
    order.clear();
    order.extend(0..n);
    order.sort_by(|&a, &b| demands[a].total_cmp(&demands[b]));
    alloc.resize(base + n, 0.0);
    let mut remaining = capacity;
    for (rank, &i) in order.iter().enumerate() {
        let claim = remaining / (n - rank) as f64;
        let a = demands[i].min(claim);
        alloc[base + i] = a;
        remaining -= a;
    }
}

/// One job inside the virtual cluster.
#[derive(Clone, Debug)]
struct VJob {
    /// Estimated total serialized work of the phase, seconds.
    total: f64,
    /// Virtual progress accumulated by aging, seconds.
    aged: f64,
    /// Estimated mean task duration (τ = total / task count), seconds.
    tau: f64,
    /// Task count of the phase (upper bound on parallelism).
    width_cap: f64,
}

impl VJob {
    fn remaining(&self) -> f64 {
        (self.total - self.aged).max(0.0)
    }

    /// Virtual parallelism: tasks still present in the PS reference.
    fn width(&self) -> f64 {
        if self.tau <= 0.0 {
            return 0.0;
        }
        (self.remaining() / self.tau).ceil().min(self.width_cap)
    }
}

/// Clamp a size estimate to the finite non-negative range the fluid
/// simulation needs: an `inf` (or NaN, in release builds) reaching the
/// width computation would poison the max-min demands with NaN.
fn clamp_size(total: f64) -> f64 {
    debug_assert!(!total.is_nan(), "NaN size estimate");
    if total.is_nan() {
        0.0
    } else {
        total.clamp(0.0, f64::MAX)
    }
}

/// The per-phase virtual cluster.
pub struct VirtualCluster {
    slots: f64,
    /// Live job ids, sorted ascending; `vjobs` is index-parallel.
    ids: Vec<JobId>,
    vjobs: Vec<VJob>,
    last_event: Time,
    backend: Box<dyn MaxMinBackend>,
    /// Cached projected finish order, ascending (valid iff `cache_valid`).
    cached_order: Vec<(JobId, Time)>,
    cache_valid: bool,
    /// Bumped whenever the projection is invalidated; consumers key their
    /// own derived caches (rank maps etc.) off this.
    generation: u64,
    // -- reusable scratch (steady state allocates nothing) --------------
    demands: Vec<f64>,
    alloc: Vec<f64>,
    waterfill_order: Vec<usize>,
    fwd_live: Vec<(JobId, VJob)>,
}

impl VirtualCluster {
    pub fn new(slots: usize) -> Self {
        Self::with_backend(slots, Box::new(NativeMaxMin::default()))
    }

    pub fn with_backend(slots: usize, backend: Box<dyn MaxMinBackend>) -> Self {
        assert!(slots > 0, "virtual cluster needs capacity");
        Self {
            slots: slots as f64,
            ids: Vec::new(),
            vjobs: Vec::new(),
            last_event: 0.0,
            backend,
            cached_order: Vec::new(),
            cache_valid: false,
            generation: 0,
            demands: Vec::new(),
            alloc: Vec::new(),
            waterfill_order: Vec::new(),
            fwd_live: Vec::new(),
        }
    }

    /// Monotone counter identifying the current projection (changes when
    /// the projected order may have changed).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    pub fn len(&self) -> usize {
        self.ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ids.is_empty()
    }

    fn idx(&self, id: JobId) -> Option<usize> {
        self.ids.binary_search(&id).ok()
    }

    pub fn contains(&self, id: JobId) -> bool {
        self.idx(id).is_some()
    }

    /// Virtual remaining work of a job.
    pub fn remaining(&self, id: JobId) -> Option<f64> {
        self.idx(id).map(|i| self.vjobs[i].remaining())
    }

    /// Total remaining virtual work (diagnostics / invariant tests).
    pub fn total_remaining(&self) -> f64 {
        self.vjobs.iter().map(VJob::remaining).sum()
    }

    fn invalidate(&mut self) {
        self.cache_valid = false;
        self.generation += 1;
    }

    /// Advance the PS fluid simulation to `now`, distributing progress
    /// among jobs per the max-min allocation (job aging, §3.1). One
    /// linear pass over the dense job arrays into reusable buffers.
    pub fn age_to(&mut self, now: Time) {
        let dt = now - self.last_event;
        if dt < 0.0 {
            debug_assert!(dt > -1e-9, "aging backwards by {dt}");
            return;
        }
        self.last_event = now;
        if dt == 0.0 || self.vjobs.is_empty() {
            return;
        }
        self.demands.clear();
        let slots = self.slots;
        self.demands.extend(self.vjobs.iter().map(|j| j.width().min(slots)));
        self.backend.allocate_into(&self.demands, slots, &mut self.alloc);
        for (j, &a) in self.vjobs.iter_mut().zip(self.alloc.iter()) {
            // Progress is capped at the job's remaining work; the PS
            // fluid would reallocate its slots after its virtual finish,
            // which the next event's allocation captures.
            j.aged = (j.aged + a * dt).min(j.total);
        }
        // Aging advances the system ALONG the cached fluid trajectory:
        // the projected completion order and absolute finish times remain
        // valid, so the cache survives (a 5x end-to-end win — §Perf).
        // Only structural changes (add/remove/set_total) invalidate.
    }

    /// Age two phase clusters (map + reduce) to `now` with **one**
    /// batched backend call ([`MaxMinBackend::allocate_segments_into`])
    /// instead of two — the per-heartbeat aging pair of
    /// [`FspDiscipline`](crate::scheduler::disciplines::fsp::FspDiscipline).
    ///
    /// The batch applies only when both clusters advance by the same
    /// positive step and both hold jobs; otherwise (one side was aged
    /// mid-event by a structural change, or is empty) it falls back to
    /// two sequential [`VirtualCluster::age_to`] calls. Either path
    /// produces bit-identical progress (pinned by test): the batched
    /// segments are water-filled with exactly the per-phase arithmetic.
    pub fn age_pair_to(a: &mut VirtualCluster, b: &mut VirtualCluster, now: Time) {
        let dt = now - a.last_event;
        if dt != now - b.last_event || dt <= 0.0 || a.vjobs.is_empty() || b.vjobs.is_empty() {
            a.age_to(now);
            b.age_to(now);
            return;
        }
        a.last_event = now;
        b.last_event = now;
        let (slots_a, slots_b) = (a.slots, b.slots);
        a.demands.clear();
        a.demands.extend(a.vjobs.iter().map(|j| j.width().min(slots_a)));
        a.demands.extend(b.vjobs.iter().map(|j| j.width().min(slots_b)));
        let split = a.vjobs.len();
        let segments = [(split, slots_a), (a.demands.len() - split, slots_b)];
        // `a`'s backend serves the whole batch (both sides of an FSP
        // pair share the backend kind) and `a`'s scratch holds the
        // concatenated result.
        a.backend
            .allocate_segments_into(&a.demands, &segments, &mut a.alloc);
        for (j, &x) in a.vjobs.iter_mut().zip(a.alloc[..split].iter()) {
            j.aged = (j.aged + x * dt).min(j.total);
        }
        for (j, &x) in b.vjobs.iter_mut().zip(a.alloc[split..].iter()) {
            j.aged = (j.aged + x * dt).min(j.total);
        }
        // Pure aging: both caches stay valid (same contract as `age_to`).
    }

    /// Register a job's phase (ages the system first). `total` is the
    /// (initially estimated) serialized phase size; `n_tasks` its task
    /// count.
    pub fn add_job(&mut self, id: JobId, total: f64, n_tasks: usize, now: Time) {
        self.age_to(now);
        // An overflowing initial estimate clamps finite, same as
        // `set_total` (clamp_size still debug-asserts against NaN).
        let total = clamp_size(total);
        let width_cap = n_tasks.max(1) as f64;
        let vjob = VJob {
            total,
            aged: 0.0,
            tau: (total / width_cap).max(f64::MIN_POSITIVE),
            width_cap,
        };
        match self.ids.binary_search(&id) {
            Ok(i) => self.vjobs[i] = vjob, // re-registration replaces
            Err(i) => {
                self.ids.insert(i, id);
                self.vjobs.insert(i, vjob);
            }
        }
        self.invalidate();
    }

    pub fn remove_job(&mut self, id: JobId, now: Time) {
        self.age_to(now);
        if let Some(i) = self.idx(id) {
            self.ids.remove(i);
            self.vjobs.remove(i);
        }
        self.invalidate();
    }

    /// Replace the job's total-size estimate ("the job scheduler *updates*
    /// the remaining amount of work to be done for the job", §3.1.1).
    /// Virtual progress made so far is preserved; τ is refreshed.
    pub fn set_total(&mut self, id: JobId, new_total: f64, now: Time) {
        self.age_to(now);
        if let Some(i) = self.idx(id) {
            let j = &mut self.vjobs[i];
            j.total = clamp_size(new_total);
            j.tau = (j.total / j.width_cap).max(f64::MIN_POSITIVE);
            self.invalidate();
        }
    }

    /// Projected PS finish times, ascending — the FSP schedule. Jobs with
    /// zero virtual remaining work sort first (they are "virtually
    /// finished": the real cluster owes them service). Returns a borrow
    /// of the cache: valid until the next `&mut` call, recomputed only
    /// after a structural change (watch [`VirtualCluster::generation`]).
    pub fn projected_finish_order(&mut self) -> &[(JobId, Time)] {
        if !self.cache_valid {
            self.fluid_forward();
            self.cache_valid = true;
        }
        &self.cached_order
    }

    /// Fluid-forward simulation from `last_event` into `cached_order`:
    /// repeatedly allocate, jump to the next virtual completion (or
    /// width change), repeat. O(n² log n) worst case with n = active
    /// jobs; all working sets are reused scratch.
    fn fluid_forward(&mut self) {
        let mut live = std::mem::take(&mut self.fwd_live);
        let mut finished = std::mem::take(&mut self.cached_order);
        live.clear();
        finished.clear();
        // `ids` is sorted ascending, so `live` starts in deterministic
        // job-id order without a sort.
        live.extend(self.ids.iter().copied().zip(self.vjobs.iter().cloned()));
        let slots = self.slots;
        let mut t = self.last_event;
        // Jobs already at zero remaining finish "now".
        live.retain(|(id, j)| {
            if j.remaining() <= 0.0 {
                finished.push((*id, t));
                false
            } else {
                true
            }
        });
        let mut guard = 0usize;
        while !live.is_empty() {
            guard += 1;
            if guard > 100_000 {
                // Numerical stall: declare the rest finished at +inf.
                for (id, _) in &live {
                    finished.push((*id, f64::INFINITY));
                }
                break;
            }
            self.demands.clear();
            self.demands.extend(live.iter().map(|(_, j)| j.width().min(slots)));
            // The projection is an L3-internal fixed-point search that
            // re-solves the allocation O(n) times per call; it always uses
            // the native water-filling. The pluggable (XLA) backend serves
            // the actual PS allocation used for job aging in `age_to` —
            // one call per real event.
            maxmin_waterfill_into(
                &self.demands,
                slots,
                &mut self.alloc,
                &mut self.waterfill_order,
            );
            // Advance until the earliest fluid completion. Widths are
            // piecewise-constant per step (re-evaluated after every
            // completion): stepping on every integer width boundary would
            // make the projection O(total task count) — measured 40x
            // slower end-to-end for a negligible accuracy gain.
            let mut dt = f64::INFINITY;
            for ((_, j), &a) in live.iter().zip(self.alloc.iter()) {
                if a <= 0.0 {
                    continue;
                }
                dt = dt.min(j.remaining() / a);
            }
            if !dt.is_finite() || dt <= 0.0 {
                // No progress possible (all allocations zero) — cannot
                // happen with positive widths, but guard against a stuck
                // loop.
                for (id, _) in &live {
                    finished.push((*id, f64::INFINITY));
                }
                break;
            }
            t += dt;
            // Apply the step and compact survivors in place (stable: the
            // write cursor only ever trails the read cursor).
            let mut keep = 0usize;
            for i in 0..live.len() {
                let a = self.alloc[i];
                let done = {
                    let j = &mut live[i].1;
                    j.aged = (j.aged + a * dt).min(j.total);
                    j.remaining() <= 1e-9
                };
                if done {
                    finished.push((live[i].0, t));
                } else {
                    live.swap(keep, i);
                    keep += 1;
                }
            }
            live.truncate(keep);
        }
        // Ascending by projected finish; stable by job id for ties
        // (earlier submission wins, as in the paper's examples).
        finished.sort_by(|a, b| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)));
        self.fwd_live = live;
        self.cached_order = finished;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // -- water-filling ----------------------------------------------------

    #[test]
    fn waterfill_all_satisfied_under_capacity() {
        let a = maxmin_waterfill(&[1.0, 2.0, 3.0], 10.0);
        assert_eq!(a, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn waterfill_even_split_when_equal_demands() {
        let a = maxmin_waterfill(&[5.0, 5.0, 5.0], 6.0);
        for x in &a {
            assert!((x - 2.0).abs() < 1e-12);
        }
    }

    #[test]
    fn waterfill_small_jobs_fully_served_first() {
        // Demands 1, 10, 10 with capacity 9: small job gets its 1, the two
        // big ones split the rest 4/4.
        let a = maxmin_waterfill(&[1.0, 10.0, 10.0], 9.0);
        assert!((a[0] - 1.0).abs() < 1e-12);
        assert!((a[1] - 4.0).abs() < 1e-12);
        assert!((a[2] - 4.0).abs() < 1e-12);
    }

    #[test]
    fn waterfill_conserves_capacity() {
        let d = [3.0, 0.5, 7.0, 2.0, 9.0];
        let a = maxmin_waterfill(&d, 10.0);
        let sum: f64 = a.iter().sum();
        assert!((sum - 10.0).abs() < 1e-9);
        for (x, dem) in a.iter().zip(&d) {
            assert!(*x <= dem + 1e-12);
            assert!(*x >= 0.0);
        }
    }

    #[test]
    fn waterfill_empty_and_zero() {
        assert!(maxmin_waterfill(&[], 5.0).is_empty());
        let a = maxmin_waterfill(&[0.0, 4.0], 2.0);
        assert_eq!(a[0], 0.0);
        assert!((a[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn waterfill_into_reuses_buffers() {
        let mut alloc = Vec::new();
        let mut order = Vec::new();
        maxmin_waterfill_into(&[4.0, 4.0], 4.0, &mut alloc, &mut order);
        assert_eq!(alloc.len(), 2);
        assert!((alloc[0] - 2.0).abs() < 1e-12);
        // A second call with fewer demands shrinks the result in place.
        maxmin_waterfill_into(&[1.0], 4.0, &mut alloc, &mut order);
        assert_eq!(alloc, vec![1.0]);
        // Backend entry point agrees with the free function.
        let mut native = NativeMaxMin::default();
        let mut out = Vec::new();
        native.allocate_into(&[1.0, 10.0, 10.0], 9.0, &mut out);
        assert_eq!(out, maxmin_waterfill(&[1.0, 10.0, 10.0], 9.0));
    }

    #[test]
    fn waterfill_append_concatenates_independent_pools() {
        let mut alloc = Vec::new();
        let mut order = Vec::new();
        maxmin_waterfill_append(&[1.0, 10.0, 10.0], 9.0, &mut alloc, &mut order);
        maxmin_waterfill_append(&[5.0, 5.0], 6.0, &mut alloc, &mut order);
        maxmin_waterfill_append(&[], 4.0, &mut alloc, &mut order);
        assert_eq!(alloc.len(), 5);
        assert_eq!(&alloc[..3], maxmin_waterfill(&[1.0, 10.0, 10.0], 9.0).as_slice());
        assert_eq!(&alloc[3..], maxmin_waterfill(&[5.0, 5.0], 6.0).as_slice());
    }

    #[test]
    fn allocate_segments_matches_the_per_segment_loop_exactly() {
        // The batched entry point must be *bit-identical* to looping
        // allocate_into over the segments — batching is a dispatch
        // optimization, never a numerical change.
        let demands = [3.0, 0.5, 7.0, 2.0, 9.0, 1.0, 10.0, 10.0];
        let segments = [(5usize, 4.0), (3usize, 9.0)];
        let mut native = NativeMaxMin::default();
        let mut batched = Vec::new();
        native.allocate_segments_into(&demands, &segments, &mut batched);
        let mut looped = Vec::new();
        let mut tmp = Vec::new();
        let mut start = 0;
        for &(len, capacity) in &segments {
            native.allocate_into(&demands[start..start + len], capacity, &mut tmp);
            looped.extend_from_slice(&tmp);
            start += len;
        }
        assert_eq!(batched, looped);
        // An under-capacity segment next to a saturated one.
        let segments = [(5usize, 100.0), (3usize, 9.0)];
        native.allocate_segments_into(&demands, &segments, &mut batched);
        assert_eq!(&batched[..5], &demands[..5], "satisfied segment copies through");
        assert_eq!(&batched[5..], maxmin_waterfill(&demands[5..], 9.0).as_slice());
    }

    #[test]
    fn age_pair_matches_sequential_aging_exactly() {
        let build = || {
            let mut m = VirtualCluster::new(4);
            let mut r = VirtualCluster::new(2);
            m.add_job(1, 50.0, 4, 0.0);
            m.add_job(2, 30.0, 8, 0.0);
            m.add_job(3, 7.0, 1, 0.0);
            r.add_job(1, 20.0, 2, 0.0);
            r.add_job(2, 60.0, 6, 0.0);
            (m, r)
        };
        let (mut m1, mut r1) = build();
        let (mut m2, mut r2) = build();
        for t in [2.0, 5.5, 9.0, 9.0, 31.0] {
            m1.age_to(t);
            r1.age_to(t);
            VirtualCluster::age_pair_to(&mut m2, &mut r2, t);
        }
        for id in [1, 2, 3] {
            // Bitwise equality: the batch is the same arithmetic.
            assert_eq!(m1.remaining(id), m2.remaining(id), "map job {id}");
            assert_eq!(r1.remaining(id), r2.remaining(id), "reduce job {id}");
        }
    }

    #[test]
    fn age_pair_falls_back_when_clocks_diverge_or_a_side_is_empty() {
        let mut m = VirtualCluster::new(2);
        let mut r = VirtualCluster::new(2);
        m.add_job(1, 10.0, 2, 0.0);
        r.add_job(1, 12.0, 2, 0.0);
        // Desynchronize the clocks: m was aged mid-event.
        m.age_to(1.0);
        VirtualCluster::age_pair_to(&mut m, &mut r, 3.0);
        assert!((m.remaining(1).unwrap() - 4.0).abs() < 1e-12);
        assert!((r.remaining(1).unwrap() - 6.0).abs() < 1e-12);
        // One side empty: the non-empty side still ages.
        let mut empty = VirtualCluster::new(2);
        VirtualCluster::age_pair_to(&mut m, &mut empty, 4.0);
        assert!((m.remaining(1).unwrap() - 2.0).abs() < 1e-12);
        assert!(empty.is_empty());
    }

    // -- virtual cluster ---------------------------------------------------

    /// The paper's Fig. 1 scenario on a single-slot server: serialized
    /// sizes 30/10/10, arrivals 0/10/15. Under PS, completion order is
    /// j2, j3, j1.
    #[test]
    fn fig1_ps_order() {
        let mut vc = VirtualCluster::new(1);
        vc.add_job(1, 30.0, 10, 0.0);
        vc.add_job(2, 10.0, 10, 10.0);
        // After 10 s alone, j1 has 20 left.
        assert!((vc.remaining(1).unwrap() - 20.0).abs() < 1e-9);
        vc.add_job(3, 10.0, 10, 15.0);
        // j1 and j2 shared [10,15]: j1 = 17.5, j2 = 7.5.
        assert!((vc.remaining(1).unwrap() - 17.5).abs() < 1e-9);
        assert!((vc.remaining(2).unwrap() - 7.5).abs() < 1e-9);
        let order = vc.projected_finish_order();
        let ids: Vec<JobId> = order.iter().map(|&(id, _)| id).collect();
        assert_eq!(ids, vec![2, 3, 1], "PS completion order of Fig. 1");
        assert!(order[0].1 <= order[1].1 && order[1].1 <= order[2].1);
    }

    #[test]
    fn narrow_job_progresses_at_its_width() {
        // One job with a single 10 s task on a 4-slot virtual cluster:
        // progresses at 1 slot-rate even though capacity is 4.
        let mut vc = VirtualCluster::new(4);
        vc.add_job(1, 10.0, 1, 0.0);
        vc.age_to(5.0);
        assert!((vc.remaining(1).unwrap() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn wide_job_uses_full_capacity() {
        let mut vc = VirtualCluster::new(4);
        vc.add_job(1, 40.0, 100, 0.0);
        vc.age_to(5.0);
        assert!((vc.remaining(1).unwrap() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn maxmin_prioritizes_small_width_jobs() {
        // Widths 1 and 10, capacity 4 => allocations 1 and 3.
        let mut vc = VirtualCluster::new(4);
        vc.add_job(1, 100.0, 1, 0.0);
        vc.add_job(2, 100.0, 10, 0.0);
        vc.age_to(10.0);
        assert!((vc.remaining(1).unwrap() - 90.0).abs() < 1e-9);
        assert!((vc.remaining(2).unwrap() - 70.0).abs() < 1e-9);
    }

    #[test]
    fn virtual_width_shrinks_with_progress_only() {
        // 10 tasks x 10 s on a 100-slot cluster: width starts at 10;
        // after aging most of the work away the virtual width drops.
        let mut vc = VirtualCluster::new(100);
        vc.add_job(1, 100.0, 10, 0.0);
        // Alone, the job gets its full width 10 -> rate 10/s.
        vc.age_to(9.5);
        let rem = vc.remaining(1).unwrap();
        assert!(rem < 10.0, "rem {rem}");
        // The projected finish accounts for the final narrow wave.
        let order = vc.projected_finish_order();
        assert_eq!(order[0].0, 1);
    }

    #[test]
    fn set_total_preserves_virtual_progress() {
        let mut vc = VirtualCluster::new(2);
        vc.add_job(1, 100.0, 2, 0.0);
        vc.age_to(5.0); // aged 10 (width 2)
        vc.set_total(1, 50.0, 5.0);
        assert!((vc.remaining(1).unwrap() - 40.0).abs() < 1e-9);
    }

    #[test]
    fn zero_remaining_sorts_first() {
        let mut vc = VirtualCluster::new(1);
        vc.add_job(1, 5.0, 1, 0.0);
        vc.add_job(2, 100.0, 1, 0.0);
        vc.age_to(11.0); // j1's share (1/2 slot * 11 s) exceeds its size
        assert_eq!(vc.projected_finish_order()[0].0, 1);
        assert!(vc.remaining(1).unwrap() <= 1e-9);
    }

    #[test]
    fn remove_job_drops_it() {
        let mut vc = VirtualCluster::new(1);
        vc.add_job(1, 5.0, 1, 0.0);
        vc.add_job(2, 5.0, 1, 0.0);
        vc.remove_job(1, 1.0);
        assert!(!vc.contains(1));
        let order = vc.projected_finish_order();
        assert_eq!(order.len(), 1);
        assert_eq!(order[0].0, 2);
    }

    #[test]
    fn projected_order_cache_invalidation() {
        let mut vc = VirtualCluster::new(1);
        vc.add_job(1, 10.0, 1, 0.0);
        vc.add_job(2, 20.0, 1, 0.0);
        assert_eq!(vc.projected_finish_order()[0].0, 1);
        let g = vc.generation();
        // Shrink job 2's estimate drastically: order must flip and the
        // generation counter must move (derived caches re-key off it).
        vc.set_total(2, 1.0, 0.0);
        assert_ne!(vc.generation(), g);
        assert_eq!(vc.projected_finish_order()[0].0, 2);
    }

    #[test]
    fn aging_preserves_the_cached_projection_and_generation() {
        let mut vc = VirtualCluster::new(2);
        vc.add_job(1, 10.0, 2, 0.0);
        vc.add_job(2, 40.0, 2, 0.0);
        let before: Vec<(JobId, Time)> = vc.projected_finish_order().to_vec();
        let g = vc.generation();
        // Pure aging moves along the fluid trajectory: same absolute
        // finish times, same order, same generation — the cache slice is
        // served without recomputation.
        vc.age_to(3.0);
        assert_eq!(vc.generation(), g, "aging must not invalidate");
        let after = vc.projected_finish_order();
        assert_eq!(before.len(), after.len());
        for (b, a) in before.iter().zip(after.iter()) {
            assert_eq!(b.0, a.0);
            assert!((b.1 - a.1).abs() < 1e-9);
        }
    }

    #[test]
    fn adversarial_estimates_never_panic_the_comparators() {
        // NaN-free but hostile estimate stream: overflowing, zero and
        // denormal sizes must clamp into a total order, not panic the
        // water-fill or finish-order sort (regression for the
        // `partial_cmp(..).unwrap()` footgun).
        let mut vc = VirtualCluster::new(4);
        vc.add_job(1, 100.0, 4, 0.0);
        vc.add_job(2, 50.0, 2, 0.0);
        vc.add_job(3, 25.0, 1, 0.0);
        for (id, est) in [
            (1, f64::INFINITY),
            (2, f64::MAX),
            (3, 0.0),
            (1, 1e-300),
            (2, f64::MIN_POSITIVE),
            (3, 1e308),
        ] {
            vc.set_total(id, est, 0.0);
            vc.age_to(vc.last_event + 1.0);
            let order = vc.projected_finish_order();
            assert_eq!(order.len(), 3, "every job stays ordered");
            assert!(order.windows(2).all(|w| w[0].1 <= w[1].1));
        }
        // The infinite estimate was clamped finite: totals stay usable.
        assert!(vc.total_remaining().is_finite());
    }

    #[test]
    fn real_progress_does_not_affect_the_reference() {
        // The PS reference only changes through aging and estimates: two
        // clusters with identical inputs stay identical regardless of
        // what the real cluster does (there is no width coupling to real
        // task completions — by design).
        let mut a = VirtualCluster::new(3);
        let mut b = VirtualCluster::new(3);
        for vc in [&mut a, &mut b] {
            vc.add_job(1, 50.0, 2, 0.0);
            vc.add_job(2, 30.0, 5, 0.0);
        }
        a.age_to(4.0);
        a.age_to(10.0);
        b.age_to(10.0);
        assert!((a.remaining(1).unwrap() - b.remaining(1).unwrap()).abs() < 1e-9);
        assert!((a.remaining(2).unwrap() - b.remaining(2).unwrap()).abs() < 1e-9);
    }

    #[test]
    fn wide_fresh_job_does_not_leapfrog_served_job() {
        // Regression test for the width-coupling bug: job 1 (small) is
        // being served fast by the real cluster; job 2 (large, wide)
        // arrives later. In the PS reference job 1 still finishes first.
        let mut vc = VirtualCluster::new(400);
        vc.add_job(1, 5_700.0, 164, 0.0); // ~35 s tasks
        vc.age_to(35.0);
        vc.add_job(2, 13_000.0, 381, 35.0);
        let order = vc.projected_finish_order();
        assert_eq!(order[0].0, 1, "smaller earlier job keeps PS priority");
    }
}
