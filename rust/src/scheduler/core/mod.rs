//! The size-based scheduling **mechanism** (the paper's §3 machinery,
//! made policy-agnostic).
//!
//! The paper observes that "the architecture underlying HFSP is suitable
//! for any size-based scheduling discipline". This module is that
//! architecture, extracted from the original HFSP implementation into a
//! reusable core:
//!
//! * **job-size estimation** — the [`training`] module samples task
//!   runtimes and fits the task-time distribution with a pluggable
//!   [`estimator`] (§3.1.1, §3.2);
//! * **virtual-time / virtual-cluster accounting** — the
//!   [`virtual_cluster`] fluid PS reference simulation used by the FSP
//!   discipline (§3.1);
//! * **preemption machinery** — SUSPEND/RESUME/KILL primitives with the
//!   suspension-pressure hysteresis guard ([`preemption`], §3.3) plus
//!   delay scheduling for map locality (§3.1);
//! * the **heartbeat assignment loop** ([`SizeBasedScheduler`]):
//!   training-priority slots, fill-in-priority-order, preempt-the-worst.
//!
//! The **policy** — in which order jobs are served — is supplied by a
//! [`Discipline`] implementation ([`crate::scheduler::disciplines`]):
//! FSP (= HFSP), SRPT, LAS and a PSBS-style virtual-time variant all run
//! on this one mechanism. A discipline that does not consume size
//! estimates (LAS) simply reports [`DisciplineKind::uses_estimates`] =
//! `false` and the mechanism skips the training module entirely.

pub mod estimator;
pub mod preemption;
pub mod training;
pub mod virtual_cluster;
pub mod xla_estimator;

pub use preemption::{PreemptionPrimitive, SuspensionGuard};

use self::estimator::{MeanEstimator, NativeEstimator, SizeEstimator};
use self::training::{TrainingModule, TrainingUpdate};
use self::virtual_cluster::{MaxMinBackend, NativeMaxMin};
use super::delay::{pick_reduce, DelayTimer, LocalityIndex};
use super::disciplines::{self, DisciplineKind};
use super::{Action, SchedView, Scheduler};
use crate::faults::ErrorModel;
use crate::job::task::NodeId;
use crate::job::{Job, JobId, Phase, TaskRef};
use crate::sim::Time;
use crate::util::fxmap::{FastMap, FastSet};
use std::path::PathBuf;

/// Which size-estimator implementation the Training module uses.
#[derive(Clone, Debug, Default)]
pub enum EstimatorKind {
    /// Pure-rust least-squares quantile estimator (reference).
    #[default]
    Native,
    /// First-order statistics only (ablation baseline).
    Mean,
    /// The AOT-compiled JAX/Pallas estimator, executed via PJRT.
    /// Panics at construction if the artifact is missing — run
    /// `make artifacts` first.
    Xla { artifact_dir: PathBuf },
}

impl EstimatorKind {
    pub fn build(&self) -> Box<dyn SizeEstimator> {
        match self {
            EstimatorKind::Native => Box::new(NativeEstimator::new()),
            EstimatorKind::Mean => Box::new(MeanEstimator),
            EstimatorKind::Xla { artifact_dir } => Box::new(
                xla_estimator::XlaSizeEstimator::load(artifact_dir)
                    .expect("loading XLA estimator artifact (run `make artifacts`)"),
            ),
        }
    }
}

/// Which max-min backend the virtual cluster uses.
#[derive(Clone, Debug, Default)]
pub enum MaxMinKind {
    #[default]
    Native,
    /// AOT-compiled water-filling kernel via PJRT.
    Xla { artifact_dir: PathBuf },
}

impl MaxMinKind {
    pub fn build(&self) -> Box<dyn MaxMinBackend> {
        match self {
            MaxMinKind::Native => Box::new(NativeMaxMin::default()),
            MaxMinKind::Xla { artifact_dir } => Box::new(
                xla_estimator::XlaMaxMin::load(artifact_dir)
                    .expect("loading XLA maxmin artifact (run `make artifacts`)"),
            ),
        }
    }
}

/// Configuration of the size-based core (defaults = the paper's
/// experimental setup, §4.1). The `discipline` field selects the
/// ordering policy; everything else parameterizes the shared mechanism.
///
/// [`HfspConfig`] is an alias of this type: HFSP is exactly this core
/// driven by the FSP discipline.
#[derive(Clone, Debug)]
pub struct SizeBasedConfig {
    /// The ordering policy run on top of the mechanism.
    pub discipline: DisciplineKind,
    /// Sample-set size for MAP and REDUCE estimation (paper: 5).
    pub sample_set: usize,
    /// Confidence parameter ξ ∈ [1, ∞) weighting initial estimates
    /// (paper: 1).
    pub xi: f64,
    /// Delay-scheduling locality timeout, seconds.
    pub locality_timeout_s: f64,
    /// Preemption primitive (paper default: eager suspension).
    pub preemption: PreemptionPrimitive,
    /// Cluster-wide suspended-task hysteresis thresholds (§3.3 "Finite
    /// machine resources").
    pub suspend_hi: usize,
    pub suspend_lo: usize,
    /// Cap on slots the top-level scheduler grants the Training module
    /// (paper: all slots).
    pub max_training_slots: usize,
    /// Minimum priority-key gap between the preempting job and its
    /// victim before preemption fires (in the discipline's key units —
    /// projected finish seconds for FSP, remaining serialized seconds
    /// for SRPT, attained seconds for LAS, virtual seconds for PSBS).
    /// Guards against mutual-preemption thrash when two jobs' keys are
    /// nearly equal.
    pub preempt_threshold_s: f64,
    /// Fig. 6 artificial estimation error α (0 disables).
    pub error_alpha: f64,
    /// Log-normal (median-1) estimation-error σ from the fault
    /// subsystem's robustness model (0 disables; takes precedence over
    /// `error_alpha` when both are set).
    pub error_sigma: f64,
    pub error_seed: u64,
    pub estimator: EstimatorKind,
    pub maxmin: MaxMinKind,
}

impl Default for SizeBasedConfig {
    fn default() -> Self {
        Self {
            discipline: DisciplineKind::Fsp,
            sample_set: 5,
            xi: 1.0,
            locality_timeout_s: 5.0,
            preemption: PreemptionPrimitive::Suspend,
            suspend_hi: 600,
            suspend_lo: 300,
            max_training_slots: usize::MAX,
            preempt_threshold_s: 20.0,
            error_alpha: 0.0,
            error_sigma: 0.0,
            error_seed: 0,
            estimator: EstimatorKind::Native,
            maxmin: MaxMinKind::Native,
        }
    }
}

/// HFSP's historical configuration type: the size-based core with the
/// FSP discipline (the default).
pub type HfspConfig = SizeBasedConfig;

impl SizeBasedConfig {
    fn build_estimator(&self) -> Box<dyn SizeEstimator> {
        self.estimator.build()
    }
}

/// The ordering **policy** plugged into [`SizeBasedScheduler`].
///
/// The mechanism notifies the discipline of every job-lifecycle event it
/// needs to maintain a total job order per phase; the discipline answers
/// [`Discipline::order`] queries with `(job, priority key)` pairs sorted
/// ascending (earlier = served first). Key units are
/// discipline-specific; the mechanism only compares key *gaps* against
/// [`SizeBasedConfig::preempt_threshold_s`].
///
/// Contract (asserted by `scheduler::disciplines` unit tests and the
/// cross-discipline property harness in `tests/properties.rs`):
///
/// 1. `order(phase)` contains exactly the jobs whose phase has started
///    and not yet completed/been removed;
/// 2. the order is deterministic (ties broken by job id);
/// 3. [`Discipline::generation`] changes whenever `order` may have —
///    the mechanism caches rank lookups keyed on it.
pub trait Discipline {
    /// Cluster capacity became known (total slots per phase). Called
    /// once, before any other hook.
    fn bind_capacity(&mut self, map_slots: usize, reduce_slots: usize);

    /// A job's phase entered the system. `initial_size` is the training
    /// module's initial serialized-size estimate (0 when the discipline
    /// does not use estimates); `n_tasks` the phase's task count.
    fn phase_started(
        &mut self,
        id: JobId,
        phase: Phase,
        initial_size: f64,
        n_tasks: usize,
        now: Time,
    );

    /// The training module delivered or revised the phase-size estimate
    /// (total serialized seconds). Never called for disciplines with
    /// [`DisciplineKind::uses_estimates`] = `false`.
    fn size_estimated(&mut self, id: JobId, phase: Phase, total: f64, now: Time);

    /// A task attempt of the phase completed `observed` seconds of
    /// serialized work (attained service).
    fn service_observed(&mut self, id: JobId, phase: Phase, observed: f64, now: Time);

    /// The phase really completed on the cluster.
    fn phase_completed(&mut self, id: JobId, phase: Phase, now: Time);

    /// The job left the system: drop all of its state.
    fn job_removed(&mut self, id: JobId, now: Time);

    /// Advance internal clocks to `now` (called once per heartbeat,
    /// before any `order` query).
    fn advance(&mut self, now: Time);

    /// Cache version for `phase`: the mechanism re-derives its rank maps
    /// only when this changes.
    fn generation(&self, phase: Phase) -> u64;

    /// Total job order for `phase`: ascending priority key. Returns a
    /// borrow of the discipline's internal cache — valid until the next
    /// `&mut` call, recomputed (at most) when
    /// [`Discipline::generation`] has moved. Implementations must not
    /// allocate when the order is unchanged.
    fn order(&mut self, phase: Phase) -> &[(JobId, f64)];

    /// Diagnostic remaining-work figure (trace logging only).
    fn remaining(&self, id: JobId, phase: Phase) -> Option<f64> {
        let _ = (id, phase);
        None
    }
}

/// Cached priority view derived from the discipline's job order, keyed
/// by the discipline's generation counter (recomputing rank/key maps on
/// every heartbeat dominated the hot path — §Perf iteration 2). The
/// order is copied from the discipline's cache slice and the rank/key
/// lookups live in one reusable [`FastMap`] (§Perf iteration 4: one
/// hash per lookup instead of two, deterministic fixed-seed hashing,
/// zero steady-state allocation).
#[derive(Default)]
pub(crate) struct OrderCache {
    generation: u64,
    valid: bool,
    /// `(job, priority key)` pairs, ascending key.
    pub(crate) order: Vec<(JobId, f64)>,
    /// job → (rank, priority key).
    rank: FastMap<JobId, (usize, f64)>,
}

impl OrderCache {
    pub(crate) fn refresh(&mut self, discipline: &mut dyn Discipline, phase: Phase) {
        let generation = discipline.generation(phase);
        if self.valid && self.generation == generation {
            return;
        }
        let projected = discipline.order(phase);
        self.order.clear();
        self.order.extend_from_slice(projected);
        self.rank.clear();
        for (r, &(id, t)) in self.order.iter().enumerate() {
            self.rank.insert(id, (r, t));
        }
        self.generation = generation;
        self.valid = true;
    }

    pub(crate) fn rank_of(&self, id: JobId) -> Option<usize> {
        self.rank.get(&id).map(|&(r, _)| r)
    }

    pub(crate) fn key_of(&self, id: JobId) -> Option<f64> {
        self.rank.get(&id).map(|&(_, k)| k)
    }
}

/// The size-based scheduler: mechanism core + pluggable ordering
/// discipline. With [`DisciplineKind::Fsp`] this is exactly the paper's
/// HFSP (and produces byte-identical schedules to the pre-split
/// implementation).
pub struct SizeBasedScheduler {
    cfg: SizeBasedConfig,
    discipline: Box<dyn Discipline>,
    /// `None` for size-oblivious disciplines (LAS): no sample sets, no
    /// training-priority slots, no estimator.
    training: Option<TrainingModule>,
    index: LocalityIndex,
    delay: DelayTimer,
    guard: SuspensionGuard,
    /// Jobs whose reduce phase has been registered with the discipline.
    reduce_started: FastSet<JobId>,
    order_map: OrderCache,
    order_reduce: OrderCache,
    /// Lazily sized from the first view (cluster capacity per phase).
    sized: bool,
    /// Reusable per-heartbeat working sets (§Perf iteration 4: two set
    /// and one vec allocation per phase per heartbeat, gone).
    scratch_picked: FastSet<TaskRef>,
    scratch_resumed: FastSet<TaskRef>,
    scratch_victims: Vec<TaskRef>,
}

impl SizeBasedScheduler {
    pub fn new(cfg: SizeBasedConfig) -> Self {
        let discipline = disciplines::build(&cfg);
        let training = if cfg.discipline.uses_estimates() {
            let error = if cfg.error_sigma > 0.0 {
                Some(ErrorModel::log_normal(cfg.error_sigma, cfg.error_seed))
            } else if cfg.error_alpha > 0.0 {
                Some(ErrorModel::uniform(cfg.error_alpha, cfg.error_seed))
            } else {
                None
            };
            Some(TrainingModule::new(
                cfg.sample_set,
                cfg.xi,
                cfg.build_estimator(),
                error,
            ))
        } else {
            None
        };
        let guard = SuspensionGuard::new(cfg.suspend_hi, cfg.suspend_lo);
        let delay = DelayTimer::new(cfg.locality_timeout_s);
        Self {
            cfg,
            discipline,
            training,
            index: LocalityIndex::new(),
            delay,
            guard,
            reduce_started: FastSet::default(),
            order_map: OrderCache::default(),
            order_reduce: OrderCache::default(),
            sized: false,
            scratch_picked: FastSet::default(),
            scratch_resumed: FastSet::default(),
            scratch_victims: Vec::new(),
        }
    }

    fn ensure_sized(&mut self, view: &SchedView) {
        if !self.sized {
            let map_slots = view.cluster.total_slots(Phase::Map).max(1);
            let red_slots = view.cluster.total_slots(Phase::Reduce).max(1);
            self.discipline.bind_capacity(map_slots, red_slots);
            self.sized = true;
        }
    }

    /// Initial size estimate for a starting phase: the training module's
    /// history-based guess, or 0 for size-oblivious disciplines.
    fn initial_estimate(&mut self, id: JobId, phase: Phase, n_tasks: usize) -> f64 {
        match &mut self.training {
            Some(t) => t.start_phase(id, phase, n_tasks),
            None => 0.0,
        }
    }

    /// Register a job's reduce phase with the discipline (at arrival for
    /// map-less jobs, else when the map phase completes).
    fn start_reduce_phase(&mut self, view: &SchedView, id: JobId) {
        if !self.reduce_started.insert(id) {
            return;
        }
        let n = view.jobs[&id].spec.n_reduces();
        if n == 0 {
            return;
        }
        let initial = self.initial_estimate(id, Phase::Reduce, n);
        self.discipline
            .phase_started(id, Phase::Reduce, initial, n, view.now);
    }

    /// Pick a map task for `job` on `node` under delay scheduling.
    fn pick_map(
        &mut self,
        view: &SchedView,
        job: &Job,
        node: NodeId,
        picked: &FastSet<TaskRef>,
    ) -> Option<(TaskRef, bool)> {
        if let Some(t) = self.index.pick_local(job, node, picked) {
            self.delay.clear(job.id());
            return Some((t, true));
        }
        if job.pending_tasks(Phase::Map) == 0 {
            return None;
        }
        if self.delay.skip_and_check(job.id(), view.now) {
            if let Some(t) = self.index.pick_any(job, picked) {
                self.delay.clear(job.id());
                return Some((t, false));
            }
        }
        None
    }

    /// Pick any schedulable task of `job`/`phase` for `node`.
    fn pick_task(
        &mut self,
        view: &SchedView,
        job: &Job,
        phase: Phase,
        node: NodeId,
        picked: &FastSet<TaskRef>,
    ) -> Option<(TaskRef, bool)> {
        match phase {
            Phase::Map => self.pick_map(view, job, node, picked),
            Phase::Reduce => pick_reduce(job, picked).map(|t| (t, true)),
        }
    }

    /// A suspended task of `job` parked on `node` not yet resumed in this
    /// batch.
    fn suspended_here(
        view: &SchedView,
        job: JobId,
        phase: Phase,
        node: NodeId,
        resumed: &FastSet<TaskRef>,
    ) -> Option<TaskRef> {
        view.cluster
            .node(node)
            .suspended_tasks()
            .find(|t| t.job == job && t.phase == phase && !resumed.contains(t))
    }

    /// Assignment + preemption for one phase on one heartbeat.
    fn assign_phase(
        &mut self,
        view: &SchedView,
        node: NodeId,
        phase: Phase,
        actions: &mut Vec<Action>,
        ctx_budget: &mut usize,
    ) {
        // Priority order from the discipline (cached across heartbeats
        // until the discipline's generation changes); the cache and the
        // scratch working sets are taken out of `self` for the duration
        // of the call so the borrow checker allows `&mut self` pickers
        // (§Perf iteration 3: cloning the rank/key maps per heartbeat
        // was measurable; iteration 4 made the working sets reusable).
        match phase {
            Phase::Map => self.order_map.refresh(self.discipline.as_mut(), phase),
            Phase::Reduce => self.order_reduce.refresh(self.discipline.as_mut(), phase),
        }
        let cache = match phase {
            Phase::Map => std::mem::take(&mut self.order_map),
            Phase::Reduce => std::mem::take(&mut self.order_reduce),
        };
        let mut picked = std::mem::take(&mut self.scratch_picked);
        let mut resumed = std::mem::take(&mut self.scratch_resumed);
        let mut victims = std::mem::take(&mut self.scratch_victims);
        picked.clear();
        resumed.clear();
        self.assign_phase_inner(
            view,
            node,
            phase,
            actions,
            ctx_budget,
            &cache,
            &mut picked,
            &mut resumed,
            &mut victims,
        );
        self.scratch_picked = picked;
        self.scratch_resumed = resumed;
        self.scratch_victims = victims;
        match phase {
            Phase::Map => self.order_map = cache,
            Phase::Reduce => self.order_reduce = cache,
        }
    }

    #[allow(clippy::too_many_lines, clippy::too_many_arguments)]
    fn assign_phase_inner(
        &mut self,
        view: &SchedView,
        node: NodeId,
        phase: Phase,
        actions: &mut Vec<Action>,
        ctx_budget: &mut usize,
        cache: &OrderCache,
        picked: &mut FastSet<TaskRef>,
        resumed: &mut FastSet<TaskRef>,
        victims: &mut Vec<TaskRef>,
    ) {
        let mut free = view.cluster.node(node).free_slots(phase);
        if node == 0 && phase == Phase::Map && log::log_enabled!(log::Level::Trace) {
            let head: Vec<String> = cache
                .order
                .iter()
                .take(4)
                .map(|&(id, key)| {
                    let j = &view.jobs[&id];
                    format!(
                        "j{id}(key={key:.0},rem={:.0},pend={},run={})",
                        self.discipline.remaining(id, phase).unwrap_or(-1.0),
                        j.pending_tasks(Phase::Map),
                        j.running_tasks(Phase::Map)
                    )
                })
                .collect();
            log::trace!("t={:.0} map order: {}", view.now, head.join(" "));
        }

        // -- Stage 0: training-priority assignments (§3.1.1) ------------
        // Jobs still collecting samples get their sample set scheduled
        // with priority, ordered by fewer remaining tasks, subject to the
        // global training-slot cap. Size-oblivious disciplines carry no
        // training module and skip the stage. The module is taken out of
        // `self` for the duration (the pickers need `&mut self`; Stage 0
        // itself never touches it mutably).
        let training = self.training.take();
        if let Some(training) = &training {
            let mut training_jobs: Vec<&Job> = view
                .active_jobs()
                .filter(|j| {
                    training.is_training(j.id(), phase)
                        && (phase == Phase::Map || j.map_phase_done())
                        && j.pending_tasks(phase) > 0
                })
                .collect();
            training_jobs.sort_by_key(|j| (j.remaining_tasks(phase), j.id()));
            let mut training_running: usize = view
                .active_jobs()
                .filter(|j| training.is_training(j.id(), phase))
                .map(|j| j.running_tasks(phase))
                .sum();
            for job in training_jobs {
                if free == 0 || training_running >= self.cfg.max_training_slots {
                    break;
                }
                let mut want = training.wanted_training_slots(
                    job.id(),
                    phase,
                    job.running_tasks(phase),
                );
                while want > 0
                    && free > 0
                    && *ctx_budget > 0
                    && training_running < self.cfg.max_training_slots
                {
                    let Some((task, local)) = self.pick_task(view, job, phase, node, picked)
                    else {
                        break;
                    };
                    picked.insert(task);
                    actions.push(Action::Launch { task, node, local });
                    free -= 1;
                    want -= 1;
                    *ctx_budget -= 1;
                    training_running += 1;
                }
            }
        }
        self.training = training;

        // -- Stage 1: fill free slots in priority order -------------------
        for &(id, _) in &cache.order {
            if free == 0 {
                break;
            }
            let job = &view.jobs[&id];
            if phase == Phase::Reduce && !job.map_phase_done() {
                continue;
            }
            // Resume-first: suspended tasks parked on this node (§3.3
            // "Impact on data locality": resume on the same machine).
            while free > 0 {
                let Some(t) = Self::suspended_here(view, id, phase, node, resumed) else {
                    break;
                };
                resumed.insert(t);
                actions.push(Action::Resume { task: t });
                free -= 1;
            }
            // Then pending launches.
            while free > 0 && *ctx_budget > 0 {
                let Some((task, local)) = self.pick_task(view, job, phase, node, picked)
                else {
                    break;
                };
                picked.insert(task);
                actions.push(Action::Launch { task, node, local });
                free -= 1;
                *ctx_budget -= 1;
            }
        }

        // -- Stage 2: preemption (§3.3) -----------------------------------
        if self.cfg.preemption == PreemptionPrimitive::Wait {
            return;
        }
        // Preemption is a last resort: the paper suspends running tasks so
        // that an earlier-finishing job "obtains resources" (§3.3). Count
        // the cluster-wide free slots once: a job whose unmet demand fits
        // in them will be served by those nodes' next heartbeats without
        // taking busy slots.
        let cluster_free = view.cluster.free_slots(phase);
        // Victims: running tasks on this node, worst priority first ("the
        // scheduler selects for suspension the tasks of jobs sorted in
        // decreasing order of their size"). `victims` is reusable scratch.
        victims.clear();
        victims.extend_from_slice(view.cluster.node(node).running(phase));
        victims.sort_by_key(|t| std::cmp::Reverse(cache.rank_of(t.job).unwrap_or(0)));
        let mut victim_iter = victims.iter().copied().peekable();
        let mut suspended_total = view.cluster.suspended_count();

        for &(id, my_finish) in &cache.order {
            let job = &view.jobs[&id];
            if phase == Phase::Reduce && !job.map_phase_done() {
                continue;
            }
            let my_rank = cache.rank_of(id).expect("ordered job has a rank");
            // Pending tasks can be absorbed by free slots anywhere in the
            // cluster; contexts suspended on THIS node can only resume
            // here, so they always justify preemption.
            let suspended_here_cnt = view
                .cluster
                .node(node)
                .suspended_tasks()
                .filter(|t| t.job == id && t.phase == phase)
                .count();
            let pending_unmet = job.pending_tasks(phase) > cluster_free;
            if suspended_here_cnt == 0 && !pending_unmet {
                continue; // free slots elsewhere will serve this job
            }
            loop {
                // Is there a victim strictly lower-priority than us, with a
                // priority key far enough behind ours to justify the
                // preemption (thrash guard)?
                let Some(&victim) = victim_iter.peek() else {
                    return;
                };
                let victim_rank = cache.rank_of(victim.job).unwrap_or(usize::MAX);
                if victim_rank <= my_rank {
                    break; // no victim is worse than this job; next job
                }
                let victim_finish = cache.key_of(victim.job).unwrap_or(f64::INFINITY);
                if victim_finish - my_finish < self.cfg.preempt_threshold_s {
                    break; // near-tie: let the victim run (avoid flapping)
                }
                // Check primitive availability BEFORE picking a placement:
                // `pick_task` consumes locality-index entries, so it must
                // only run when the launch will actually be emitted.
                let resume_cand = Self::suspended_here(view, id, phase, node, resumed);
                if resume_cand.is_none() && !pending_unmet {
                    break; // remaining pending demand fits in free slots
                }
                let preempt_action = match self.cfg.preemption {
                    PreemptionPrimitive::Kill => Some(Action::Kill { task: victim }),
                    PreemptionPrimitive::Suspend => {
                        // A resume-backfill is context-neutral; a
                        // launch-backfill needs context budget.
                        let have_ctx = resume_cand.is_some() || *ctx_budget >= 1;
                        if have_ctx && self.guard.allow_suspend(suspended_total) {
                            Some(Action::Suspend { task: victim })
                        } else {
                            None // out of context memory: WAIT instead
                        }
                    }
                    PreemptionPrimitive::Wait => unreachable!(),
                };
                let Some(preempt_action) = preempt_action else {
                    return; // suspension pressure: stop preempting entirely
                };
                let placement: Option<Action> = match resume_cand {
                    Some(t) => Some(Action::Resume { task: t }),
                    None => self
                        .pick_task(view, job, phase, node, picked)
                        .map(|(task, local)| Action::Launch { task, node, local }),
                };
                let Some(placement) = placement else {
                    break; // nothing to place; next job
                };
                let _ = victim_iter.next();
                if matches!(preempt_action, Action::Suspend { .. }) {
                    suspended_total += 1;
                }
                actions.push(preempt_action);
                match placement {
                    Action::Resume { task } => {
                        resumed.insert(task);
                    }
                    Action::Launch { task, .. } => {
                        picked.insert(task);
                        *ctx_budget = ctx_budget.saturating_sub(1);
                    }
                    _ => {}
                }
                actions.push(placement);
            }
        }
    }
}

impl Scheduler for SizeBasedScheduler {
    fn name(&self) -> &'static str {
        self.cfg.discipline.label()
    }

    fn on_job_arrival(&mut self, view: &SchedView, id: JobId) {
        self.ensure_sized(view);
        let job = &view.jobs[&id];
        self.index.add_job(job, view.hdfs);
        let n_maps = job.spec.n_maps();
        if n_maps > 0 {
            let initial = self.initial_estimate(id, Phase::Map, n_maps);
            self.discipline
                .phase_started(id, Phase::Map, initial, n_maps, view.now);
        } else {
            // Map-less job: the reduce phase is immediately eligible.
            self.start_reduce_phase(view, id);
        }
    }

    fn on_task_completed(&mut self, view: &SchedView, task: TaskRef, observed: f64) {
        let id = task.job;
        let job = &view.jobs[&id];
        let phase = task.phase;
        let tasks_done = match phase {
            Phase::Map => job.maps_done,
            Phase::Reduce => job.reduces_done,
        };
        // Attained service (LAS/SRPT ordering input; FSP ignores it).
        self.discipline.service_observed(id, phase, observed, view.now);
        // Feed the estimator.
        if let Some(training) = &mut self.training {
            if let TrainingUpdate::Estimated { total } =
                training.observe_completion(id, phase, observed, tasks_done)
            {
                self.discipline.size_estimated(id, phase, total, view.now);
            }
        }
        // Real phase completion retires the job from the discipline's
        // reference; virtual progress in between is the discipline's own
        // business (the reference world is deliberately decoupled from
        // real progress).
        if job.remaining_tasks(phase) == 0 {
            self.discipline.phase_completed(id, phase, view.now);
        }
        // Map phase completion opens the reduce phase (§2.2: reducers are
        // scheduled once intermediate data is available).
        if phase == Phase::Map && job.map_phase_done() {
            self.start_reduce_phase(view, id);
        }
    }

    fn on_reduce_progress(&mut self, view: &SchedView, task: TaskRef, delta: f64, progress: f64) {
        if progress <= 0.0 {
            return;
        }
        if let Some(training) = &mut self.training {
            if let TrainingUpdate::Estimated { total } =
                training.observe_progress(task.job, delta, progress)
            {
                self.discipline
                    .size_estimated(task.job, Phase::Reduce, total, view.now);
            }
        }
    }

    fn on_job_finished(&mut self, view: &SchedView, id: JobId) {
        self.discipline.job_removed(id, view.now);
        if let Some(training) = &mut self.training {
            training.remove_job(id);
        }
        self.index.remove_job(id);
        self.delay.remove_job(id);
        self.reduce_started.remove(&id);
    }

    fn on_heartbeat(&mut self, view: &SchedView, node: NodeId, actions: &mut Vec<Action>) {
        self.ensure_sized(view);
        // Job aging / virtual-clock advance (§3.1).
        self.discipline.advance(view.now);
        // Context-memory budget shared by both phases: every launch adds a
        // JVM context on the node; suspensions park one. The budget keeps
        // a heartbeat batch within RAM + swap capacity (§3.3).
        let mut ctx_budget = view.cluster.node(node).context_headroom();
        self.assign_phase(view, node, Phase::Map, actions, &mut ctx_budget);
        self.assign_phase(view, node, Phase::Reduce, actions, &mut ctx_budget);
    }
}
