//! The Training module (§3.1.1, §3.2): on-line job size estimation.
//!
//! When a job arrives its size is unknown. HFSP immediately gives the
//! job scheduler an **initial estimate** — task count × the average
//! duration of recently executed tasks of other jobs, weighted by the
//! confidence parameter ξ ∈ [1, ∞) (ξ = 1: trust history; ξ → ∞: treat
//! the job as infinitely large until trained) — and in parallel schedules
//! a **sample set** of the job's tasks (default 5, §4.1) with priority.
//! As samples complete (map tasks) or report Δ-progress (reduce tasks,
//! σ̃ = Δ/p, §3.2.1), the pluggable estimator fits the task-time
//! distribution and produces the final size; the job scheduler then
//! updates the job's remaining virtual work, discounted by the work the
//! sampled tasks already did.

use super::estimator::SizeEstimator;
use crate::faults::ErrorModel;
use crate::job::{JobId, Phase};
use crate::util::fxmap::FastMap;
use std::collections::VecDeque;

/// Rolling mean of the last `cap` observations (the "recently executed
/// tasks of other jobs" statistic behind initial estimates).
#[derive(Debug)]
pub struct RollingMean {
    window: VecDeque<f64>,
    cap: usize,
    sum: f64,
}

impl RollingMean {
    pub fn new(cap: usize) -> Self {
        Self {
            window: VecDeque::with_capacity(cap),
            cap,
            sum: 0.0,
        }
    }

    pub fn push(&mut self, x: f64) {
        if self.window.len() == self.cap {
            self.sum -= self.window.pop_front().unwrap();
        }
        self.window.push_back(x);
        self.sum += x;
    }

    /// Mean of the window, or `default` when empty.
    pub fn mean_or(&self, default: f64) -> f64 {
        if self.window.is_empty() {
            default
        } else {
            self.sum / self.window.len() as f64
        }
    }

    pub fn len(&self) -> usize {
        self.window.len()
    }
}

/// Per-(job, phase) training state.
#[derive(Debug)]
enum PhaseState {
    /// Collecting the sample set.
    Collecting {
        samples: Vec<f64>,
        /// Serialized work already completed in this phase (discounted
        /// from the final estimate).
        completed_work: f64,
        n_tasks: usize,
    },
    /// Final estimate delivered.
    Done,
}

/// The Training module.
pub struct TrainingModule {
    states: FastMap<(JobId, Phase), PhaseState>,
    recent_map: RollingMean,
    recent_reduce: RollingMean,
    sample_set: usize,
    xi: f64,
    /// Prior task duration when no history exists yet (first jobs).
    prior_task_s: f64,
    estimator: Box<dyn SizeEstimator>,
    /// Artificial estimation-error injection (Fig. 6 uniform model or the
    /// fault subsystem's log-normal model); `None` delivers exact
    /// estimator output.
    error: Option<ErrorModel>,
}

/// Outcome of feeding an observation into the module.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TrainingUpdate {
    /// Still collecting samples.
    Pending,
    /// Training completed: the estimated **total** serialized phase size
    /// (error-injected when configured). The virtual cluster applies its
    /// own virtual-progress discount (§3.1.1).
    Estimated { total: f64 },
    /// Not in training (already estimated, or unknown phase).
    NotTraining,
}

impl TrainingModule {
    pub fn new(
        sample_set: usize,
        xi: f64,
        estimator: Box<dyn SizeEstimator>,
        error: Option<ErrorModel>,
    ) -> Self {
        assert!(sample_set >= 1);
        assert!(xi >= 1.0, "confidence parameter ξ ranges over [1, ∞)");
        Self {
            states: FastMap::default(),
            recent_map: RollingMean::new(100),
            recent_reduce: RollingMean::new(100),
            sample_set,
            xi,
            prior_task_s: 1.0,
            estimator,
            error,
        }
    }

    fn recent(&self, phase: Phase) -> &RollingMean {
        match phase {
            Phase::Map => &self.recent_map,
            Phase::Reduce => &self.recent_reduce,
        }
    }

    fn recent_mut(&mut self, phase: Phase) -> &mut RollingMean {
        match phase {
            Phase::Map => &mut self.recent_map,
            Phase::Reduce => &mut self.recent_reduce,
        }
    }

    /// Begin training a phase; returns the **initial estimate** of the
    /// phase's serialized size for the virtual cluster (task count ×
    /// recent average × ξ). With ξ = ∞ semantics the caller can use
    /// `f64::INFINITY`; we keep ξ finite and large instead.
    pub fn start_phase(&mut self, job: JobId, phase: Phase, n_tasks: usize) -> f64 {
        if n_tasks == 0 {
            self.states.insert((job, phase), PhaseState::Done);
            return 0.0;
        }
        self.states.insert(
            (job, phase),
            PhaseState::Collecting {
                samples: Vec::with_capacity(self.sample_set),
                completed_work: 0.0,
                n_tasks,
            },
        );
        let avg = self.recent(phase).mean_or(self.prior_task_s);
        n_tasks as f64 * avg * self.xi
    }

    /// Whether the phase is still collecting samples (→ the job is granted
    /// training-priority slots).
    pub fn is_training(&self, job: JobId, phase: Phase) -> bool {
        matches!(
            self.states.get(&(job, phase)),
            Some(PhaseState::Collecting { .. })
        )
    }

    /// How many additional outstanding tasks the Training module wants for
    /// this phase, given how many samples it has and how many of the
    /// job's tasks are currently running. (The "minimum share required by
    /// the estimator", §3.2.)
    pub fn wanted_training_slots(&self, job: JobId, phase: Phase, running: usize) -> usize {
        match self.states.get(&(job, phase)) {
            Some(PhaseState::Collecting { samples, n_tasks, .. }) => {
                let outstanding = samples.len() + running;
                self.sample_set.min(*n_tasks).saturating_sub(outstanding)
            }
            _ => 0,
        }
    }

    /// A task of the phase completed with the given measured duration.
    pub fn observe_completion(
        &mut self,
        job: JobId,
        phase: Phase,
        duration: f64,
        tasks_done: usize,
    ) -> TrainingUpdate {
        self.recent_mut(phase).push(duration);
        let Some(state) = self.states.get_mut(&(job, phase)) else {
            return TrainingUpdate::NotTraining;
        };
        match state {
            PhaseState::Done => TrainingUpdate::NotTraining,
            PhaseState::Collecting {
                samples,
                completed_work,
                n_tasks,
            } => {
                samples.push(duration);
                *completed_work += duration;
                let n_tasks = *n_tasks;
                let enough = samples.len() >= self.sample_set.min(n_tasks)
                    || tasks_done >= n_tasks;
                if enough {
                    let samples = samples.clone();
                    let completed = *completed_work;
                    self.finalize(job, phase, &samples, n_tasks, completed)
                } else {
                    TrainingUpdate::Pending
                }
            }
        }
    }

    /// A reduce task reported progress `p` after Δ seconds: the estimated
    /// task duration is σ̃ = Δ/p (§3.2.1). Map phases never call this.
    pub fn observe_progress(
        &mut self,
        job: JobId,
        delta: f64,
        progress: f64,
    ) -> TrainingUpdate {
        debug_assert!(progress > 0.0 && progress <= 1.0);
        let sigma = delta / progress;
        let Some(state) = self.states.get_mut(&(job, Phase::Reduce)) else {
            return TrainingUpdate::NotTraining;
        };
        match state {
            PhaseState::Done => TrainingUpdate::NotTraining,
            PhaseState::Collecting {
                samples, n_tasks, completed_work,
            } => {
                samples.push(sigma);
                let n_tasks = *n_tasks;
                if samples.len() >= self.sample_set.min(n_tasks) {
                    let samples = samples.clone();
                    let completed = *completed_work;
                    self.finalize(job, Phase::Reduce, &samples, n_tasks, completed)
                } else {
                    TrainingUpdate::Pending
                }
            }
        }
    }

    fn finalize(
        &mut self,
        job: JobId,
        phase: Phase,
        samples: &[f64],
        n_tasks: usize,
        completed_work: f64,
    ) -> TrainingUpdate {
        let _ = completed_work;
        let total = self.estimator.estimate_phase(samples, n_tasks);
        let total = match &mut self.error {
            Some(model) => model.perturb(total),
            None => total,
        };
        self.states.insert((job, phase), PhaseState::Done);
        TrainingUpdate::Estimated { total }
    }

    /// Drop all state for a finished job.
    pub fn remove_job(&mut self, job: JobId) {
        self.states.remove(&(job, Phase::Map));
        self.states.remove(&(job, Phase::Reduce));
    }

    pub fn estimator_name(&self) -> &'static str {
        self.estimator.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::core::estimator::NativeEstimator;

    fn module(sample_set: usize, xi: f64) -> TrainingModule {
        TrainingModule::new(sample_set, xi, Box::new(NativeEstimator::new()), None)
    }

    #[test]
    fn rolling_mean_window() {
        let mut r = RollingMean::new(3);
        assert_eq!(r.mean_or(9.0), 9.0);
        r.push(1.0);
        r.push(2.0);
        r.push(3.0);
        assert!((r.mean_or(0.0) - 2.0).abs() < 1e-12);
        r.push(10.0); // evicts 1.0
        assert!((r.mean_or(0.0) - 5.0).abs() < 1e-12);
        assert_eq!(r.len(), 3);
    }

    #[test]
    fn initial_estimate_uses_history_and_xi() {
        let mut m = module(5, 2.0);
        // Seed history via completions of another job's phase.
        let _ = m.start_phase(1, Phase::Map, 10);
        for _ in 0..5 {
            let _ = m.observe_completion(1, Phase::Map, 20.0, 0);
        }
        let est = m.start_phase(2, Phase::Map, 10);
        assert!((est - 10.0 * 20.0 * 2.0).abs() < 1e-9);
    }

    #[test]
    fn initial_estimate_prior_when_no_history() {
        let mut m = module(5, 1.0);
        let est = m.start_phase(1, Phase::Map, 7);
        assert!((est - 7.0).abs() < 1e-12, "prior is 1 s/task");
    }

    #[test]
    fn estimates_after_sample_set() {
        let mut m = module(3, 1.0);
        let _ = m.start_phase(1, Phase::Map, 100);
        assert!(m.is_training(1, Phase::Map));
        assert_eq!(m.observe_completion(1, Phase::Map, 10.0, 1), TrainingUpdate::Pending);
        assert_eq!(m.observe_completion(1, Phase::Map, 10.0, 2), TrainingUpdate::Pending);
        match m.observe_completion(1, Phase::Map, 10.0, 3) {
            TrainingUpdate::Estimated { total } => {
                assert!((total - 1000.0).abs() < 1e-9, "total={total}");
            }
            other => panic!("expected estimate, got {other:?}"),
        }
        assert!(!m.is_training(1, Phase::Map));
        assert_eq!(
            m.observe_completion(1, Phase::Map, 10.0, 4),
            TrainingUpdate::NotTraining
        );
    }

    #[test]
    fn small_jobs_finish_training_early() {
        // Job with 2 tasks and sample set 5: training ends at 2 samples.
        let mut m = module(5, 1.0);
        let _ = m.start_phase(1, Phase::Map, 2);
        assert_eq!(m.observe_completion(1, Phase::Map, 5.0, 1), TrainingUpdate::Pending);
        match m.observe_completion(1, Phase::Map, 5.0, 2) {
            TrainingUpdate::Estimated { total } => assert!((total - 10.0).abs() < 1e-9),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn reduce_progress_reports_estimate() {
        let mut m = module(2, 1.0);
        let _ = m.start_phase(1, Phase::Reduce, 10);
        // Two reduce tasks of true duration 120 s report after Δ=60 s:
        // p = 0.5 → σ̃ = 120.
        assert_eq!(m.observe_progress(1, 60.0, 0.5), TrainingUpdate::Pending);
        match m.observe_progress(1, 60.0, 0.5) {
            TrainingUpdate::Estimated { total } => {
                assert!((total - 1200.0).abs() < 1e-9);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn wanted_training_slots_decreases() {
        let mut m = module(5, 1.0);
        let _ = m.start_phase(1, Phase::Map, 100);
        assert_eq!(m.wanted_training_slots(1, Phase::Map, 0), 5);
        assert_eq!(m.wanted_training_slots(1, Phase::Map, 3), 2);
        let _ = m.observe_completion(1, Phase::Map, 1.0, 1);
        assert_eq!(m.wanted_training_slots(1, Phase::Map, 3), 1);
        assert_eq!(m.wanted_training_slots(1, Phase::Map, 9), 0);
    }

    #[test]
    fn wanted_capped_by_job_width() {
        let mut m = module(5, 1.0);
        let _ = m.start_phase(1, Phase::Map, 2);
        assert_eq!(m.wanted_training_slots(1, Phase::Map, 0), 2);
    }

    #[test]
    fn zero_task_phase_is_immediately_done() {
        let mut m = module(5, 1.0);
        let est = m.start_phase(1, Phase::Reduce, 0);
        assert_eq!(est, 0.0);
        assert!(!m.is_training(1, Phase::Reduce));
    }

    #[test]
    fn error_injection_bounds() {
        for seed in 0..20 {
            let inj = ErrorModel::uniform(0.5, seed);
            let mut m = TrainingModule::new(
                1,
                1.0,
                Box::new(NativeEstimator::new()),
                Some(inj),
            );
            let _ = m.start_phase(1, Phase::Map, 100);
            match m.observe_completion(1, Phase::Map, 10.0, 1) {
                TrainingUpdate::Estimated { total } => {
                    // θ = 1000, α = 0.5: total in [500, 1500].
                    assert!((500.0..=1500.0).contains(&total), "total={total}");
                }
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn log_normal_error_injection_perturbs_estimates() {
        let mut m = TrainingModule::new(
            1,
            1.0,
            Box::new(NativeEstimator::new()),
            Some(ErrorModel::log_normal(0.5, 7)),
        );
        let _ = m.start_phase(1, Phase::Map, 100);
        match m.observe_completion(1, Phase::Map, 10.0, 1) {
            TrainingUpdate::Estimated { total } => {
                assert!(total > 0.0);
                assert!(
                    (total - 1000.0).abs() > 1e-9,
                    "σ=0.5 should virtually never deliver the exact size"
                );
            }
            other => panic!("{other:?}"),
        }
    }
}
