//! XLA-backed estimator and max-min backend.
//!
//! These adapters plug the AOT-compiled JAX/Pallas artifacts
//! ([`crate::runtime`]) into HFSP's pluggable interfaces: the paper's
//! "pluggable estimator" (§3.2.1) becomes an XLA computation compiled
//! once at build time and executed through PJRT on the scheduler hot
//! path. Both fall back to the native implementation when the request
//! exceeds the artifact's static shapes (rare; logged).

use super::estimator::{lsq_quantile_phase_size, SizeEstimator};
use super::virtual_cluster::{maxmin_waterfill, MaxMinBackend};
use crate::runtime::{ArtifactSet, EstimatorExec, MaxMinExec};
use std::path::Path;
use std::rc::Rc;

/// [`SizeEstimator`] implemented by the `estimator.hlo.txt` artifact.
pub struct XlaSizeEstimator {
    exec: EstimatorExec,
}

impl XlaSizeEstimator {
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        Ok(Self {
            exec: EstimatorExec::load(dir)?,
        })
    }

    pub fn from_set(set: Rc<ArtifactSet>) -> Self {
        Self {
            exec: EstimatorExec::new(set),
        }
    }
}

impl SizeEstimator for XlaSizeEstimator {
    fn estimate_phase(&mut self, samples: &[f64], n_tasks: usize) -> f64 {
        match self.exec.estimate_one(samples, n_tasks) {
            Ok(size) => size,
            Err(e) => {
                // Execution failure is unexpected after successful load;
                // keep the system alive with the native path.
                log::error!("XLA estimator failed ({e}); using native fallback");
                lsq_quantile_phase_size(samples, n_tasks)
            }
        }
    }

    fn name(&self) -> &'static str {
        "xla-lsq"
    }
}

/// [`MaxMinBackend`] implemented by the `maxmin.hlo.txt` artifact.
pub struct XlaMaxMin {
    exec: MaxMinExec,
}

impl XlaMaxMin {
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        Ok(Self {
            exec: MaxMinExec::load(dir)?,
        })
    }

    pub fn from_set(set: Rc<ArtifactSet>) -> Self {
        Self {
            exec: MaxMinExec::new(set),
        }
    }
}

impl MaxMinBackend for XlaMaxMin {
    fn allocate(&mut self, demands: &[f64], capacity: f64) -> Vec<f64> {
        if demands.len() > self.exec.max_jobs() {
            log::warn!(
                "maxmin demand vector {} exceeds artifact capacity {}; native fallback",
                demands.len(),
                self.exec.max_jobs()
            );
            return maxmin_waterfill(demands, capacity);
        }
        match self.exec.allocate(demands, capacity) {
            Ok(alloc) => alloc,
            Err(e) => {
                log::error!("XLA maxmin failed ({e}); using native fallback");
                maxmin_waterfill(demands, capacity)
            }
        }
    }
}
