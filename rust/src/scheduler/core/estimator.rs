//! Job-size estimation (§3.2.1 "Runtime estimator").
//!
//! The estimator is a **pluggable module**: given the measured durations
//! of a job's sample-set tasks and the phase's task count, it produces the
//! estimated *serialized phase size* (sum of all task durations). The
//! paper's shipped estimator fits the task-time distribution with simple
//! regression (least squares) on the sample quantiles, reconstructs the
//! per-task duration vector from the fitted CDF, and sums it.
//!
//! Two interchangeable implementations exist:
//! * [`NativeEstimator`] — pure rust (below), the reference;
//! * `XlaEstimator` ([`super::xla_estimator`]) — the same computation
//!   expressed as a JAX/Pallas graph, AOT-compiled to an XLA artifact and
//!   executed through PJRT. Integration tests assert the two agree.

/// Pluggable size estimator.
pub trait SizeEstimator {
    /// Estimate the serialized size of a phase with `n_tasks` tasks, from
    /// the measured durations of its sample set. `samples` is non-empty.
    ///
    /// Returns the estimated **total** phase size (seconds). The caller
    /// (Training module) handles discounting work already done.
    fn estimate_phase(&mut self, samples: &[f64], n_tasks: usize) -> f64;

    fn name(&self) -> &'static str;
}

/// The paper's estimator: least-squares fit of the empirical quantile
/// function, then reconstruction of the full task-duration vector.
///
/// With the sample durations sorted ascending as an empirical quantile
/// function `q(u)` at plotting positions `u_k = (k + 0.5)/s`, fit
/// `q(u) ≈ a + b·u` by least squares, then predict each of the `n` task
/// durations at positions `u_j = (j + 0.5)/n` and sum:
///
/// ```text
/// size ≈ Σ_j (a + b·u_j) = n·a + b·Σ_j u_j = n·(a + b/2)
/// ```
///
/// For skew-free task times (the FB-dataset assumption, §4.1) this
/// reduces to `n × mean(samples)` — the "first order statistics" the
/// paper mentions — while remaining exact for linearly-varying task-time
/// distributions (e.g. uniform).
#[derive(Debug, Default)]
pub struct NativeEstimator;

impl NativeEstimator {
    pub fn new() -> Self {
        Self
    }
}

/// Shared fitting routine (also mirrored by `python/compile/kernels/` and
/// asserted equal by the runtime integration tests).
pub fn lsq_quantile_phase_size(samples: &[f64], n_tasks: usize) -> f64 {
    assert!(!samples.is_empty(), "estimator needs at least one sample");
    let s = samples.len();
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    if s == 1 {
        return sorted[0] * n_tasks as f64;
    }
    // Plotting positions u_k = (k + 0.5)/s.
    let us: Vec<f64> = (0..s).map(|k| (k as f64 + 0.5) / s as f64).collect();
    let (a, b) = crate::util::stats::linear_least_squares(&us, &sorted);
    // Σ_j u_j over j = 0..n of (j+0.5)/n equals n/2, hence n(a + b/2).
    let n = n_tasks as f64;
    let size = n * (a + b * 0.5);
    // Guard: a wildly negative slope on tiny samples could go negative.
    size.max(0.0)
}

impl SizeEstimator for NativeEstimator {
    fn estimate_phase(&mut self, samples: &[f64], n_tasks: usize) -> f64 {
        lsq_quantile_phase_size(samples, n_tasks)
    }

    fn name(&self) -> &'static str {
        "native-lsq"
    }
}

/// Trivial mean-based estimator (first-order statistics only) — useful as
/// an ablation baseline.
#[derive(Debug, Default)]
pub struct MeanEstimator;

impl SizeEstimator for MeanEstimator {
    fn estimate_phase(&mut self, samples: &[f64], n_tasks: usize) -> f64 {
        assert!(!samples.is_empty());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        mean * n_tasks as f64
    }

    fn name(&self) -> &'static str {
        "mean"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_on_constant_task_times() {
        let mut e = NativeEstimator::new();
        let size = e.estimate_phase(&[10.0, 10.0, 10.0, 10.0, 10.0], 100);
        assert!((size - 1000.0).abs() < 1e-9);
    }

    #[test]
    fn exact_on_uniform_task_times() {
        // Samples at the quantiles of U[0, 20]: mean 10 → size n*10.
        let mut e = NativeEstimator::new();
        let samples: Vec<f64> = (0..5).map(|k| (k as f64 + 0.5) / 5.0 * 20.0).collect();
        let size = e.estimate_phase(&samples, 50);
        assert!((size - 500.0).abs() < 1e-9, "got {size}");
    }

    #[test]
    fn single_sample_scales() {
        let mut e = NativeEstimator::new();
        assert!((e.estimate_phase(&[7.0], 3) - 21.0).abs() < 1e-12);
    }

    #[test]
    fn unsorted_samples_accepted() {
        let mut e = NativeEstimator::new();
        let a = e.estimate_phase(&[3.0, 1.0, 2.0], 10);
        let b = e.estimate_phase(&[1.0, 2.0, 3.0], 10);
        assert!((a - b).abs() < 1e-12);
    }

    #[test]
    fn matches_mean_for_symmetric_samples() {
        // LSQ through symmetric quantiles passes through the mean, so the
        // two estimators agree.
        let mut lsq = NativeEstimator::new();
        let mut mean = MeanEstimator;
        let samples = [8.0, 9.0, 10.0, 11.0, 12.0];
        let a = lsq.estimate_phase(&samples, 40);
        let b = mean.estimate_phase(&samples, 40);
        assert!((a - b).abs() < 1e-9);
    }

    #[test]
    fn never_negative() {
        let mut e = NativeEstimator::new();
        // Pathological: steeply decreasing... impossible once sorted, but
        // extreme spread with tiny n must still clamp at 0.
        let size = e.estimate_phase(&[0.001, 100.0], 1);
        assert!(size >= 0.0);
    }

    #[test]
    #[should_panic(expected = "at least one sample")]
    fn empty_samples_panic() {
        let mut e = NativeEstimator::new();
        let _ = e.estimate_phase(&[], 10);
    }
}
