//! Preemption primitives and the suspension-pressure hysteresis guard
//! (§3.3 of the paper).
//!
//! HFSP prefers **eager preemption** (SUSPEND/RESUME via SIGSTOP/SIGCONT
//! on the child JVM): no work is lost, at the price of memory held by the
//! parked context. The alternatives are **WAIT** (let running tasks
//! finish; fine when task runtimes are short) and **KILL** (classic
//! Hadoop preemption; wastes all work done).
//!
//! Because suspended contexts consume RAM/swap, HFSP bounds them with "a
//! set of thresholds (with hysteresis) on the number of tasks that can be
//! suspended. When too many tasks are suspended, HFSP switches to the
//! WAIT-based preemption technique, until conditions are met for
//! reverting to eager preemption." [`SuspensionGuard`] implements that
//! state machine over the cluster-wide suspended-task count.

/// Which primitive the scheduler uses to take slots from running jobs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PreemptionPrimitive {
    /// SIGSTOP / SIGCONT: suspend tasks, resume them later on the same
    /// node (eager preemption; the paper's default).
    Suspend,
    /// Never take a busy slot; wait for tasks to complete.
    Wait,
    /// Kill victim tasks (work is lost; they re-queue as pending).
    Kill,
}

impl PreemptionPrimitive {
    pub fn from_name(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "suspend" | "eager" => Ok(Self::Suspend),
            "wait" => Ok(Self::Wait),
            "kill" => Ok(Self::Kill),
            other => anyhow::bail!("unknown preemption primitive {other:?} (suspend|wait|kill)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Suspend => "suspend",
            Self::Wait => "wait",
            Self::Kill => "kill",
        }
    }
}

/// Hysteresis over the cluster-wide suspended-task count: above `hi`
/// suspensions are disallowed (fall back to WAIT) until the count drains
/// below `lo`.
#[derive(Clone, Debug)]
pub struct SuspensionGuard {
    hi: usize,
    lo: usize,
    in_fallback: bool,
}

impl SuspensionGuard {
    pub fn new(hi: usize, lo: usize) -> Self {
        assert!(lo <= hi, "hysteresis requires lo <= hi");
        Self {
            hi,
            lo,
            in_fallback: false,
        }
    }

    /// May the scheduler suspend another task, given the current
    /// cluster-wide suspended count? Updates the hysteresis state.
    pub fn allow_suspend(&mut self, suspended_now: usize) -> bool {
        if self.in_fallback {
            if suspended_now <= self.lo {
                self.in_fallback = false;
            }
        } else if suspended_now >= self.hi {
            self.in_fallback = true;
        }
        !self.in_fallback
    }

    pub fn in_fallback(&self) -> bool {
        self.in_fallback
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn primitive_parsing() {
        assert_eq!(
            PreemptionPrimitive::from_name("suspend").unwrap(),
            PreemptionPrimitive::Suspend
        );
        assert_eq!(
            PreemptionPrimitive::from_name("EAGER").unwrap(),
            PreemptionPrimitive::Suspend
        );
        assert_eq!(
            PreemptionPrimitive::from_name("wait").unwrap(),
            PreemptionPrimitive::Wait
        );
        assert_eq!(
            PreemptionPrimitive::from_name("kill").unwrap(),
            PreemptionPrimitive::Kill
        );
        assert!(PreemptionPrimitive::from_name("bogus").is_err());
    }

    #[test]
    fn hysteresis_cycle() {
        let mut g = SuspensionGuard::new(10, 4);
        assert!(g.allow_suspend(0));
        assert!(g.allow_suspend(9));
        // Trip at hi.
        assert!(!g.allow_suspend(10));
        assert!(g.in_fallback());
        // Still tripped while draining above lo.
        assert!(!g.allow_suspend(7));
        assert!(!g.allow_suspend(5));
        // Recover at/below lo.
        assert!(g.allow_suspend(4));
        assert!(!g.in_fallback());
        assert!(g.allow_suspend(9));
    }

    #[test]
    #[should_panic(expected = "lo <= hi")]
    fn bad_thresholds_panic() {
        let _ = SuspensionGuard::new(4, 10);
    }
}
