//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! The compile path (`python/compile/aot.py`, build-time only) lowers the
//! L2 JAX graphs to **HLO text** in `artifacts/`; this module loads them
//! through the `xla` crate (`PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → compile → execute) and exposes
//! typed, padded executors to the scheduler hot path. Python is never on
//! the request path.
//!
//! Interchange is HLO *text*, not serialized `HloModuleProto`: jax ≥ 0.5
//! emits protos with 64-bit instruction ids which the image's
//! xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md).

pub mod artifacts;
pub mod executors;

pub use artifacts::{ArtifactManifest, ArtifactSet};
pub use executors::{EstimatorExec, MaxMinExec};

use std::path::Path;

/// Compile an HLO-text artifact on the CPU PJRT client.
pub fn load_hlo_text(
    client: &xla::PjRtClient,
    path: &Path,
) -> anyhow::Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .map_err(|e| anyhow::anyhow!("parsing HLO text {path:?}: {e}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow::anyhow!("compiling {path:?} on PJRT: {e}"))
}

/// Default artifact directory: `$HFSP_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> std::path::PathBuf {
    std::env::var_os("HFSP_ARTIFACTS")
        .map(Into::into)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
