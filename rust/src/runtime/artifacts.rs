//! Artifact manifest and set loading.
//!
//! `python/compile/aot.py` writes, next to the HLO text files, a
//! `manifest.json` recording the static shapes each artifact was lowered
//! with:
//!
//! ```json
//! {"estimator": {"batch": 8, "samples": 8},
//!  "maxmin":    {"jobs": 256, "iters": 64},
//!  "jax": "0.8.2"}
//! ```
//!
//! The rust side pads its inputs to those shapes; the manifest keeps the
//! two layers honest (shape drift fails loudly at load time, not with
//! silent garbage at execute time).

use crate::util::json::{self, Json};
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// Static shapes the artifacts were compiled for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ArtifactManifest {
    /// Estimator batch rows.
    pub est_batch: usize,
    /// Estimator max sample-set size.
    pub est_samples: usize,
    /// Max-min job-vector length.
    pub maxmin_jobs: usize,
    /// Water-level bisection iterations compiled into the kernel.
    pub maxmin_iters: usize,
}

impl ArtifactManifest {
    pub fn parse(text: &str) -> anyhow::Result<Self> {
        let v = json::parse(text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let field = |obj: &str, key: &str| -> anyhow::Result<usize> {
            v.get(obj)
                .and_then(|o| o.get(key))
                .and_then(Json::as_u64)
                .map(|x| x as usize)
                .ok_or_else(|| anyhow::anyhow!("manifest missing {obj}.{key}"))
        };
        Ok(Self {
            est_batch: field("estimator", "batch")?,
            est_samples: field("estimator", "samples")?,
            maxmin_jobs: field("maxmin", "jobs")?,
            maxmin_iters: field("maxmin", "iters")?,
        })
    }

    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {path:?}: {e} (run `make artifacts`)"))?;
        Self::parse(&text)
    }
}

/// A loaded artifact set sharing one PJRT client.
pub struct ArtifactSet {
    pub manifest: ArtifactManifest,
    pub client: Rc<xla::PjRtClient>,
    pub estimator: xla::PjRtLoadedExecutable,
    pub maxmin: xla::PjRtLoadedExecutable,
    pub dir: PathBuf,
}

impl ArtifactSet {
    /// Load and compile both artifacts from `dir`.
    pub fn load(dir: &Path) -> anyhow::Result<ArtifactSet> {
        let manifest = ArtifactManifest::load(dir)?;
        let client = Rc::new(
            xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT CPU client: {e}"))?,
        );
        let estimator = super::load_hlo_text(&client, &dir.join("estimator.hlo.txt"))?;
        let maxmin = super::load_hlo_text(&client, &dir.join("maxmin.hlo.txt"))?;
        Ok(ArtifactSet {
            manifest,
            client,
            estimator,
            maxmin,
            dir: dir.to_path_buf(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let text = r#"{
            "estimator": {"batch": 8, "samples": 8},
            "maxmin": {"jobs": 256, "iters": 64},
            "jax": "0.8.2"
        }"#;
        let m = ArtifactManifest::parse(text).unwrap();
        assert_eq!(m.est_batch, 8);
        assert_eq!(m.est_samples, 8);
        assert_eq!(m.maxmin_jobs, 256);
        assert_eq!(m.maxmin_iters, 64);
    }

    #[test]
    fn manifest_rejects_missing_fields() {
        assert!(ArtifactManifest::parse(r#"{"estimator": {"batch": 8}}"#).is_err());
        assert!(ArtifactManifest::parse("not json").is_err());
    }
}
