//! Typed executors over the AOT artifacts: padding, execution, unpadding.
//!
//! Both artifacts are lowered with `return_tuple=True`, so every result is
//! a 1-tuple that must be unwrapped with `to_tuple1`.

use super::artifacts::ArtifactSet;
use anyhow::Result;
use std::path::Path;
use std::rc::Rc;

fn run_one(
    exe: &xla::PjRtLoadedExecutable,
    inputs: &[xla::Literal],
) -> Result<Vec<f32>> {
    let out = exe
        .execute::<xla::Literal>(inputs)
        .map_err(|e| anyhow::anyhow!("PJRT execute: {e}"))?;
    let lit = out[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("PJRT to_literal: {e}"))?;
    let inner = lit
        .to_tuple1()
        .map_err(|e| anyhow::anyhow!("unwrapping 1-tuple result: {e}"))?;
    inner
        .to_vec::<f32>()
        .map_err(|e| anyhow::anyhow!("reading f32 result: {e}"))
}

/// Batched size-estimator executor.
///
/// Artifact signature (see `python/compile/model.py`):
/// `(samples f32[B,S], mask f32[B,S], n_tasks f32[B]) -> (sizes f32[B])`.
pub struct EstimatorExec {
    set: Rc<ArtifactSet>,
}

impl EstimatorExec {
    pub fn new(set: Rc<ArtifactSet>) -> Self {
        Self { set }
    }

    pub fn load(dir: &Path) -> Result<Self> {
        Ok(Self::new(Rc::new(ArtifactSet::load(dir)?)))
    }

    pub fn batch(&self) -> usize {
        self.set.manifest.est_batch
    }

    pub fn max_samples(&self) -> usize {
        self.set.manifest.est_samples
    }

    /// Estimate phase sizes for up to `batch()` jobs at once. Each entry
    /// is `(samples, n_tasks)`; samples beyond `max_samples()` are
    /// truncated (the paper's sample set is 5 ≤ S).
    pub fn estimate_batch(&self, jobs: &[(&[f64], usize)]) -> Result<Vec<f64>> {
        let b = self.batch();
        let s = self.max_samples();
        anyhow::ensure!(
            jobs.len() <= b,
            "estimator batch {} exceeds artifact batch {b}",
            jobs.len()
        );
        let mut samples = vec![0f32; b * s];
        let mut mask = vec![0f32; b * s];
        let mut n_tasks = vec![0f32; b];
        for (row, (xs, n)) in jobs.iter().enumerate() {
            let take = xs.len().min(s);
            if xs.len() > s {
                log::debug!("estimator: truncating {} samples to artifact S={s}", xs.len());
            }
            for (k, &x) in xs.iter().take(take).enumerate() {
                samples[row * s + k] = x as f32;
                mask[row * s + k] = 1.0;
            }
            n_tasks[row] = *n as f32;
        }
        let lit_samples = xla::Literal::vec1(&samples)
            .reshape(&[b as i64, s as i64])
            .map_err(|e| anyhow::anyhow!("reshape samples: {e}"))?;
        let lit_mask = xla::Literal::vec1(&mask)
            .reshape(&[b as i64, s as i64])
            .map_err(|e| anyhow::anyhow!("reshape mask: {e}"))?;
        let lit_n = xla::Literal::vec1(&n_tasks);
        let out = run_one(&self.set.estimator, &[lit_samples, lit_mask, lit_n])?;
        anyhow::ensure!(out.len() == b, "estimator returned {} values", out.len());
        Ok(out[..jobs.len()].iter().map(|&x| x as f64).collect())
    }

    /// Single-job convenience wrapper.
    pub fn estimate_one(&self, samples: &[f64], n_tasks: usize) -> Result<f64> {
        Ok(self.estimate_batch(&[(samples, n_tasks)])?[0])
    }
}

/// Max-min (water-filling) allocation executor.
///
/// Artifact signature: `(demands f32[N], capacity f32[]) -> (alloc f32[N])`.
pub struct MaxMinExec {
    set: Rc<ArtifactSet>,
}

impl MaxMinExec {
    pub fn new(set: Rc<ArtifactSet>) -> Self {
        Self { set }
    }

    pub fn load(dir: &Path) -> Result<Self> {
        Ok(Self::new(Rc::new(ArtifactSet::load(dir)?)))
    }

    pub fn max_jobs(&self) -> usize {
        self.set.manifest.maxmin_jobs
    }

    /// Max-min fair allocation of `capacity` over `demands`
    /// (`demands.len() ≤ max_jobs()`).
    pub fn allocate(&self, demands: &[f64], capacity: f64) -> Result<Vec<f64>> {
        let n = self.max_jobs();
        anyhow::ensure!(
            demands.len() <= n,
            "maxmin demand vector {} exceeds artifact N={n}",
            demands.len()
        );
        let mut d = vec![0f32; n];
        for (i, &x) in demands.iter().enumerate() {
            d[i] = x as f32;
        }
        let lit_d = xla::Literal::vec1(&d);
        let lit_cap = xla::Literal::scalar(capacity as f32);
        let out = run_one(&self.set.maxmin, &[lit_d, lit_cap])?;
        anyhow::ensure!(out.len() == n, "maxmin returned {} values", out.len());
        Ok(out[..demands.len()].iter().map(|&x| x as f64).collect())
    }
}
