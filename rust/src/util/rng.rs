//! Deterministic pseudo-random number generation and distribution sampling.
//!
//! The offline build environment does not ship the `rand` crate, so this
//! module provides the small subset the simulator needs: a fast, seedable,
//! high-quality generator ([`Pcg64`], the PCG-XSL-RR 128/64 variant) plus
//! the samplers used by the workload generator (exponential, uniform,
//! log-normal, Zipf, Pareto, categorical choice).
//!
//! Determinism is a hard requirement: every experiment in the paper
//! reproduction is seeded, and two runs with the same seed must produce
//! bit-identical event traces (this is asserted by integration tests).

/// Minimal trait mirroring `rand::RngCore` for the operations we need.
pub trait Rng {
    /// Next 64 uniformly distributed bits.
    fn next_u64(&mut self) -> u64;

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    #[inline]
    fn next_f64(&mut self) -> f64 {
        // Take the top 53 bits: mantissa precision of an f64.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` using Lemire's multiply-shift
    /// rejection method (unbiased).
    fn gen_range_u64(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "gen_range_u64: bound must be positive");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let low = m as u64;
            if low >= bound || low >= (bound.wrapping_neg() % bound) {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform `usize` in `[0, bound)`.
    #[inline]
    fn gen_index(&mut self, bound: usize) -> usize {
        self.gen_range_u64(bound as u64) as usize
    }

    /// Uniform `f64` in `[lo, hi)`.
    #[inline]
    fn gen_range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        debug_assert!(hi >= lo);
        lo + (hi - lo) * self.next_f64()
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    fn gen_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }
}

/// Seeding constructor, mirroring `rand::SeedableRng::seed_from_u64`.
pub trait SeedableRng: Sized {
    fn seed_from_u64(seed: u64) -> Self;
}

/// SplitMix64: used to expand a 64-bit seed into generator state.
///
/// Reference: Steele, Lea, Flood, "Fast splittable pseudorandom number
/// generators" (OOPSLA 2014). Passes BigCrush when used directly; here it
/// only seeds PCG state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }
}

impl Rng for SplitMix64 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

impl SeedableRng for SplitMix64 {
    fn seed_from_u64(seed: u64) -> Self {
        Self::new(seed)
    }
}

/// PCG-XSL-RR 128/64 ("pcg64"): 128-bit LCG state, 64-bit xor-shift-low +
/// random-rotate output. Period 2^128, passes PractRand/BigCrush.
///
/// Reference: O'Neill, "PCG: A Family of Simple Fast Space-Efficient
/// Statistically Good Algorithms for Random Number Generation" (2014).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Pcg64 {
    /// Construct from explicit state/stream. The stream selector is forced
    /// odd, as PCG requires.
    pub fn new(state: u128, stream: u128) -> Self {
        let mut rng = Self {
            state: 0,
            inc: (stream << 1) | 1,
        };
        // Standard PCG seeding dance.
        let _ = rng.step();
        rng.state = rng.state.wrapping_add(state);
        let _ = rng.step();
        rng
    }

    #[inline]
    fn step(&mut self) -> u128 {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
        self.state
    }

    /// Derive an independent child generator; used to give each simulation
    /// component (workload gen, HDFS placement, task-time sampling, ...) its
    /// own stream so adding draws in one component does not perturb others.
    pub fn split(&mut self) -> Pcg64 {
        let s = ((self.next_u64() as u128) << 64) | self.next_u64() as u128;
        let inc = ((self.next_u64() as u128) << 64) | self.next_u64() as u128;
        Pcg64::new(s, inc)
    }
}

impl Rng for Pcg64 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        let state = self.step();
        // XSL-RR output function.
        let xored = ((state >> 64) as u64) ^ (state as u64);
        let rot = (state >> 122) as u32;
        xored.rotate_right(rot)
    }
}

impl SeedableRng for Pcg64 {
    fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let state = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        let stream = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        Pcg64::new(state, stream)
    }
}

// ---------------------------------------------------------------------------
// Named substreams
// ---------------------------------------------------------------------------

/// Named RNG substream identifiers, one per simulator subsystem.
///
/// The discriminant **is the derivation order** and therefore part of the
/// reproducibility format: stream `k` is the `k`-th [`Pcg64::split`] child
/// of the master generator. `Placement` must stay first — it matches the
/// legacy derivation (`Pcg64::seed_from_u64(seed).split()`) used since the
/// first sweep release, keeping old experiment outputs byte-identical.
/// New subsystems append at the end; never reorder.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StreamId {
    /// HDFS block placement (the first split — legacy-compatible).
    Placement = 0,
    /// Fault & perturbation subsystem (node churn, straggler sampling).
    Faults = 1,
    /// Reserved for scheduler-internal randomness.
    Scheduler = 2,
    /// Open arrival-process generation (workload sources pulling from
    /// [`crate::workload::OpenArrivals`]). Appended for the session
    /// API; closed sources never draw from it, so batch replays keep
    /// their historical byte-identical outcomes.
    Arrivals = 3,
    /// Tenant-population synthesis: Zipf user-identity draws and job-shape
    /// sampling inside [`crate::workload::population::TenantPopulation`].
    /// Kept separate from `Arrivals` (which drives inter-arrival gaps) so
    /// the *who submits what* sequence is byte-identical regardless of
    /// faults, placement, or how the arrival clock is consumed.
    Population = 4,
}

/// Number of named substreams derived by [`RngStreams::new`].
pub const STREAM_COUNT: usize = 5;

/// Per-subsystem RNG substreams, all derived **eagerly and in a fixed
/// order** from one master seed.
///
/// Eager derivation is the point: whether a subsystem actually *draws*
/// from its stream (e.g. faults enabled or disabled) can never shift the
/// draws any other subsystem sees. This is what preserves byte-identical
/// workload/placement sequences when perturbations are toggled on.
#[derive(Clone, Debug)]
pub struct RngStreams {
    streams: Vec<Pcg64>,
}

impl RngStreams {
    pub fn new(seed: u64) -> Self {
        let mut master = Pcg64::seed_from_u64(seed);
        let streams = (0..STREAM_COUNT).map(|_| master.split()).collect();
        Self { streams }
    }

    /// An independent generator for the named substream. Each call returns
    /// a fresh clone positioned at the stream's start.
    pub fn stream(&self, id: StreamId) -> Pcg64 {
        self.streams[id as usize].clone()
    }

    /// The workload-synthesis stream: the root generator seeded directly
    /// from the master seed. This is the derivation `WorkloadSpec::realize`
    /// has always used; it is kept as the root (rather than a split child)
    /// for bit-compatibility with previously published traces. The split
    /// children consume master *outputs* as seed material, so their output
    /// streams are independent of the root's.
    pub fn workload(seed: u64) -> Pcg64 {
        Pcg64::seed_from_u64(seed)
    }
}

// ---------------------------------------------------------------------------
// Distribution samplers
// ---------------------------------------------------------------------------

/// Exponential variate with the given mean (= 1/rate), by inversion.
pub fn exponential<R: Rng>(rng: &mut R, mean: f64) -> f64 {
    debug_assert!(mean > 0.0);
    // 1 - U in (0, 1] avoids ln(0).
    -mean * (1.0 - rng.next_f64()).ln()
}

/// Standard normal via Box–Muller (polar-free variant; uses two uniforms).
pub fn std_normal<R: Rng>(rng: &mut R) -> f64 {
    let u1 = 1.0 - rng.next_f64(); // (0, 1]
    let u2 = rng.next_f64();
    (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
}

/// Normal with mean/stddev.
pub fn normal<R: Rng>(rng: &mut R, mean: f64, std: f64) -> f64 {
    mean + std * std_normal(rng)
}

/// Log-normal parameterised by the mean/std of the *underlying* normal.
pub fn log_normal<R: Rng>(rng: &mut R, mu: f64, sigma: f64) -> f64 {
    normal(rng, mu, sigma).exp()
}

/// Pareto (Lomax-free classic form): `x_m * U^(-1/alpha)`.
pub fn pareto<R: Rng>(rng: &mut R, x_min: f64, alpha: f64) -> f64 {
    debug_assert!(x_min > 0.0 && alpha > 0.0);
    x_min * (1.0 - rng.next_f64()).powf(-1.0 / alpha)
}

/// Zipf-distributed rank in `[1, n]` with exponent `s`, by inverse-CDF over
/// the precomputed harmonic weights. O(log n) per draw.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0, "Zipf: n must be positive");
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for v in &mut cdf {
            *v /= total;
        }
        Self { cdf }
    }

    /// Draw a rank in `[1, n]`.
    pub fn sample<R: Rng>(&self, rng: &mut R) -> usize {
        let u = rng.next_f64();
        match self
            .cdf
            .binary_search_by(|probe| probe.total_cmp(&u))
        {
            Ok(i) => i + 1,
            Err(i) => i + 1,
        }
    }
}

/// Table-free Zipf-distributed rank in `[1, n]` with exponent `s > 0`,
/// by Hörmann–Derflinger rejection inversion. O(1) memory and O(1)
/// expected draws regardless of `n` — this is what lets the tenant
/// population model 10⁶ users without materializing a CDF table
/// ([`Zipf`] stays the small-`n` reference; the two agree in
/// distribution, not draw-for-draw).
#[derive(Clone, Copy, Debug)]
pub struct ZipfStreaming {
    n: u64,
    s: f64,
    h_x1: f64,
    h_n: f64,
    threshold: f64,
}

impl ZipfStreaming {
    pub fn new(n: u64, s: f64) -> Self {
        assert!(n > 0, "ZipfStreaming: n must be positive");
        assert!(s > 0.0, "ZipfStreaming: exponent must be positive");
        let h = |x: f64| h_integral(x, s);
        Self {
            n,
            s,
            h_x1: h(1.5) - 1.0,
            h_n: h(n as f64 + 0.5),
            threshold: 2.0 - h_integral_inverse(h(2.5) - (-s * 2.0f64.ln()).exp(), s),
        }
    }

    /// Draw a rank in `[1, n]`.
    pub fn sample<R: Rng>(&self, rng: &mut R) -> u64 {
        loop {
            let u = self.h_n + rng.next_f64() * (self.h_x1 - self.h_n);
            let x = h_integral_inverse(u, self.s);
            let k = (x + 0.5).floor().clamp(1.0, self.n as f64);
            if k - x <= self.threshold
                || u >= h_integral(k + 0.5, self.s) - (-self.s * k.ln()).exp()
            {
                return k as u64;
            }
        }
    }
}

/// ∫ (1+t)^(-s) dt rewritten as `helper((1-s)·ln x)·ln x`, stable at
/// s → 1 (where it degenerates to ln x).
fn h_integral(x: f64, s: f64) -> f64 {
    let log_x = x.ln();
    let q = (1.0 - s) * log_x;
    // (e^q − 1)/q, with the q → 0 limit handled by expm1's precision
    // plus an explicit series guard.
    let helper = if q.abs() > 1e-8 { q.exp_m1() / q } else { 1.0 + q / 2.0 };
    helper * log_x
}

/// Inverse of [`h_integral`].
fn h_integral_inverse(x: f64, s: f64) -> f64 {
    let mut t = x * (1.0 - s);
    if t < -1.0 {
        // Numerical round-off below the function's range; clamp to the
        // boundary (matches the reference implementation).
        t = -1.0;
    }
    // ln1p(t)/t with the t → 0 limit.
    let helper = if t.abs() > 1e-8 { t.ln_1p() / t } else { 1.0 - t / 2.0 };
    (helper * x).exp()
}

/// Weighted categorical choice: returns an index sampled proportionally to
/// `weights`. Panics on empty or all-zero weights.
pub fn weighted_choice<R: Rng>(rng: &mut R, weights: &[f64]) -> usize {
    let total: f64 = weights.iter().sum();
    assert!(total > 0.0, "weighted_choice: weights must sum to > 0");
    let mut u = rng.next_f64() * total;
    for (i, w) in weights.iter().enumerate() {
        u -= w;
        if u <= 0.0 {
            return i;
        }
    }
    weights.len() - 1
}

/// Fisher–Yates shuffle.
pub fn shuffle<R: Rng, T>(rng: &mut R, xs: &mut [T]) {
    for i in (1..xs.len()).rev() {
        let j = rng.gen_index(i + 1);
        xs.swap(i, j);
    }
}

/// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
pub fn sample_indices<R: Rng>(rng: &mut R, n: usize, k: usize) -> Vec<usize> {
    assert!(k <= n, "sample_indices: k must be <= n");
    let mut idx: Vec<usize> = (0..n).collect();
    for i in 0..k {
        let j = i + rng.gen_index(n - i);
        idx.swap(i, j);
    }
    idx.truncate(k);
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zipf_streaming_matches_the_table_zipf_in_distribution() {
        // Rank frequencies from the rejection sampler must track the
        // table-based reference: p(k) ∝ k^(-s).
        let n = 50;
        let s = 0.8;
        let z = ZipfStreaming::new(n as u64, s);
        let mut rng = Pcg64::seed_from_u64(11);
        let mut counts = vec![0u64; n];
        let draws = 200_000;
        for _ in 0..draws {
            let k = z.sample(&mut rng);
            assert!((1..=n as u64).contains(&k));
            counts[(k - 1) as usize] += 1;
        }
        let hn: f64 = (1..=n).map(|k| (k as f64).powf(-s)).sum();
        for k in [1usize, 2, 5, 20] {
            let expect = (k as f64).powf(-s) / hn;
            let got = counts[k - 1] as f64 / draws as f64;
            assert!(
                (got - expect).abs() < 0.01,
                "rank {k}: got {got}, expect {expect}"
            );
        }
    }

    #[test]
    fn zipf_streaming_stays_in_range_for_huge_populations() {
        // The whole point: 10⁶ ranks with no table. Also cover s = 1,
        // the analytic singularity of the transform.
        for s in [0.5, 1.0, 1.5] {
            let z = ZipfStreaming::new(1_000_000, s);
            let mut rng = Pcg64::seed_from_u64(29);
            let draws = 100_000u64;
            let mut top = 0u64;
            for _ in 0..draws {
                let k = z.sample(&mut rng);
                assert!((1..=1_000_000).contains(&k), "s={s}");
                if k == 1 {
                    top += 1;
                }
            }
            // Rank-1 frequency must track 1/H_n(s) — the skew survives
            // the transform (for s = 0.5 that is only ≈ 5·10⁻⁴, so the
            // check is a wide Poisson band, not a tight tolerance).
            let hn: f64 = (1..=1_000_000u64).map(|k| (k as f64).powf(-s)).sum();
            let expect = draws as f64 / hn;
            assert!(
                (top as f64) > 0.3 * expect && (top as f64) < 3.0 * expect,
                "s={s}: rank-1 count {top}, expected ≈ {expect:.1}"
            );
        }
    }

    #[test]
    fn pcg64_is_deterministic() {
        let mut a = Pcg64::seed_from_u64(7);
        let mut b = Pcg64::seed_from_u64(7);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn pcg64_differs_across_seeds() {
        let mut a = Pcg64::seed_from_u64(1);
        let mut b = Pcg64::seed_from_u64(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn next_f64_in_unit_interval() {
        let mut r = Pcg64::seed_from_u64(3);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_u64_unbiased_small_bound() {
        let mut r = Pcg64::seed_from_u64(11);
        let mut counts = [0usize; 5];
        let n = 100_000;
        for _ in 0..n {
            counts[r.gen_range_u64(5) as usize] += 1;
        }
        for &c in &counts {
            let frac = c as f64 / n as f64;
            assert!((frac - 0.2).abs() < 0.01, "bucket fraction {frac}");
        }
    }

    #[test]
    fn exponential_mean_converges() {
        let mut r = Pcg64::seed_from_u64(5);
        let n = 200_000;
        let mean = 13.0;
        let sum: f64 = (0..n).map(|_| exponential(&mut r, mean)).sum();
        let emp = sum / n as f64;
        assert!((emp - mean).abs() / mean < 0.02, "empirical mean {emp}");
    }

    #[test]
    fn normal_moments_converge() {
        let mut r = Pcg64::seed_from_u64(6);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| normal(&mut r, 3.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.05, "mean {mean}");
        assert!((var - 4.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn pareto_respects_min() {
        let mut r = Pcg64::seed_from_u64(8);
        for _ in 0..10_000 {
            assert!(pareto(&mut r, 2.0, 1.5) >= 2.0);
        }
    }

    #[test]
    fn zipf_rank_one_most_frequent() {
        let mut r = Pcg64::seed_from_u64(9);
        let z = Zipf::new(50, 1.1);
        let mut counts = vec![0usize; 51];
        for _ in 0..50_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[1] > counts[2]);
        assert!(counts[2] > counts[10]);
        assert_eq!(counts[0], 0);
    }

    #[test]
    fn weighted_choice_proportions() {
        let mut r = Pcg64::seed_from_u64(10);
        let w = [1.0, 3.0, 6.0];
        let mut counts = [0usize; 3];
        let n = 100_000;
        for _ in 0..n {
            counts[weighted_choice(&mut r, &w)] += 1;
        }
        assert!((counts[0] as f64 / n as f64 - 0.1).abs() < 0.01);
        assert!((counts[2] as f64 / n as f64 - 0.6).abs() < 0.01);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seed_from_u64(12);
        let mut xs: Vec<u32> = (0..100).collect();
        shuffle(&mut r, &mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg64::seed_from_u64(13);
        for _ in 0..100 {
            let s = sample_indices(&mut r, 20, 7);
            assert_eq!(s.len(), 7);
            let mut d = s.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), 7, "indices must be distinct");
            assert!(s.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn placement_stream_matches_legacy_derivation() {
        // Byte-compat pin: the Placement substream must equal the
        // historical `Pcg64::seed_from_u64(seed).split()` sequence, or
        // every published sweep aggregate changes.
        for seed in [0u64, 7, 42, 0xDEAD_BEEF] {
            let mut legacy = Pcg64::seed_from_u64(seed).split();
            let mut named = RngStreams::new(seed).stream(StreamId::Placement);
            for _ in 0..64 {
                assert_eq!(legacy.next_u64(), named.next_u64());
            }
        }
    }

    #[test]
    fn toggling_an_unused_stream_leaves_other_draws_unchanged() {
        // Run A: only placement + workload draw.
        let streams_a = RngStreams::new(99);
        let mut placement_a = streams_a.stream(StreamId::Placement);
        let mut workload_a = RngStreams::workload(99);
        let pa: Vec<u64> = (0..32).map(|_| placement_a.next_u64()).collect();
        let wa: Vec<u64> = (0..32).map(|_| workload_a.next_u64()).collect();

        // Run B: the faults stream is also consumed, heavily.
        let streams_b = RngStreams::new(99);
        let mut faults_b = streams_b.stream(StreamId::Faults);
        for _ in 0..10_000 {
            let _ = faults_b.next_u64();
        }
        let mut placement_b = streams_b.stream(StreamId::Placement);
        let mut workload_b = RngStreams::workload(99);
        let pb: Vec<u64> = (0..32).map(|_| placement_b.next_u64()).collect();
        let wb: Vec<u64> = (0..32).map(|_| workload_b.next_u64()).collect();

        assert_eq!(pa, pb, "placement draws must not depend on fault draws");
        assert_eq!(wa, wb, "workload draws must not depend on fault draws");
    }

    #[test]
    fn named_streams_are_mutually_distinct() {
        let streams = RngStreams::new(5);
        let mut a = streams.stream(StreamId::Placement);
        let mut b = streams.stream(StreamId::Faults);
        let mut c = streams.stream(StreamId::Scheduler);
        let mut d = streams.stream(StreamId::Arrivals);
        let mut p = streams.stream(StreamId::Population);
        let mut w = RngStreams::workload(5);
        let xs: Vec<u64> = (0..64).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..64).map(|_| b.next_u64()).collect();
        let zs: Vec<u64> = (0..64).map(|_| c.next_u64()).collect();
        let ds: Vec<u64> = (0..64).map(|_| d.next_u64()).collect();
        let ps: Vec<u64> = (0..64).map(|_| p.next_u64()).collect();
        let ws: Vec<u64> = (0..64).map(|_| w.next_u64()).collect();
        assert_ne!(xs, ys);
        assert_ne!(ys, zs);
        assert_ne!(xs, zs);
        assert_ne!(zs, ds);
        assert_ne!(xs, ds);
        assert_ne!(ds, ps);
        assert_ne!(xs, ps);
        assert_ne!(xs, ws);
    }

    #[test]
    fn appending_the_population_stream_kept_earlier_streams_stable() {
        // Regression for the STREAM_COUNT=4 -> 5 bump: the first four
        // named substreams are split *before* Population, so its addition
        // must not shift a single draw in any of them. Pin against a
        // hand-rolled four-split derivation.
        for seed in [0u64, 99, 0xFEED] {
            let mut master = Pcg64::seed_from_u64(seed);
            let legacy: Vec<Pcg64> = (0..4).map(|_| master.split()).collect();
            let streams = RngStreams::new(seed);
            for (i, id) in [
                StreamId::Placement,
                StreamId::Faults,
                StreamId::Scheduler,
                StreamId::Arrivals,
            ]
            .into_iter()
            .enumerate()
            {
                let mut old = legacy[i].clone();
                let mut new = streams.stream(id);
                for _ in 0..32 {
                    assert_eq!(old.next_u64(), new.next_u64(), "stream {id:?} shifted");
                }
            }
        }
    }

    #[test]
    fn split_streams_are_independent_of_parent_consumption() {
        // Splitting then drawing from the parent must not change the child.
        let mut p1 = Pcg64::seed_from_u64(99);
        let mut c1 = p1.split();
        let a: Vec<u64> = (0..16).map(|_| c1.next_u64()).collect();

        let mut p2 = Pcg64::seed_from_u64(99);
        let mut c2 = p2.split();
        for _ in 0..1000 {
            let _ = p2.next_u64();
        }
        let b: Vec<u64> = (0..16).map(|_| c2.next_u64()).collect();
        assert_eq!(a, b);
    }
}
