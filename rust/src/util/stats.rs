//! Descriptive statistics, empirical CDFs and least-squares fitting.
//!
//! These are the numeric primitives behind the metrics pipeline (sojourn
//! statistics, per-class ECDFs — Fig. 3 of the paper) and the native job
//! size estimator (first-order statistics + least-squares quantile fit,
//! §3.2.1 of the paper).

/// Running mean/variance accumulator (Welford's algorithm) — numerically
/// stable one-pass moments.
#[derive(Clone, Debug, Default)]
pub struct Moments {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Moments {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Sample variance (n-1 denominator).
    pub fn sample_variance(&self) -> f64 {
        if self.n < 2 {
            f64::NAN
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Merge another accumulator (parallel Welford / Chan et al.).
    pub fn merge(&mut self, other: &Moments) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let delta = other.mean - self.mean;
        let mean = self.mean + delta * other.n as f64 / n as f64;
        let m2 = self.m2
            + other.m2
            + delta * delta * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Mean of a slice; NaN on empty.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation of a slice.
pub fn std(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile with linear interpolation between order statistics
/// (the "linear" / type-7 method used by numpy). `q` in `[0, 100]`.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of empty slice");
    debug_assert!(sorted.windows(2).all(|w| w[0] <= w[1]), "input must be sorted");
    let q = q.clamp(0.0, 100.0);
    let rank = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Median convenience wrapper.
pub fn median(sorted: &[f64]) -> f64 {
    percentile(sorted, 50.0)
}

/// Empirical cumulative distribution function over a sample.
///
/// Stored as the sorted sample; `eval(x)` returns `P(X <= x)` and
/// `quantile(p)` the inverse. This is the CDF representation the paper's
/// estimator constructs from the sample set of task runtimes (§3.2).
#[derive(Clone, Debug)]
pub struct Ecdf {
    sorted: Vec<f64>,
}

impl Ecdf {
    pub fn new(mut xs: Vec<f64>) -> Self {
        xs.sort_by(|a, b| a.total_cmp(b));
        Self { sorted: xs }
    }

    pub fn is_empty(&self) -> bool {
        self.sorted.is_empty()
    }

    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    /// P(X <= x).
    pub fn eval(&self, x: f64) -> f64 {
        if self.sorted.is_empty() {
            return f64::NAN;
        }
        // Number of samples <= x, via binary search for the upper bound.
        let mut lo = 0usize;
        let mut hi = self.sorted.len();
        while lo < hi {
            let mid = (lo + hi) / 2;
            if self.sorted[mid] <= x {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        lo as f64 / self.sorted.len() as f64
    }

    /// Inverse CDF with linear interpolation; `p` in `[0, 1]`.
    pub fn quantile(&self, p: f64) -> f64 {
        assert!(!self.sorted.is_empty());
        percentile(&self.sorted, p.clamp(0.0, 1.0) * 100.0)
    }

    /// The sorted sample (the paper's "vector of task durations").
    pub fn samples(&self) -> &[f64] {
        &self.sorted
    }

    /// Evaluate the ECDF on a grid of `n` points spanning `[min, max]`,
    /// returning `(x, P(X<=x))` pairs — the series plotted in Fig. 3.
    pub fn series(&self, n: usize) -> Vec<(f64, f64)> {
        assert!(n >= 2 && !self.sorted.is_empty());
        let lo = self.sorted[0];
        let hi = *self.sorted.last().unwrap();
        (0..n)
            .map(|i| {
                let x = lo + (hi - lo) * i as f64 / (n - 1) as f64;
                (x, self.eval(x))
            })
            .collect()
    }
}

/// Ordinary least squares fit `y = a + b x`. Returns `(a, b)`.
///
/// Used by the native estimator to fit the task-time quantile function from
/// the sample set (the paper uses "simple regression analysis ... such that
/// least squares error is minimized", §3.2.1).
pub fn linear_least_squares(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(!xs.is_empty(), "least squares on empty data");
    let n = xs.len() as f64;
    let sx: f64 = xs.iter().sum();
    let sy: f64 = ys.iter().sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| x * y).sum();
    let denom = n * sxx - sx * sx;
    if denom.abs() < 1e-12 {
        // Degenerate (all x equal): flat line through the mean.
        return (sy / n, 0.0);
    }
    let b = (n * sxy - sx * sy) / denom;
    let a = (sy - b * sx) / n;
    (a, b)
}

/// Coefficient of determination R² for a linear fit.
pub fn r_squared(xs: &[f64], ys: &[f64], a: f64, b: f64) -> f64 {
    let my = mean(ys);
    let ss_tot: f64 = ys.iter().map(|y| (y - my).powi(2)).sum();
    let ss_res: f64 = xs
        .iter()
        .zip(ys)
        .map(|(x, y)| (y - (a + b * x)).powi(2))
        .sum();
    if ss_tot == 0.0 {
        1.0
    } else {
        1.0 - ss_res / ss_tot
    }
}

/// Fixed-width histogram over `[lo, hi)` with `bins` buckets; values outside
/// the range are clamped into the edge buckets.
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    counts: Vec<u64>,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Self {
            lo,
            hi,
            counts: vec![0; bins],
        }
    }

    pub fn push(&mut self, x: f64) {
        let bins = self.counts.len();
        let t = (x - self.lo) / (self.hi - self.lo);
        let idx = ((t * bins as f64).floor() as i64).clamp(0, bins as i64 - 1) as usize;
        self.counts[idx] += 1;
    }

    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Bucket midpoints.
    pub fn midpoints(&self) -> Vec<f64> {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        (0..self.counts.len())
            .map(|i| self.lo + w * (i as f64 + 0.5))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moments_match_direct_computation() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut m = Moments::new();
        for &x in &xs {
            m.push(x);
        }
        assert_eq!(m.count(), 8);
        assert!((m.mean() - 5.0).abs() < 1e-12);
        assert!((m.variance() - 4.0).abs() < 1e-12);
        assert!((m.std() - 2.0).abs() < 1e-12);
        assert_eq!(m.min(), 2.0);
        assert_eq!(m.max(), 9.0);
    }

    #[test]
    fn moments_merge_equals_single_pass() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut all = Moments::new();
        for &x in &xs {
            all.push(x);
        }
        let mut a = Moments::new();
        let mut b = Moments::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.variance() - all.variance()).abs() < 1e-9);
        assert_eq!(a.count(), all.count());
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn ecdf_eval_and_quantile_roundtrip() {
        let e = Ecdf::new(vec![3.0, 1.0, 2.0, 4.0, 5.0]);
        assert_eq!(e.len(), 5);
        assert!((e.eval(0.5) - 0.0).abs() < 1e-12);
        assert!((e.eval(3.0) - 0.6).abs() < 1e-12);
        assert!((e.eval(10.0) - 1.0).abs() < 1e-12);
        assert!((e.quantile(0.0) - 1.0).abs() < 1e-12);
        assert!((e.quantile(1.0) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn ecdf_series_monotone() {
        let e = Ecdf::new((0..50).map(|i| (i as f64 * 37.0) % 13.0).collect());
        let s = e.series(20);
        assert_eq!(s.len(), 20);
        for w in s.windows(2) {
            assert!(w[1].1 >= w[0].1, "ECDF must be nondecreasing");
        }
        assert!((s.last().unwrap().1 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn least_squares_exact_on_line() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 + 3.0 * x).collect();
        let (a, b) = linear_least_squares(&xs, &ys);
        assert!((a - 2.0).abs() < 1e-9);
        assert!((b - 3.0).abs() < 1e-9);
        assert!((r_squared(&xs, &ys, a, b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn least_squares_degenerate_x() {
        let xs = [2.0, 2.0, 2.0];
        let ys = [1.0, 2.0, 3.0];
        let (a, b) = linear_least_squares(&xs, &ys);
        assert_eq!(b, 0.0);
        assert!((a - 2.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_clamps_and_counts() {
        let mut h = Histogram::new(0.0, 10.0, 5);
        for x in [-1.0, 0.5, 3.0, 9.9, 42.0] {
            h.push(x);
        }
        assert_eq!(h.total(), 5);
        assert_eq!(h.counts()[0], 2); // -1 clamped + 0.5
        assert_eq!(h.counts()[4], 2); // 9.9 + 42 clamped
        assert_eq!(h.midpoints().len(), 5);
        assert!((h.midpoints()[0] - 1.0).abs() < 1e-12);
    }
}
