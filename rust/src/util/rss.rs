//! Process peak-RSS probe for the benchmark harness.
//!
//! Reads `VmHWM` from `/proc/self/status` (Linux). The value is the
//! process-lifetime high-water mark, so per-scenario readings taken
//! after each run are **cumulative**: a scenario's reading is "the
//! largest resident set any scenario so far has needed". That is the
//! right trajectory signal for `BENCH_sim.json` (a memory regression in
//! any scenario lifts the plateau) without the portability burden of
//! per-allocation accounting. On non-Linux hosts the probe returns
//! `None` and the bench record omits the field.

/// Peak resident set size of this process in mebibytes, if the
/// platform exposes it.
pub fn peak_rss_mb() -> Option<f64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    parse_vm_hwm_kb(&status).map(|kb| kb / 1024.0)
}

/// Extract `VmHWM` (kB) from `/proc/self/status` content.
fn parse_vm_hwm_kb(status: &str) -> Option<f64> {
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let number = rest.trim().trim_end_matches("kB").trim();
            return number.parse::<f64>().ok();
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_vm_hwm_line() {
        let status = "Name:\thfsp\nVmPeak:\t  200 kB\nVmHWM:\t   10240 kB\nThreads:\t1\n";
        assert_eq!(parse_vm_hwm_kb(status), Some(10240.0));
    }

    #[test]
    fn missing_line_is_none() {
        assert_eq!(parse_vm_hwm_kb("Name:\thfsp\n"), None);
    }

    #[cfg(target_os = "linux")]
    #[test]
    fn live_probe_reports_a_positive_value() {
        let mb = peak_rss_mb().expect("linux exposes VmHWM");
        assert!(mb > 0.0);
    }
}
