//! Key-value configuration files with typed access and CLI overrides.
//!
//! A small TOML-subset loader (sections, `key = value`, comments, strings,
//! numbers, booleans, homogeneous inline arrays) standing in for the
//! unavailable `toml`/`serde` crates. The launcher reads a config file,
//! applies `--set section.key=value` overrides from the command line, and
//! hands typed views to each subsystem.

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

/// A parsed configuration: flat map of `section.key` → raw value.
#[derive(Clone, Debug, Default)]
pub struct Config {
    entries: BTreeMap<String, Value>,
}

/// Configuration value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Str(s) => write!(f, "{s}"),
            Value::Num(x) => write!(f, "{x}"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
        }
    }
}

#[derive(Debug)]
pub struct ConfigError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config error at line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ConfigError {}

impl Config {
    pub fn new() -> Self {
        Self::default()
    }

    /// Parse TOML-subset text.
    pub fn parse(text: &str) -> Result<Config, ConfigError> {
        let mut cfg = Config::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                if section.is_empty() {
                    return Err(ConfigError {
                        line: lineno + 1,
                        message: "empty section name".into(),
                    });
                }
                continue;
            }
            let (key, val) = line.split_once('=').ok_or(ConfigError {
                line: lineno + 1,
                message: format!("expected `key = value`, got {line:?}"),
            })?;
            let key = key.trim();
            if key.is_empty() {
                return Err(ConfigError {
                    line: lineno + 1,
                    message: "empty key".into(),
                });
            }
            let value = parse_value(val.trim()).map_err(|m| ConfigError {
                line: lineno + 1,
                message: m,
            })?;
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            cfg.entries.insert(full, value);
        }
        Ok(cfg)
    }

    pub fn load(path: &Path) -> anyhow::Result<Config> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read config {path:?}: {e}"))?;
        Ok(Self::parse(&text)?)
    }

    /// Apply a `section.key=value` override (from `--set`).
    pub fn apply_override(&mut self, spec: &str) -> anyhow::Result<()> {
        let (key, val) = spec
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("override must be key=value, got {spec:?}"))?;
        let value = parse_value(val.trim()).map_err(|m| anyhow::anyhow!("{m}"))?;
        self.entries.insert(key.trim().to_string(), value);
        Ok(())
    }

    pub fn set(&mut self, key: &str, value: Value) {
        self.entries.insert(key.to_string(), value);
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        match self.entries.get(key) {
            Some(Value::Num(x)) => *x,
            _ => default,
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        match self.entries.get(key) {
            Some(Value::Num(x)) if *x >= 0.0 => *x as usize,
            _ => default,
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        match self.entries.get(key) {
            Some(Value::Num(x)) if *x >= 0.0 => *x as u64,
            _ => default,
        }
    }

    pub fn get_bool(&self, key: &str, default: bool) -> bool {
        match self.entries.get(key) {
            Some(Value::Bool(b)) => *b,
            _ => default,
        }
    }

    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        match self.entries.get(key) {
            Some(Value::Str(s)) => s.as_str(),
            _ => default,
        }
    }

    /// All keys under a section prefix (`"hfsp"` matches `hfsp.*`).
    pub fn section_keys(&self, section: &str) -> Vec<&str> {
        let prefix = format!("{section}.");
        self.entries
            .keys()
            .filter(|k| k.starts_with(&prefix))
            .map(|k| k.as_str())
            .collect()
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(|k| k.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // Respect '#' inside quoted strings.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| format!("unterminated string {s:?}"))?;
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| format!("unterminated array {s:?}"))?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in trimmed.split(',') {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(Value::Arr(items));
    }
    s.parse::<f64>()
        .map(Value::Num)
        .map_err(|_| format!("cannot parse value {s:?} (string values need quotes)"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# cluster shape
[cluster]
nodes = 100
map_slots = 4      # per node
reduce_slots = 2
block_mb = 128.0

[hfsp]
enabled = true
preemption = "suspend"
sample_set = 5
xi = 1.0
thresholds = [8, 16]

[workload]
name = "fb-dataset"
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.get_usize("cluster.nodes", 0), 100);
        assert_eq!(c.get_usize("cluster.map_slots", 0), 4);
        assert_eq!(c.get_f64("cluster.block_mb", 0.0), 128.0);
        assert!(c.get_bool("hfsp.enabled", false));
        assert_eq!(c.get_str("hfsp.preemption", ""), "suspend");
        assert_eq!(c.get_str("workload.name", ""), "fb-dataset");
        match c.get("hfsp.thresholds") {
            Some(Value::Arr(v)) => assert_eq!(v.len(), 2),
            other => panic!("expected array, got {other:?}"),
        }
    }

    #[test]
    fn defaults_on_missing() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.get_usize("cluster.nodes", 7), 7);
        assert_eq!(c.get_str("x.y", "dflt"), "dflt");
    }

    #[test]
    fn overrides_win() {
        let mut c = Config::parse(SAMPLE).unwrap();
        c.apply_override("cluster.nodes=10").unwrap();
        c.apply_override(r#"hfsp.preemption="wait""#).unwrap();
        assert_eq!(c.get_usize("cluster.nodes", 0), 10);
        assert_eq!(c.get_str("hfsp.preemption", ""), "wait");
    }

    #[test]
    fn comment_inside_string_preserved() {
        let c = Config::parse(r##"k = "a#b""##).unwrap();
        assert_eq!(c.get_str("k", ""), "a#b");
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = Config::parse("ok = 1\nbroken line\n").unwrap_err();
        assert_eq!(err.line, 2);
        let err = Config::parse("[s]\nk = \n").unwrap_err();
        assert_eq!(err.line, 2);
    }

    #[test]
    fn unquoted_string_rejected() {
        assert!(Config::parse("k = hello").is_err());
    }

    #[test]
    fn section_keys_lists_prefix() {
        let c = Config::parse(SAMPLE).unwrap();
        let keys = c.section_keys("hfsp");
        assert!(keys.contains(&"hfsp.enabled"));
        assert!(keys.contains(&"hfsp.sample_set"));
        assert!(!keys.iter().any(|k| k.starts_with("cluster.")));
    }
}
