//! Fast, deterministic hashing for simulator-internal maps.
//!
//! The per-event hot path indexes arenas and caches by small integer
//! keys (job ids, task refs). `std`'s default SipHash is DoS-resistant
//! but costs ~10x more than needed for trusted keys, and its per-map
//! random seed makes iteration order differ between map instances —
//! every hot structure here must already avoid order-dependence, but a
//! fixed-seed hasher removes the hazard class entirely. This is the
//! classic FxHash multiply-rotate mix (as used by rustc), implemented
//! locally because the offline build carries no external crates.
//!
//! Use [`FastMap`]/[`FastSet`] for simulator-internal state keyed by
//! trusted ids; keep `std` defaults for anything fed by external input.

use std::collections::{HashMap, HashSet};
use std::hash::{BuildHasherDefault, Hasher};

/// FxHash: one wrapping multiply + rotate per word. Deterministic
/// (seed-free) and fast on integer keys.
#[derive(Default)]
pub struct FxHasher {
    hash: u64,
}

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for chunk in bytes.chunks(8) {
            let mut buf = [0u8; 8];
            buf[..chunk.len()].copy_from_slice(chunk);
            self.add_to_hash(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u8(&mut self, n: u8) {
        self.add_to_hash(n as u64);
    }
    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.add_to_hash(n as u64);
    }
    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.add_to_hash(n);
    }
    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.add_to_hash(n as u64);
    }
}

/// `HashMap` with the deterministic [`FxHasher`].
pub type FastMap<K, V> = HashMap<K, V, BuildHasherDefault<FxHasher>>;

/// `HashSet` with the deterministic [`FxHasher`].
pub type FastSet<T> = HashSet<T, BuildHasherDefault<FxHasher>>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_and_set_round_trip() {
        let mut m: FastMap<u64, &str> = FastMap::default();
        m.insert(1, "a");
        m.insert(2, "b");
        assert_eq!(m.get(&1), Some(&"a"));
        assert_eq!(m.remove(&2), Some("b"));
        assert!(m.get(&2).is_none());

        let mut s: FastSet<(u64, u32)> = FastSet::default();
        assert!(s.insert((7, 3)));
        assert!(!s.insert((7, 3)));
        assert!(s.contains(&(7, 3)));
    }

    #[test]
    fn hashing_is_deterministic_across_instances() {
        use std::hash::{BuildHasher, BuildHasherDefault};
        let b1: BuildHasherDefault<FxHasher> = BuildHasherDefault::default();
        let b2: BuildHasherDefault<FxHasher> = BuildHasherDefault::default();
        for key in [0u64, 1, 42, u64::MAX] {
            assert_eq!(b1.hash_one(key), b2.hash_one(key));
        }
    }

    #[test]
    fn distinct_keys_rarely_collide() {
        use std::hash::{BuildHasher, BuildHasherDefault};
        let b: BuildHasherDefault<FxHasher> = BuildHasherDefault::default();
        let mut seen = std::collections::HashSet::new();
        for key in 0u64..10_000 {
            seen.insert(b.hash_one(key));
        }
        assert_eq!(seen.len(), 10_000, "trivial collisions on dense keys");
    }
}
