//! Per-job slot-occupancy timelines.
//!
//! Records, for each job, the intervals during which it held task slots.
//! This is the data behind the paper's Fig. 7 "resource allocation graphs"
//! (cumulative slot utilization per job over time) and is also used by
//! tests to assert slot conservation.

use std::collections::BTreeMap;

/// One recorded slot-holding interval.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Interval {
    pub start: f64,
    pub end: f64,
}

/// Step-function of concurrent slots held by one job.
#[derive(Clone, Debug, Default)]
pub struct JobTimeline {
    /// (time, delta) events: +1 slot acquired, -1 slot released.
    deltas: Vec<(f64, i64)>,
}

impl JobTimeline {
    pub fn acquire(&mut self, t: f64) {
        self.deltas.push((t, 1));
    }

    pub fn release(&mut self, t: f64) {
        self.deltas.push((t, -1));
    }

    /// Evaluate concurrent slot count just after time `t`.
    pub fn slots_at(&self, t: f64) -> i64 {
        self.deltas
            .iter()
            .filter(|(dt, _)| *dt <= t)
            .map(|(_, d)| d)
            .sum()
    }

    /// Collapse to a sorted step series `(time, slots)`; consecutive equal
    /// values are merged.
    pub fn step_series(&self) -> Vec<(f64, i64)> {
        let mut events = self.deltas.clone();
        events.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut out: Vec<(f64, i64)> = Vec::new();
        let mut level = 0i64;
        let mut i = 0;
        while i < events.len() {
            let t = events[i].0;
            while i < events.len() && events[i].0.total_cmp(&t).is_eq() {
                level += events[i].1;
                i += 1;
            }
            if out.last().map(|&(_, l)| l) != Some(level) {
                out.push((t, level));
            }
        }
        out
    }

    /// Total slot-seconds consumed (integral of the step function). The
    /// series must be balanced (every acquire has a release).
    pub fn slot_seconds(&self) -> f64 {
        let series = self.step_series();
        let mut total = 0.0;
        for w in series.windows(2) {
            total += w[0].1 as f64 * (w[1].0 - w[0].0);
        }
        // Any trailing level must be zero for a finished job.
        total
    }

    /// Maximum concurrency.
    pub fn peak_slots(&self) -> i64 {
        self.step_series().iter().map(|&(_, l)| l).max().unwrap_or(0)
    }

    pub fn is_balanced(&self) -> bool {
        self.deltas.iter().map(|(_, d)| d).sum::<i64>() == 0
    }

    /// Append another timeline's raw events. Order does not matter:
    /// every reader sorts ([`JobTimeline::step_series`]) or reduces over
    /// the whole delta set.
    pub fn merge(&mut self, other: JobTimeline) {
        self.deltas.extend(other.deltas);
    }
}

/// Timelines for a set of jobs, keyed by an opaque id.
#[derive(Clone, Debug, Default)]
pub struct TimelineSet {
    jobs: BTreeMap<u64, JobTimeline>,
}

impl TimelineSet {
    pub fn acquire(&mut self, job: u64, t: f64) {
        self.jobs.entry(job).or_default().acquire(t);
    }

    pub fn release(&mut self, job: u64, t: f64) {
        self.jobs.entry(job).or_default().release(t);
    }

    pub fn job(&self, job: u64) -> Option<&JobTimeline> {
        self.jobs.get(&job)
    }

    pub fn jobs(&self) -> impl Iterator<Item = (&u64, &JobTimeline)> {
        self.jobs.iter()
    }

    /// Fold another set into this one, concatenating timelines of jobs
    /// present in both (sharded-run merge; a job that migrated between
    /// shards has slot intervals in several sets).
    pub fn merge(&mut self, other: TimelineSet) {
        for (job, tl) in other.jobs {
            self.jobs.entry(job).or_default().merge(tl);
        }
    }

    /// Total concurrent slots across all jobs at time `t` — used to assert
    /// cluster capacity is never exceeded.
    pub fn total_slots_at(&self, t: f64) -> i64 {
        self.jobs.values().map(|j| j.slots_at(t)).sum()
    }

    /// Render an ASCII stacked allocation chart (one row per job), sampling
    /// `cols` time points in `[t0, t1]`. Each cell shows the job's slot
    /// count (0 -> '.', 1-9 -> digit, >9 -> '#'): the textual analogue of
    /// the paper's Fig. 7.
    pub fn ascii_chart(&self, t0: f64, t1: f64, cols: usize) -> String {
        let mut out = String::new();
        for (id, tl) in &self.jobs {
            out.push_str(&format!("job {id:>3} |"));
            for c in 0..cols {
                let t = t0 + (t1 - t0) * c as f64 / (cols.max(2) - 1) as f64;
                let s = tl.slots_at(t);
                let ch = match s {
                    0 => '.',
                    1..=9 => char::from_digit(s as u32, 10).unwrap(),
                    _ => '#',
                };
                out.push(ch);
            }
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_series_merges_and_orders() {
        let mut tl = JobTimeline::default();
        tl.acquire(0.0);
        tl.acquire(0.0);
        tl.release(5.0);
        tl.acquire(2.0);
        tl.release(5.0);
        tl.release(8.0);
        let s = tl.step_series();
        assert_eq!(s, vec![(0.0, 2), (2.0, 3), (5.0, 1), (8.0, 0)]);
        assert!(tl.is_balanced());
    }

    #[test]
    fn slots_at_evaluates_step() {
        let mut tl = JobTimeline::default();
        tl.acquire(1.0);
        tl.release(4.0);
        assert_eq!(tl.slots_at(0.5), 0);
        assert_eq!(tl.slots_at(1.0), 1);
        assert_eq!(tl.slots_at(3.9), 1);
        assert_eq!(tl.slots_at(4.0), 0);
    }

    #[test]
    fn slot_seconds_integrates() {
        let mut tl = JobTimeline::default();
        tl.acquire(0.0); // 1 slot on [0, 10)
        tl.acquire(5.0); // 2 slots on [5, 10)
        tl.release(10.0);
        tl.release(10.0);
        assert!((tl.slot_seconds() - 15.0).abs() < 1e-12);
        assert_eq!(tl.peak_slots(), 2);
    }

    #[test]
    fn total_slots_sums_jobs() {
        let mut ts = TimelineSet::default();
        ts.acquire(1, 0.0);
        ts.acquire(2, 0.0);
        ts.release(1, 2.0);
        ts.release(2, 3.0);
        assert_eq!(ts.total_slots_at(1.0), 2);
        assert_eq!(ts.total_slots_at(2.5), 1);
        assert_eq!(ts.total_slots_at(3.5), 0);
    }

    #[test]
    fn merge_concatenates_shared_jobs() {
        let mut a = TimelineSet::default();
        a.acquire(1, 0.0);
        a.release(1, 2.0);
        let mut b = TimelineSet::default();
        b.acquire(1, 4.0);
        b.release(1, 6.0);
        b.acquire(2, 0.0);
        b.release(2, 1.0);
        a.merge(b);
        let tl = a.job(1).unwrap();
        assert!(tl.is_balanced());
        assert!((tl.slot_seconds() - 4.0).abs() < 1e-12);
        assert!(a.job(2).is_some());
    }

    #[test]
    fn ascii_chart_shape() {
        let mut ts = TimelineSet::default();
        ts.acquire(7, 0.0);
        ts.release(7, 10.0);
        let chart = ts.ascii_chart(0.0, 10.0, 20);
        assert!(chart.starts_with("job   7 |"));
        assert!(chart.contains('1'));
        assert_eq!(chart.lines().count(), 1);
    }
}
