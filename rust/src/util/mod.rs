//! Infrastructure substrates: PRNG, statistics, JSON, CLI, config, logging
//! and slot timelines.
//!
//! The offline build environment only carries the `xla` crate's dependency
//! closure, so functionality usually imported from `rand`, `serde_json`,
//! `clap`, `toml` and `tracing-subscriber` is implemented here (see
//! DESIGN.md §2 for the substitution table).

pub mod cli;
pub mod config;
pub mod fxmap;
pub mod json;
pub mod logging;
pub mod rng;
pub mod rss;
pub mod stats;
pub mod timeline;
