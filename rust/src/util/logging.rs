//! Leveled logger implementing the `log` facade.
//!
//! Writes to stderr with a monotonic-ish timestamp and module path; level
//! is controlled by `HFSP_LOG` (error|warn|info|debug|trace) or
//! programmatically. Substitute for the unavailable `tracing-subscriber`.

use log::{Level, LevelFilter, Log, Metadata, Record};
use std::io::Write;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

static INSTALLED: AtomicBool = AtomicBool::new(false);

struct StderrLogger {
    start: Instant,
}

impl Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        let lvl = match record.level() {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        let mut err = std::io::stderr().lock();
        let _ = writeln!(
            err,
            "[{t:10.3}s {lvl} {}] {}",
            record.module_path().unwrap_or("?"),
            record.args()
        );
    }

    fn flush(&self) {
        let _ = std::io::stderr().flush();
    }
}

/// Parse a level name; defaults to `Info` on unknown input.
pub fn parse_level(s: &str) -> LevelFilter {
    match s.to_ascii_lowercase().as_str() {
        "off" => LevelFilter::Off,
        "error" => LevelFilter::Error,
        "warn" => LevelFilter::Warn,
        "debug" => LevelFilter::Debug,
        "trace" => LevelFilter::Trace,
        _ => LevelFilter::Info,
    }
}

/// Install the logger once; later calls only adjust the level.
pub fn init(level: LevelFilter) {
    if !INSTALLED.swap(true, Ordering::SeqCst) {
        // The logger lives for the program duration.
        let _ = log::set_boxed_logger(Box::new(StderrLogger {
            start: Instant::now(),
        }));
    }
    log::set_max_level(level);
}

/// Initialize from the `HFSP_LOG` environment variable (default `warn`,
/// so tests and benches stay quiet unless asked).
pub fn init_from_env() {
    let level = std::env::var("HFSP_LOG")
        .map(|s| parse_level(&s))
        .unwrap_or(LevelFilter::Warn);
    init(level);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_level_known_and_unknown() {
        assert_eq!(parse_level("error"), LevelFilter::Error);
        assert_eq!(parse_level("TRACE"), LevelFilter::Trace);
        assert_eq!(parse_level("bogus"), LevelFilter::Info);
        assert_eq!(parse_level("off"), LevelFilter::Off);
    }

    #[test]
    fn init_is_idempotent() {
        init(LevelFilter::Warn);
        init(LevelFilter::Info);
        assert_eq!(log::max_level(), LevelFilter::Info);
        log::info!("logger smoke test");
    }
}
