//! Minimal JSON value model, writer and parser.
//!
//! The offline environment does not ship `serde`/`serde_json`, so this
//! module provides the subset the project needs: a dynamic [`Json`] value,
//! a compact writer with correct string escaping and float formatting, and
//! a recursive-descent parser. Used for workload traces (JSONL), benchmark
//! reports and the artifact manifest.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept in a `BTreeMap` so serialization is
/// deterministic (stable key order) — important for golden-file tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics if `self` is not an object.
    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize compactly (no whitespace).
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    push_indent(out, indent + 1);
                    item.write_pretty(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    push_indent(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push('}');
            }
            _ => self.write(out),
        }
    }
}

fn push_indent(out: &mut String, n: usize) {
    for _ in 0..n {
        out.push_str("  ");
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_nan() || x.is_infinite() {
        // JSON has no NaN/Inf; emit null (matches common lenient writers).
        out.push_str("null");
    } else if x.fract() == 0.0 && x.abs() < 1e15 {
        let _ = fmt::Write::write_fmt(out, format_args!("{}", x as i64));
    } else {
        let _ = fmt::Write::write_fmt(out, format_args!("{}", x));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

/// Parse error with byte offset context.
#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "JSON parse error at byte {}: {}",
            self.offset, self.message
        )
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

/// Parse a JSON document. Trailing whitespace is allowed; trailing garbage
/// is an error.
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after JSON value"));
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            offset: self.pos,
            message: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("invalid literal, expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are not needed for our data;
                            // map unpaired surrogates to the replacement char.
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

// Convenience From impls for building values tersely.
impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Self {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Self {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Self {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Self {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1", "3.25", "1e3"] {
            let v = parse(text).unwrap();
            let v2 = parse(&v.to_string_compact()).unwrap();
            assert_eq!(v, v2, "roundtrip of {text}");
        }
    }

    #[test]
    fn roundtrip_nested() {
        let text = r#"{"a": [1, 2, {"b": "x\ny", "c": null}], "d": -2.5}"#;
        let v = parse(text).unwrap();
        let v2 = parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, v2);
        let v3 = parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn accessors() {
        let v = parse(r#"{"n": 3, "s": "hi", "b": true, "a": [1,2]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_u64(), Some(3));
        assert_eq!(v.get("n").unwrap().as_f64(), Some(3.0));
        assert_eq!(v.get("s").unwrap().as_str(), Some("hi"));
        assert_eq!(v.get("b").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
        assert!(v.get("missing").is_none());
    }

    #[test]
    fn string_escapes() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".to_string());
        let s = v.to_string_compact();
        assert_eq!(s, r#""a\"b\\c\nd\te\u0001""#);
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn integer_formatting_is_exact() {
        assert_eq!(Json::Num(42.0).to_string_compact(), "42");
        assert_eq!(Json::Num(-7.0).to_string_compact(), "-7");
        assert_eq!(Json::Num(2.5).to_string_compact(), "2.5");
        assert_eq!(Json::Num(f64::NAN).to_string_compact(), "null");
    }

    #[test]
    fn object_key_order_is_stable() {
        let mut o = Json::obj();
        o.set("zebra", 1u64.into());
        o.set("alpha", 2u64.into());
        assert_eq!(o.to_string_compact(), r#"{"alpha":2,"zebra":1}"#);
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse(r#""héllo ☃""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo ☃"));
    }
}
