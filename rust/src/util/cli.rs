//! Command-line argument parsing (subcommands + flags).
//!
//! A small, dependency-free substitute for `clap`: the offline environment
//! only carries the `xla` crate closure. Supports `--flag value`,
//! `--flag=value`, boolean `--flag`, repeated flags, positional arguments
//! and auto-generated usage text.

use std::collections::BTreeMap;

/// Declarative flag specification.
#[derive(Clone, Debug)]
pub struct FlagSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// None => boolean flag; Some(default) => valued flag with default
    /// (empty string means "required").
    pub default: Option<&'static str>,
}

/// Parsed arguments for one (sub)command.
#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, Vec<String>>,
    bools: BTreeMap<String, bool>,
    positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn get_all(&self, name: &str) -> &[String] {
        self.values.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn get_bool(&self, name: &str) -> bool {
        self.bools.get(name).copied().unwrap_or(false)
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> anyhow::Result<Option<T>> {
        match self.get(name) {
            None => Ok(None),
            Some(s) => s
                .parse::<T>()
                .map(Some)
                .map_err(|_| anyhow::anyhow!("invalid value for --{name}: {s:?}")),
        }
    }

    /// Valued flag with a required parse; error mentions the flag name.
    pub fn require<T: std::str::FromStr>(&self, name: &str) -> anyhow::Result<T> {
        self.get_parsed(name)?
            .ok_or_else(|| anyhow::anyhow!("missing required flag --{name}"))
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

/// A subcommand with its flag specs.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub flags: Vec<FlagSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self {
            name,
            about,
            flags: Vec::new(),
        }
    }

    /// Valued flag with default.
    pub fn flag(mut self, name: &'static str, default: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec {
            name,
            help,
            default: Some(default),
        });
        self
    }

    /// Boolean switch.
    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec {
            name,
            help,
            default: None,
        });
        self
    }

    /// Parse `argv` (without the program/subcommand names).
    pub fn parse(&self, argv: &[String]) -> anyhow::Result<Args> {
        let mut args = Args::default();
        // Seed defaults.
        for f in &self.flags {
            if let Some(d) = f.default {
                if !d.is_empty() {
                    args.values
                        .entry(f.name.to_string())
                        .or_default()
                        .push(d.to_string());
                }
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (stripped, None),
                };
                let spec = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| anyhow::anyhow!("unknown flag --{name}\n{}", self.usage()))?;
                match spec.default {
                    None => {
                        if inline_val.is_some() {
                            anyhow::bail!("flag --{name} does not take a value");
                        }
                        args.bools.insert(name.to_string(), true);
                    }
                    Some(_) => {
                        let val = match inline_val {
                            Some(v) => v,
                            None => {
                                i += 1;
                                argv.get(i)
                                    .cloned()
                                    .ok_or_else(|| anyhow::anyhow!("flag --{name} needs a value"))?
                            }
                        };
                        let entry = args.values.entry(name.to_string()).or_default();
                        // Replace the default on first explicit occurrence;
                        // append on repeats.
                        if entry.len() == 1
                            && spec.default.map(|d| !d.is_empty()).unwrap_or(false)
                            && entry[0] == spec.default.unwrap()
                        {
                            entry.clear();
                        }
                        entry.push(val);
                    }
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    pub fn usage(&self) -> String {
        let mut s = format!("usage: hfsp {} [flags]\n  {}\n\nflags:\n", self.name, self.about);
        for f in &self.flags {
            let kind = match f.default {
                None => "".to_string(),
                Some("") => " <value> (required)".to_string(),
                Some(d) => format!(" <value> (default: {d})"),
            };
            s.push_str(&format!("  --{}{}\n      {}\n", f.name, kind, f.help));
        }
        s
    }
}

/// Top-level CLI: a set of subcommands.
pub struct Cli {
    pub about: &'static str,
    pub commands: Vec<Command>,
}

impl Cli {
    pub fn usage(&self) -> String {
        let mut s = format!("{}\n\nsubcommands:\n", self.about);
        for c in &self.commands {
            s.push_str(&format!("  {:<18} {}\n", c.name, c.about));
        }
        s.push_str("\nrun `hfsp <subcommand> --help` for flags\n");
        s
    }

    /// Dispatch: returns the matched command name and parsed args, or the
    /// usage/help text to print.
    pub fn parse(&self, argv: &[String]) -> anyhow::Result<Parsed> {
        if argv.is_empty() || argv[0] == "--help" || argv[0] == "help" {
            return Ok(Parsed::Help(self.usage()));
        }
        let name = &argv[0];
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == name.as_str())
            .ok_or_else(|| anyhow::anyhow!("unknown subcommand {name:?}\n{}", self.usage()))?;
        let rest = &argv[1..];
        if rest.iter().any(|a| a == "--help") {
            return Ok(Parsed::Help(cmd.usage()));
        }
        Ok(Parsed::Command(cmd.name, cmd.parse(rest)?))
    }
}

/// Result of CLI dispatch.
pub enum Parsed {
    Help(String),
    Command(&'static str, Args),
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("simulate", "run a simulation")
            .flag("nodes", "100", "cluster size")
            .flag("seed", "42", "rng seed")
            .flag("out", "", "output path (required)")
            .switch("verbose", "chatty output")
    }

    fn argv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(&argv(&["--out", "x.json"])).unwrap();
        assert_eq!(a.get("nodes"), Some("100"));
        assert_eq!(a.require::<u64>("seed").unwrap(), 42);
        assert!(!a.get_bool("verbose"));
    }

    #[test]
    fn explicit_overrides_default() {
        let a = cmd()
            .parse(&argv(&["--nodes=10", "--out", "x", "--verbose"]))
            .unwrap();
        assert_eq!(a.require::<usize>("nodes").unwrap(), 10);
        assert!(a.get_bool("verbose"));
    }

    #[test]
    fn equals_and_space_forms() {
        let a = cmd().parse(&argv(&["--seed=7", "--out", "o"])).unwrap();
        assert_eq!(a.require::<u64>("seed").unwrap(), 7);
        let b = cmd().parse(&argv(&["--seed", "7", "--out=o"])).unwrap();
        assert_eq!(b.require::<u64>("seed").unwrap(), 7);
        assert_eq!(b.get("out"), Some("o"));
    }

    #[test]
    fn missing_required_flag_errors() {
        let a = cmd().parse(&argv(&[])).unwrap();
        assert!(a.require::<String>("out").is_err());
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(cmd().parse(&argv(&["--bogus", "1"])).is_err());
    }

    #[test]
    fn bool_flag_rejects_value() {
        assert!(cmd().parse(&argv(&["--verbose=1"])).is_err());
    }

    #[test]
    fn positional_collected() {
        let a = cmd().parse(&argv(&["trace.jsonl", "--out", "x"])).unwrap();
        assert_eq!(a.positional(), &["trace.jsonl".to_string()]);
    }

    #[test]
    fn repeated_flag_collects() {
        let a = cmd()
            .parse(&argv(&["--out", "a", "--out", "b"]))
            .unwrap();
        assert_eq!(a.get_all("out"), &["a".to_string(), "b".to_string()]);
        assert_eq!(a.get("out"), Some("b"));
    }

    #[test]
    fn cli_dispatch() {
        let cli = Cli {
            about: "hfsp",
            commands: vec![cmd()],
        };
        match cli.parse(&argv(&["simulate", "--out", "x"])).unwrap() {
            Parsed::Command("simulate", a) => assert_eq!(a.get("out"), Some("x")),
            _ => panic!("expected command"),
        }
        assert!(matches!(cli.parse(&argv(&["--help"])).unwrap(), Parsed::Help(_)));
        assert!(cli.parse(&argv(&["nope"])).is_err());
    }
}
