//! Pull-based workload sources for streaming simulation sessions.
//!
//! A [`WorkloadSource`] hands jobs to the driver **one arrival batch at
//! a time** instead of materializing the whole workload up front. The
//! driver keeps only the current same-instant arrival batch plus one
//! look-ahead job in memory, so a session's working state (job table
//! with per-task runtimes, pending events) scales with the number of
//! *concurrently active* jobs, not the workload length — the
//! difference between a 100-job closed trace and a steady-state open
//! run of millions of jobs. (The built-in sojourn statistics still
//! keep one compact record per finished job.)
//!
//! ## Contract
//!
//! * `next_job` returns jobs in **nondecreasing `submit_time` order**;
//!   a regression is clamped to the previous arrival instant (and
//!   logged) rather than crashing, but sources should never rely on
//!   that.
//! * Job ids must be unique across the whole stream — the driver's job
//!   table is keyed by id. Closed sources inherit this guarantee from
//!   [`Workload::new`]; generators must assign fresh ids.
//! * `next_job` receives the session's dedicated arrival RNG stream
//!   (see [`StreamId::Arrivals`](crate::util::rng::StreamId)), so open
//!   generators are reproducible per master seed and never perturb
//!   placement or fault draws. Deterministic sources ignore it.
//! * `None` is final: once a source reports exhaustion the driver stops
//!   polling and lets the cluster drain.

use super::Workload;
use crate::job::JobSpec;
use crate::util::rng::Pcg64;
use std::borrow::Cow;

/// A pull-based job stream feeding one simulation session.
pub trait WorkloadSource {
    /// Display name, recorded in `SimOutcome::workload` and sweep group
    /// keys.
    fn name(&self) -> &str;

    /// Pull the next job, in nondecreasing `submit_time` order; `None`
    /// when the stream is exhausted.
    fn next_job(&mut self, rng: &mut Pcg64) -> Option<JobSpec>;

    /// The error that truncated the stream, if any — polled by the
    /// driver once `next_job` returns `None` and surfaced as
    /// `SimOutcome::stream_error`, so a partial replay (e.g. a corrupt
    /// trace line) is never mistaken for normal exhaustion. Sources
    /// that cannot fail keep the `None` default.
    fn take_error(&mut self) -> Option<String> {
        None
    }
}

/// The closed-workload source: replays a [`Workload`]'s job vector in
/// submission order. This is what the [`run_simulation`] compat shim
/// wraps around its `&Workload` argument — each spec is cloned on pull,
/// exactly the per-arrival cost of the historical batch path.
///
/// [`run_simulation`]: crate::cluster::driver::run_simulation
pub struct ClosedSource<'a> {
    name: String,
    jobs: Cow<'a, [JobSpec]>,
    next: usize,
}

impl<'a> ClosedSource<'a> {
    /// Borrow a workload (jobs cloned one at a time as they arrive).
    pub fn of(workload: &'a Workload) -> Self {
        Self {
            name: workload.name.clone(),
            jobs: Cow::Borrowed(&workload.jobs),
            next: 0,
        }
    }
}

impl From<Workload> for ClosedSource<'static> {
    /// Take ownership of a workload (builder-friendly).
    fn from(workload: Workload) -> Self {
        Self {
            name: workload.name,
            jobs: Cow::Owned(workload.jobs),
            next: 0,
        }
    }
}

impl WorkloadSource for ClosedSource<'_> {
    fn name(&self) -> &str {
        &self.name
    }

    fn next_job(&mut self, _rng: &mut Pcg64) -> Option<JobSpec> {
        let job = self.jobs.get(self.next).cloned()?;
        self.next += 1;
        Some(job)
    }
}

impl Workload {
    /// Stream this workload by reference (see [`ClosedSource::of`]).
    pub fn as_source(&self) -> ClosedSource<'_> {
        ClosedSource::of(self)
    }

    /// Stream this workload by value (see
    /// [`ClosedSource::from`](ClosedSource)).
    pub fn into_source(self) -> ClosedSource<'static> {
        ClosedSource::from(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SeedableRng;
    use crate::workload::synthetic;

    #[test]
    fn closed_source_replays_in_submission_order() {
        let wl = synthetic::fig7_workload();
        let mut rng = Pcg64::seed_from_u64(1);
        let mut src = wl.as_source();
        assert_eq!(src.name(), "fig7-preemption");
        let mut last = f64::NEG_INFINITY;
        let mut n = 0;
        while let Some(job) = src.next_job(&mut rng) {
            assert!(job.submit_time >= last, "nondecreasing arrivals");
            last = job.submit_time;
            n += 1;
        }
        assert_eq!(n, 5);
        assert!(src.next_job(&mut rng).is_none(), "None is final");
    }

    #[test]
    fn borrowed_and_owned_sources_yield_identical_streams() {
        let wl = synthetic::uniform_batch(4, 2, 3.0);
        let mut rng = Pcg64::seed_from_u64(2);
        let mut by_ref = wl.as_source();
        let mut by_val = wl.clone().into_source();
        loop {
            let a = by_ref.next_job(&mut rng);
            let b = by_val.next_job(&mut rng);
            match (&a, &b) {
                (Some(x), Some(y)) => {
                    assert_eq!(x.id, y.id);
                    assert_eq!(x.submit_time, y.submit_time);
                }
                (None, None) => break,
                _ => panic!("streams diverged"),
            }
        }
    }
}
