//! Workload generation and trace I/O.
//!
//! The paper drives its evaluation with SWIM-generated workloads
//! synthesized from Facebook production traces ("FB-dataset", §4.1). The
//! raw traces are not public; what the paper discloses is the class mix
//! and shape statistics, which [`swim::FbWorkload`] reproduces exactly
//! (see DESIGN.md §2 for the substitution note). Pathological and
//! illustrative workloads used by the micro-benchmarks live in
//! [`synthetic`]; [`trace`] reads/writes replayable JSONL traces.
//!
//! Simulation sessions consume jobs through the pull-based
//! [`WorkloadSource`] abstraction ([`source`]): closed [`Workload`]
//! vectors stream through [`ClosedSource`], open rate-controlled
//! arrival processes through [`open::OpenArrivals`], and JSONL traces
//! replay lazily through [`trace::TraceSource`].

pub mod open;
pub mod population;
pub mod source;
pub mod swim;
pub mod synthetic;
pub mod trace;

pub use open::{JobMix, OpenArrivals};
pub use population::TenantPopulation;
pub use source::{ClosedSource, WorkloadSource};

use crate::job::JobSpec;

/// A workload: jobs sorted by submission time.
#[derive(Clone, Debug)]
pub struct Workload {
    pub name: String,
    pub jobs: Vec<JobSpec>,
}

impl Workload {
    /// Build a workload: sorts jobs by submission time and rejects
    /// duplicate job ids (they would corrupt the driver's job table).
    /// Generators that assign ids themselves can `expect` the result;
    /// anything ingesting external data (trace replay, the CLI) must
    /// propagate the error.
    pub fn new(name: impl Into<String>, mut jobs: Vec<JobSpec>) -> anyhow::Result<Self> {
        jobs.sort_by(|a, b| a.submit_time.total_cmp(&b.submit_time).then(a.id.cmp(&b.id)));
        let mut ids: Vec<_> = jobs.iter().map(|j| j.id).collect();
        ids.sort_unstable();
        if let Some(dup) = ids.windows(2).find(|w| w[0] == w[1]) {
            anyhow::bail!("duplicate job id {} in workload", dup[0]);
        }
        Ok(Self {
            name: name.into(),
            jobs,
        })
    }

    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// Total serialized work (map + reduce), seconds.
    pub fn total_work(&self) -> f64 {
        self.jobs.iter().map(|j| j.true_size()).sum()
    }

    /// Total task count over both phases.
    pub fn total_tasks(&self) -> usize {
        self.jobs.iter().map(|j| j.n_maps() + j.n_reduces()).sum()
    }

    /// Submission window (last arrival − first arrival), seconds.
    pub fn span(&self) -> f64 {
        match (self.jobs.first(), self.jobs.last()) {
            (Some(a), Some(b)) => b.submit_time - a.submit_time,
            _ => 0.0,
        }
    }

    /// Keep only the MAP phase of every job (used by the paper's Fig. 6
    /// robustness experiment, which runs a "modified, MAP only version of
    /// the FB-dataset").
    pub fn map_only(&self) -> Workload {
        let jobs = self
            .jobs
            .iter()
            .map(|j| {
                let mut j = j.clone();
                j.reduce_durations.clear();
                j
            })
            .collect();
        Workload::new(format!("{}-map-only", self.name), jobs)
            .expect("source workload ids are unique")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::JobClass;

    fn spec(id: u64, submit: f64) -> JobSpec {
        JobSpec {
            id,
            name: format!("j{id}"),
            class: JobClass::Small,
            tenant: crate::job::TenantId::default(),
            submit_time: submit,
            map_durations: vec![10.0],
            reduce_durations: vec![5.0],
        }
    }

    #[test]
    fn sorts_by_submission() {
        let w = Workload::new("t", vec![spec(1, 5.0), spec(2, 1.0)]).unwrap();
        assert_eq!(w.jobs[0].id, 2);
        assert!((w.span() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn rejects_duplicate_ids_with_an_error() {
        let err = Workload::new("t", vec![spec(1, 0.0), spec(1, 1.0)]).unwrap_err();
        assert!(err.to_string().contains("duplicate job id 1"), "{err}");
    }

    #[test]
    fn totals() {
        let w = Workload::new("t", vec![spec(1, 0.0), spec(2, 1.0)]).unwrap();
        assert_eq!(w.total_tasks(), 4);
        assert!((w.total_work() - 30.0).abs() < 1e-12);
    }

    #[test]
    fn map_only_strips_reduces() {
        let w = Workload::new("t", vec![spec(1, 0.0)]).unwrap().map_only();
        assert_eq!(w.jobs[0].n_reduces(), 0);
        assert_eq!(w.jobs[0].n_maps(), 1);
        assert!(w.name.ends_with("map-only"));
    }
}
