//! Synthetic workloads for the paper's micro-benchmarks and illustrations.

use super::Workload;
use crate::job::{JobClass, JobSpec, TenantId};

/// The Fig. 7 preemption workload (§4.3 "Job preemption disciplines"):
/// a small cluster of 4 machines × 2 reduce slots; five reduce-only jobs.
///
/// * `j1`: 11 reduce tasks of ~500 s each, arriving at t = 2 min 20 s;
/// * `j2`: 2 reduce tasks, arriving at t = 2 min 30 s;
/// * `j3..j5`: 1 reduce task each, arriving at t = 2 min 30 s;
/// * reduce task times of `j2..j5` are smaller than `j1`'s (we use 60 s).
pub fn fig7_workload() -> Workload {
    let mut jobs = Vec::new();
    jobs.push(JobSpec {
        id: 1,
        name: "fig7-j1".into(),
        class: JobClass::Large,
        tenant: TenantId::default(),
        submit_time: 140.0,
        map_durations: vec![],
        reduce_durations: vec![500.0; 11],
    });
    for (i, n_red) in [(2u64, 2usize), (3, 1), (4, 1), (5, 1)] {
        jobs.push(JobSpec {
            id: i,
            name: format!("fig7-j{i}"),
            class: JobClass::Small,
            tenant: TenantId::default(),
            submit_time: 150.0,
            map_durations: vec![],
            reduce_durations: vec![60.0; n_red],
        });
    }
    Workload::new("fig7-preemption", jobs).expect("fig7 ids are unique")
}

/// Pathological arrival pattern discussed in §3.3 ("Finite machine
/// resources"): a sequence of jobs sorted in decreasing size arriving
/// back-to-back, each preempting its predecessor under eager preemption —
/// the stressor for the suspension-threshold hysteresis.
pub fn decreasing_size_workload(n_jobs: usize, slots_worth: usize, base_task_s: f64) -> Workload {
    let jobs = (0..n_jobs)
        .map(|i| {
            // Sizes decrease geometrically so each arrival preempts.
            let task_s = base_task_s * 0.7f64.powi(i as i32);
            JobSpec {
                id: i as u64 + 1,
                name: format!("dec-{i}"),
                class: JobClass::Medium,
                tenant: TenantId::default(),
                submit_time: 5.0 * i as f64,
                map_durations: vec![],
                reduce_durations: vec![task_s.max(10.0); slots_worth],
            }
        })
        .collect();
    Workload::new("decreasing-size", jobs).expect("sequential ids are unique")
}

/// The three-job single-server example of Fig. 1 (§2.1): jobs requiring
/// the full system, sizes 30/10/10 s (time to completion when holding
/// *all* resources), arrivals 0/10/15 s.
///
/// Jobs are split into `waves` waves of `server_slots` tasks each so the
/// slot-granular simulator can approximate fluid processor sharing (with
/// a single wave, a job monopolizes the slots for its entire life and
/// neither PS nor FSP behaviour is observable).
pub fn fig1_workload(server_slots: usize, waves: usize) -> Workload {
    assert!(waves >= 1);
    let mk = |id: u64, submit: f64, size_s: f64| JobSpec {
        id,
        name: format!("fig1-j{id}"),
        class: JobClass::Small,
        tenant: TenantId::default(),
        submit_time: submit,
        map_durations: vec![size_s / waves as f64; server_slots * waves],
        reduce_durations: vec![],
    };
    Workload::new(
        "fig1-fsp-intuition",
        vec![mk(1, 0.0, 30.0), mk(2, 10.0, 10.0), mk(3, 15.0, 10.0)],
    )
    .expect("fig1 ids are unique")
}

/// The multi-processor example of Fig. 2 (§2.1): jobs needing 100 %, 55 %
/// and 35 % of the cluster, processing times 30/10/10 s, arrivals
/// 0/10/13 s. Split into `waves` waves like [`fig1_workload`].
pub fn fig2_workload(total_slots: usize, waves: usize) -> Workload {
    assert!(waves >= 1);
    let mk = |id: u64, submit: f64, frac: f64, size_s: f64| {
        let width = ((total_slots as f64 * frac).round() as usize).max(1);
        JobSpec {
            id,
            name: format!("fig2-j{id}"),
            class: JobClass::Small,
            tenant: TenantId::default(),
            submit_time: submit,
            map_durations: vec![size_s / waves as f64; width * waves],
            reduce_durations: vec![],
        }
    };
    Workload::new(
        "fig2-fsp-multiproc",
        vec![
            mk(1, 0.0, 1.0, 30.0),
            mk(2, 10.0, 0.55, 10.0),
            mk(3, 13.0, 0.35, 10.0),
        ],
    )
    .expect("fig2 ids are unique")
}

/// A uniform batch: `n` identical jobs arriving together — useful for
/// fairness tests (under FAIR each should get an equal share; under HFSP
/// they run in series in arrival order).
pub fn uniform_batch(n: usize, maps_per_job: usize, task_s: f64) -> Workload {
    let jobs = (0..n)
        .map(|i| JobSpec {
            id: i as u64 + 1,
            name: format!("uni-{i}"),
            class: JobClass::Medium,
            tenant: TenantId::default(),
            submit_time: 0.0,
            map_durations: vec![task_s; maps_per_job],
            reduce_durations: vec![],
        })
        .collect();
    Workload::new("uniform-batch", jobs).expect("sequential ids are unique")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::Phase;

    #[test]
    fn fig7_matches_paper_description() {
        let w = fig7_workload();
        assert_eq!(w.len(), 5);
        let j1 = &w.jobs[0];
        assert_eq!(j1.id, 1);
        assert_eq!(j1.n_reduces(), 11);
        assert!((j1.submit_time - 140.0).abs() < 1e-12);
        assert!((j1.reduce_durations[0] - 500.0).abs() < 1e-12);
        let j2 = w.jobs.iter().find(|j| j.id == 2).unwrap();
        assert_eq!(j2.n_reduces(), 2);
        for id in 3..=5 {
            let j = w.jobs.iter().find(|j| j.id == id).unwrap();
            assert_eq!(j.n_reduces(), 1);
            assert!(j.reduce_durations[0] < 500.0);
        }
    }

    #[test]
    fn decreasing_sizes_decrease() {
        let w = decreasing_size_workload(5, 8, 400.0);
        let sizes: Vec<f64> = w.jobs.iter().map(|j| j.true_phase_size(Phase::Reduce)).collect();
        for pair in sizes.windows(2) {
            assert!(pair[0] > pair[1]);
        }
    }

    #[test]
    fn fig1_sizes() {
        let w = fig1_workload(4, 6);
        // Serialized work = hold-all-slots time x slots.
        assert!((w.jobs[0].true_size() - 120.0).abs() < 1e-9);
        assert!((w.jobs[1].true_size() - 40.0).abs() < 1e-9);
        assert_eq!(w.jobs[0].n_maps(), 24);
    }

    #[test]
    fn fig2_fractions() {
        let w = fig2_workload(20, 1);
        assert_eq!(w.jobs[0].n_maps(), 20);
        assert_eq!(w.jobs[1].n_maps(), 11);
        assert_eq!(w.jobs[2].n_maps(), 7);
    }

    #[test]
    fn uniform_batch_shape() {
        let w = uniform_batch(3, 4, 10.0);
        assert_eq!(w.len(), 3);
        assert!(w.jobs.iter().all(|j| j.n_maps() == 4));
        assert!(w.jobs.iter().all(|j| j.submit_time == 0.0));
    }
}
