//! SWIM-like synthesis of the paper's FB-dataset workload (§4.1).
//!
//! The paper's workload is 100 unique jobs synthesized (with SWIM, Chen et
//! al. MASCOTS'11) from Facebook production traces, clustered as:
//!
//! * **small** — 53 jobs; 75 % have a single MAP task, 25 % have 2;
//! * **medium** — 41 jobs; 5–500 MAP tasks; half have no REDUCE tasks,
//!   the rest have 2–100;
//! * **large** — 6 jobs; 2 with ~3000 MAP tasks and no reduces, 3 with
//!   700–1500 maps and 150–250 reduces, and 1 with 200 maps and 1000
//!   reduces;
//!
//! with exponential inter-arrival times of mean 13 s (≈ 22-minute
//! submission schedule). Jobs are I/O-intensive; task times within a job
//! have no skew (§4.1 "Individual jobs" + §3.2.1: the shipped estimator
//! assumes uniformly distributed task sizes), with residual variability
//! below 5 % (§5).
//!
//! Counts within a class are drawn log-uniformly over the published
//! ranges; per-job mean task durations are log-normal around I/O-bound
//! processing of one 128 MB block (maps) and of a reducer partition
//! (reduces). These are the only free parameters the paper does not pin
//! down; EXPERIMENTS.md records the values used.

use super::Workload;
use crate::job::{JobClass, JobSpec};
use crate::util::rng::{exponential, log_normal, shuffle, weighted_choice, Pcg64, Rng};

/// FB-dataset generator parameters.
#[derive(Clone, Debug)]
pub struct FbWorkload {
    pub n_small: usize,
    pub n_medium: usize,
    pub n_large: usize,
    /// Mean of the exponential inter-arrival distribution, seconds.
    pub mean_interarrival_s: f64,
    /// Median map-task duration, seconds (I/O time of one 128 MB block).
    pub map_task_median_s: f64,
    /// Log-normal sigma of per-job mean map-task duration.
    pub map_task_sigma: f64,
    /// Median reduce-task duration, seconds.
    pub reduce_task_median_s: f64,
    /// Log-normal sigma of per-job mean reduce-task duration.
    pub reduce_task_sigma: f64,
    /// Relative within-job task-time jitter (uniform ±jitter; the paper
    /// reports < 5 % task-time variability on EC2).
    pub task_jitter: f64,
}

impl Default for FbWorkload {
    fn default() -> Self {
        Self {
            n_small: 53,
            n_medium: 41,
            n_large: 6,
            mean_interarrival_s: 13.0,
            map_task_median_s: 45.0,
            map_task_sigma: 0.35,
            reduce_task_median_s: 220.0,
            reduce_task_sigma: 0.45,
            task_jitter: 0.04,
        }
    }
}

impl FbWorkload {
    /// Scale the workload keeping class proportions (utility for stress
    /// experiments beyond the paper's 100 jobs).
    pub fn scaled(factor: f64) -> Self {
        let d = Self::default();
        Self {
            n_small: (d.n_small as f64 * factor).round().max(1.0) as usize,
            n_medium: (d.n_medium as f64 * factor).round().max(1.0) as usize,
            n_large: (d.n_large as f64 * factor).round().max(1.0) as usize,
            ..d
        }
    }

    /// Generate the workload.
    pub fn generate(&self, rng: &mut Pcg64) -> Workload {
        let mut classes = Vec::with_capacity(self.n_small + self.n_medium + self.n_large);
        classes.extend(std::iter::repeat(JobClass::Small).take(self.n_small));
        classes.extend(std::iter::repeat(JobClass::Medium).take(self.n_medium));
        classes.extend(std::iter::repeat(JobClass::Large).take(self.n_large));
        // Interleave classes randomly in the arrival sequence.
        shuffle(rng, &mut classes);

        // Pre-assign the six large-job shapes of §4.1, in random order.
        let mut large_shapes = self.large_shapes(rng);
        shuffle(rng, &mut large_shapes);
        let mut next_large = 0;

        let mut jobs = Vec::with_capacity(classes.len());
        let mut t = 0.0;
        for (i, class) in classes.iter().enumerate() {
            t += exponential(rng, self.mean_interarrival_s);
            let (n_maps, n_reduces) = match class {
                JobClass::Small => Self::sample_small_shape(rng),
                JobClass::Medium => Self::sample_medium_shape(rng),
                JobClass::Large => {
                    let shape = large_shapes[next_large % large_shapes.len()];
                    next_large += 1;
                    shape
                }
            };
            jobs.push(self.make_job(rng, i as u64, *class, t, n_maps, n_reduces));
        }
        Workload::new("fb-dataset", jobs).expect("generator assigns sequential ids")
    }

    /// §4.1 small-job shape: 75 % single map, 25 % two maps; no
    /// reduces. Shared by the closed generator and the open-arrival
    /// sampler ([`crate::workload::JobMix`]).
    pub fn sample_small_shape(rng: &mut Pcg64) -> (usize, usize) {
        (if rng.gen_bool(0.25) { 2 } else { 1 }, 0)
    }

    /// §4.1 medium-job shape: 5–500 maps (log-uniform); half the jobs
    /// have no reduce phase, the rest 2–100 reduces (log-uniform).
    pub fn sample_medium_shape(rng: &mut Pcg64) -> (usize, usize) {
        let maps = log_uniform_usize(rng, 5, 500);
        // Half the medium jobs have no reduce phase.
        let reduces = if rng.gen_bool(0.5) {
            0
        } else {
            log_uniform_usize(rng, 2, 100)
        };
        (maps, reduces)
    }

    /// One of the three §4.1 large archetypes, drawn i.i.d. with the
    /// published 2:3:1 frequencies — the *open-arrival* large sampler.
    /// (The closed generator instead pre-assigns the exact six-shape
    /// multiset via [`FbWorkload::generate`]'s `large_shapes`.)
    pub fn sample_large_archetype(rng: &mut Pcg64) -> (usize, usize) {
        match weighted_choice(rng, &[2.0, 3.0, 1.0]) {
            0 => (2800 + rng.gen_index(400), 0),
            1 => (700 + rng.gen_index(801), 150 + rng.gen_index(101)),
            _ => (200, 1000),
        }
    }

    /// The six large-job shapes from §4.1.
    fn large_shapes(&self, rng: &mut Pcg64) -> Vec<(usize, usize)> {
        let mut shapes = Vec::with_capacity(6);
        // 2 jobs with about 3000 map tasks, no reduces.
        for _ in 0..2 {
            shapes.push((2800 + rng.gen_index(400), 0));
        }
        // 3 jobs with 700–1500 maps and 150–250 reduces.
        for _ in 0..3 {
            shapes.push((
                700 + rng.gen_index(801),
                150 + rng.gen_index(101),
            ));
        }
        // 1 job with 200 maps and 1000 reduces.
        shapes.push((200, 1000));
        shapes
    }

    /// Materialize one job: per-job mean task durations drawn from the
    /// configured log-normals, with sub-5 % within-job jitter (§4.1).
    /// Shared by the closed generator and the open-arrival sampler.
    pub fn make_job(
        &self,
        rng: &mut Pcg64,
        id: u64,
        class: JobClass,
        submit: f64,
        n_maps: usize,
        n_reduces: usize,
    ) -> JobSpec {
        // Per-job mean task durations; no within-job skew (§4.1), just
        // sub-5% jitter.
        let map_mu = self.map_task_median_s.ln();
        let red_mu = self.reduce_task_median_s.ln();
        let job_map_mean = log_normal(rng, map_mu, self.map_task_sigma);
        let job_red_mean = log_normal(rng, red_mu, self.reduce_task_sigma);
        let jitter = |rng: &mut Pcg64, mean: f64| {
            mean * (1.0 + rng.gen_range_f64(-self.task_jitter, self.task_jitter))
        };
        let map_durations = (0..n_maps).map(|_| jitter(rng, job_map_mean)).collect();
        let reduce_durations = (0..n_reduces).map(|_| jitter(rng, job_red_mean)).collect();
        JobSpec {
            id,
            name: format!("fb-{}-{id}", class.name()),
            class,
            tenant: crate::job::TenantId::default(),
            submit_time: submit,
            map_durations,
            reduce_durations,
        }
    }
}

/// Integer drawn log-uniformly from `[lo, hi]` — heavy toward small values,
/// matching the long-tailed job-size mix of production traces.
fn log_uniform_usize(rng: &mut Pcg64, lo: usize, hi: usize) -> usize {
    debug_assert!(lo >= 1 && hi >= lo);
    let x = rng.gen_range_f64((lo as f64).ln(), (hi as f64 + 1.0).ln());
    (x.exp().floor() as usize).clamp(lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SeedableRng;

    fn gen(seed: u64) -> Workload {
        FbWorkload::default().generate(&mut Pcg64::seed_from_u64(seed))
    }

    #[test]
    fn class_counts_match_paper() {
        let w = gen(1);
        assert_eq!(w.len(), 100);
        let count = |c: JobClass| w.jobs.iter().filter(|j| j.class == c).count();
        assert_eq!(count(JobClass::Small), 53);
        assert_eq!(count(JobClass::Medium), 41);
        assert_eq!(count(JobClass::Large), 6);
    }

    #[test]
    fn small_jobs_have_one_or_two_maps() {
        let w = gen(2);
        for j in w.jobs.iter().filter(|j| j.class == JobClass::Small) {
            assert!(j.n_maps() == 1 || j.n_maps() == 2, "got {}", j.n_maps());
            assert_eq!(j.n_reduces(), 0);
        }
    }

    #[test]
    fn medium_jobs_in_range() {
        let w = gen(3);
        for j in w.jobs.iter().filter(|j| j.class == JobClass::Medium) {
            assert!((5..=500).contains(&j.n_maps()));
            assert!(j.n_reduces() == 0 || (2..=100).contains(&j.n_reduces()));
        }
    }

    #[test]
    fn large_shapes_present() {
        let w = gen(4);
        let large: Vec<_> = w.jobs.iter().filter(|j| j.class == JobClass::Large).collect();
        assert_eq!(large.len(), 6);
        let huge_maps = large
            .iter()
            .filter(|j| j.n_maps() >= 2800 && j.n_reduces() == 0)
            .count();
        assert_eq!(huge_maps, 2, "two ~3000-map jobs");
        let mid = large
            .iter()
            .filter(|j| (700..=1500).contains(&j.n_maps()) && (150..=250).contains(&j.n_reduces()))
            .count();
        assert_eq!(mid, 3);
        let reducer_heavy = large
            .iter()
            .filter(|j| j.n_maps() == 200 && j.n_reduces() == 1000)
            .count();
        assert_eq!(reducer_heavy, 1);
    }

    #[test]
    fn interarrival_mean_is_about_13s() {
        // Average the span over several seeds: 100 jobs * 13 s ≈ 1300 s.
        let mut spans = 0.0;
        for seed in 0..10 {
            spans += gen(seed).span();
        }
        let mean_span = spans / 10.0;
        assert!(
            (mean_span - 13.0 * 99.0).abs() < 250.0,
            "mean span {mean_span}"
        );
    }

    #[test]
    fn task_times_have_low_within_job_skew() {
        let w = gen(5);
        for j in &w.jobs {
            if j.n_maps() >= 2 {
                let mean = j.true_phase_size(crate::job::Phase::Map) / j.n_maps() as f64;
                for &d in &j.map_durations {
                    assert!(
                        (d - mean).abs() / mean < 0.1,
                        "within-job skew too high: {d} vs mean {mean}"
                    );
                }
            }
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let a = gen(42);
        let b = gen(42);
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.submit_time, y.submit_time);
            assert_eq!(x.map_durations, y.map_durations);
        }
    }

    #[test]
    fn total_tasks_matches_paper_scale() {
        // The paper reports >14,000 map tasks across experiments; one
        // workload instance lands in the same ballpark.
        let mut totals = 0usize;
        for seed in 0..5 {
            totals += gen(seed).total_tasks();
        }
        let mean = totals / 5;
        assert!(
            (9_000..30_000).contains(&mean),
            "mean total tasks {mean} out of expected ballpark"
        );
    }

    #[test]
    fn log_uniform_respects_bounds() {
        let mut rng = Pcg64::seed_from_u64(9);
        for _ in 0..10_000 {
            let x = log_uniform_usize(&mut rng, 5, 500);
            assert!((5..=500).contains(&x));
        }
    }

    #[test]
    fn scaled_keeps_proportions() {
        let half = FbWorkload::scaled(0.5);
        assert_eq!(half.n_small, 27);
        assert_eq!(half.n_medium, 21);
        assert_eq!(half.n_large, 3);
    }
}
