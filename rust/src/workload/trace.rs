//! Replayable workload traces (JSON-lines).
//!
//! One job per line:
//!
//! ```json
//! {"id": 3, "name": "fb-medium-3", "class": "medium", "submit": 41.2,
//!  "maps": [24.8, 25.1], "reduces": []}
//! ```
//!
//! Traces make experiments portable: `hfsp workload-gen` emits one, and
//! `hfsp simulate --trace <file>` replays it under any scheduler, so a
//! FAIR run and an HFSP run see the *identical* job sequence (as in the
//! paper's macro benchmarks).

use super::Workload;
use crate::job::{JobClass, JobSpec};
use crate::util::json::{self, Json};
use std::io::{BufRead, Write};
use std::path::Path;

fn class_name(c: JobClass) -> &'static str {
    c.name()
}

fn class_from_name(s: &str) -> anyhow::Result<JobClass> {
    match s {
        "small" => Ok(JobClass::Small),
        "medium" => Ok(JobClass::Medium),
        "large" => Ok(JobClass::Large),
        other => anyhow::bail!("unknown job class {other:?}"),
    }
}

/// Encode one job as a JSON object.
pub fn job_to_json(job: &JobSpec) -> Json {
    let mut o = Json::obj();
    o.set("id", job.id.into());
    o.set("name", job.name.as_str().into());
    o.set("class", class_name(job.class).into());
    o.set("submit", job.submit_time.into());
    o.set("maps", job.map_durations.clone().into());
    o.set("reduces", job.reduce_durations.clone().into());
    o
}

/// Decode one job from a JSON object.
pub fn job_from_json(v: &Json) -> anyhow::Result<JobSpec> {
    let get = |key: &str| {
        v.get(key)
            .ok_or_else(|| anyhow::anyhow!("trace job missing field {key:?}"))
    };
    let durations = |key: &str| -> anyhow::Result<Vec<f64>> {
        get(key)?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("field {key:?} must be an array"))?
            .iter()
            .map(|x| {
                x.as_f64()
                    .filter(|d| *d > 0.0)
                    .ok_or_else(|| anyhow::anyhow!("task duration must be a positive number"))
            })
            .collect()
    };
    Ok(JobSpec {
        id: get("id")?
            .as_u64()
            .ok_or_else(|| anyhow::anyhow!("id must be a non-negative integer"))?,
        name: get("name")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("name must be a string"))?
            .to_string(),
        class: class_from_name(
            get("class")?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("class must be a string"))?,
        )?,
        submit_time: get("submit")?
            .as_f64()
            .filter(|t| *t >= 0.0)
            .ok_or_else(|| anyhow::anyhow!("submit must be a non-negative number"))?,
        map_durations: durations("maps")?,
        reduce_durations: durations("reduces")?,
    })
}

/// Serialize a workload to JSONL text.
pub fn to_jsonl(workload: &Workload) -> String {
    let mut s = String::new();
    for job in &workload.jobs {
        s.push_str(&job_to_json(job).to_string_compact());
        s.push('\n');
    }
    s
}

/// Parse a workload from JSONL text.
pub fn from_jsonl(name: &str, text: &str) -> anyhow::Result<Workload> {
    let mut jobs = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let v = json::parse(line)
            .map_err(|e| anyhow::anyhow!("trace line {}: {e}", lineno + 1))?;
        jobs.push(
            job_from_json(&v).map_err(|e| anyhow::anyhow!("trace line {}: {e}", lineno + 1))?,
        );
    }
    anyhow::ensure!(!jobs.is_empty(), "trace contains no jobs");
    Ok(Workload::new(name, jobs))
}

/// Write a trace file.
pub fn write_trace(workload: &Workload, path: &Path) -> anyhow::Result<()> {
    let mut f = std::fs::File::create(path)
        .map_err(|e| anyhow::anyhow!("cannot create trace {path:?}: {e}"))?;
    f.write_all(to_jsonl(workload).as_bytes())?;
    Ok(())
}

/// Read a trace file.
pub fn read_trace(path: &Path) -> anyhow::Result<Workload> {
    let file = std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("cannot open trace {path:?}: {e}"))?;
    let reader = std::io::BufReader::new(file);
    let mut text = String::new();
    for line in reader.lines() {
        text.push_str(&line?);
        text.push('\n');
    }
    let name = path
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("trace")
        .to_string();
    from_jsonl(&name, &text)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{Pcg64, SeedableRng};
    use crate::workload::swim::FbWorkload;

    #[test]
    fn roundtrip_preserves_jobs() {
        let w = FbWorkload::default().generate(&mut Pcg64::seed_from_u64(17));
        let text = to_jsonl(&w);
        let w2 = from_jsonl("fb-dataset", &text).unwrap();
        assert_eq!(w.len(), w2.len());
        for (a, b) in w.jobs.iter().zip(&w2.jobs) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.class, b.class);
            assert_eq!(a.name, b.name);
            assert!((a.submit_time - b.submit_time).abs() < 1e-9);
            assert_eq!(a.map_durations.len(), b.map_durations.len());
            for (x, y) in a.map_durations.iter().zip(&b.map_durations) {
                assert!((x - y).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(from_jsonl("t", "not json\n").is_err());
        assert!(from_jsonl("t", "{}\n").is_err());
        assert!(from_jsonl("t", "").is_err());
        // Negative duration.
        let bad = r#"{"id":1,"name":"x","class":"small","submit":0,"maps":[-5],"reduces":[]}"#;
        assert!(from_jsonl("t", bad).is_err());
        // Unknown class.
        let bad = r#"{"id":1,"name":"x","class":"huge","submit":0,"maps":[5],"reduces":[]}"#;
        assert!(from_jsonl("t", bad).is_err());
    }

    #[test]
    fn skips_blank_lines() {
        let line = r#"{"id":1,"name":"x","class":"small","submit":0,"maps":[5],"reduces":[]}"#;
        let w = from_jsonl("t", &format!("\n{line}\n\n")).unwrap();
        assert_eq!(w.len(), 1);
    }

    #[test]
    fn file_roundtrip() {
        let w = crate::workload::synthetic::fig7_workload();
        let dir = std::env::temp_dir().join("hfsp-trace-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fig7.jsonl");
        write_trace(&w, &path).unwrap();
        let w2 = read_trace(&path).unwrap();
        assert_eq!(w2.len(), 5);
        assert_eq!(w2.name, "fig7");
        std::fs::remove_file(&path).ok();
    }
}
