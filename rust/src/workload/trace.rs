//! Replayable workload traces (JSON-lines).
//!
//! One job per line:
//!
//! ```json
//! {"id": 3, "name": "fb-medium-3", "class": "medium", "submit": 41.2,
//!  "maps": [24.8, 25.1], "reduces": []}
//! ```
//!
//! Traces make experiments portable: `hfsp workload-gen` emits one, and
//! `hfsp simulate --trace <file>` replays it under any scheduler, so a
//! FAIR run and an HFSP run see the *identical* job sequence (as in the
//! paper's macro benchmarks).
//!
//! Two replay paths exist: [`read_trace`] materializes the whole file
//! into a [`Workload`] (validating ids up front), while [`TraceSource`]
//! streams it line by line as a
//! [`WorkloadSource`](crate::workload::WorkloadSource) — constant
//! memory regardless of trace length, for million-job replays.

use super::source::WorkloadSource;
use super::Workload;
use crate::job::{JobClass, JobSpec, TenantId};
use crate::util::json::{self, Json};
use crate::util::rng::Pcg64;
use std::io::{BufRead, Write};
use std::path::Path;

fn class_name(c: JobClass) -> &'static str {
    c.name()
}

fn class_from_name(s: &str) -> anyhow::Result<JobClass> {
    match s {
        "small" => Ok(JobClass::Small),
        "medium" => Ok(JobClass::Medium),
        "large" => Ok(JobClass::Large),
        other => anyhow::bail!("unknown job class {other:?}"),
    }
}

/// Encode one job as a JSON object.
pub fn job_to_json(job: &JobSpec) -> Json {
    let mut o = Json::obj();
    o.set("id", job.id.into());
    o.set("name", job.name.as_str().into());
    o.set("class", class_name(job.class).into());
    o.set("submit", job.submit_time.into());
    o.set("maps", job.map_durations.clone().into());
    o.set("reduces", job.reduce_durations.clone().into());
    // Tenant keys are only emitted for multi-tenant jobs, so every
    // pre-hierarchy trace (and its golden bytes) is unchanged.
    if !job.tenant.is_default() {
        o.set("pool", u64::from(job.tenant.pool).into());
        o.set("user", u64::from(job.tenant.user).into());
    }
    o
}

/// Decode the optional tenant keys (absent = the single-tenant default).
fn tenant_from_json(v: &Json) -> anyhow::Result<TenantId> {
    let field = |key: &str| -> anyhow::Result<u32> {
        match v.get(key) {
            None => Ok(0),
            Some(x) => x
                .as_u64()
                .and_then(|n| u32::try_from(n).ok())
                .ok_or_else(|| anyhow::anyhow!("field {key:?} must be a u32")),
        }
    };
    Ok(TenantId::new(field("pool")?, field("user")?))
}

/// Decode one job from a JSON object.
pub fn job_from_json(v: &Json) -> anyhow::Result<JobSpec> {
    let get = |key: &str| {
        v.get(key)
            .ok_or_else(|| anyhow::anyhow!("trace job missing field {key:?}"))
    };
    let durations = |key: &str| -> anyhow::Result<Vec<f64>> {
        get(key)?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("field {key:?} must be an array"))?
            .iter()
            .map(|x| {
                x.as_f64()
                    .filter(|d| *d > 0.0)
                    .ok_or_else(|| anyhow::anyhow!("task duration must be a positive number"))
            })
            .collect()
    };
    Ok(JobSpec {
        id: get("id")?
            .as_u64()
            .ok_or_else(|| anyhow::anyhow!("id must be a non-negative integer"))?,
        name: get("name")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("name must be a string"))?
            .to_string(),
        class: class_from_name(
            get("class")?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("class must be a string"))?,
        )?,
        tenant: tenant_from_json(v)?,
        submit_time: get("submit")?
            .as_f64()
            .filter(|t| *t >= 0.0)
            .ok_or_else(|| anyhow::anyhow!("submit must be a non-negative number"))?,
        map_durations: durations("maps")?,
        reduce_durations: durations("reduces")?,
    })
}

/// Serialize a workload to JSONL text.
pub fn to_jsonl(workload: &Workload) -> String {
    let mut s = String::new();
    for job in &workload.jobs {
        s.push_str(&job_to_json(job).to_string_compact());
        s.push('\n');
    }
    s
}

/// Parse a workload from JSONL text.
pub fn from_jsonl(name: &str, text: &str) -> anyhow::Result<Workload> {
    let mut jobs = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let v = json::parse(line)
            .map_err(|e| anyhow::anyhow!("trace line {}: {e}", lineno + 1))?;
        jobs.push(
            job_from_json(&v).map_err(|e| anyhow::anyhow!("trace line {}: {e}", lineno + 1))?,
        );
    }
    anyhow::ensure!(!jobs.is_empty(), "trace contains no jobs");
    Workload::new(name, jobs)
}

/// Write a trace file.
pub fn write_trace(workload: &Workload, path: &Path) -> anyhow::Result<()> {
    let mut f = std::fs::File::create(path)
        .map_err(|e| anyhow::anyhow!("cannot create trace {path:?}: {e}"))?;
    f.write_all(to_jsonl(workload).as_bytes())?;
    Ok(())
}

/// Read a trace file.
pub fn read_trace(path: &Path) -> anyhow::Result<Workload> {
    let file = std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("cannot open trace {path:?}: {e}"))?;
    let reader = std::io::BufReader::new(file);
    let mut text = String::new();
    for line in reader.lines() {
        text.push_str(&line?);
        text.push('\n');
    }
    let name = path
        .file_stem()
        .and_then(|s| s.to_str())
        .unwrap_or("trace")
        .to_string();
    from_jsonl(&name, &text)
}

/// Streaming JSONL trace replay: a [`WorkloadSource`] that parses one
/// line per pulled job, holding O(1) trace state regardless of length.
///
/// The trace must be sorted by submission time (which [`write_trace`]
/// guarantees) and carry unique job ids — unlike [`read_trace`], the
/// streaming path cannot validate ids without O(jobs) memory, so it
/// trusts the file. A malformed or out-of-order line ends the stream
/// early and parks the error for [`WorkloadSource::take_error`], which
/// the driver polls at exhaustion and surfaces as
/// `SimOutcome::stream_error` (a hard error in the CLI).
pub struct TraceSource {
    name: String,
    lines: std::io::Lines<std::io::BufReader<std::fs::File>>,
    lineno: usize,
    last_submit: f64,
    yielded: usize,
    error: Option<anyhow::Error>,
}

impl TraceSource {
    /// Open a trace file for streaming replay.
    pub fn open(path: &Path) -> anyhow::Result<Self> {
        let file = std::fs::File::open(path)
            .map_err(|e| anyhow::anyhow!("cannot open trace {path:?}: {e}"))?;
        let name = path
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("trace")
            .to_string();
        Ok(Self {
            name,
            lines: std::io::BufReader::new(file).lines(),
            lineno: 0,
            last_submit: 0.0,
            yielded: 0,
            error: None,
        })
    }

    /// Jobs yielded so far.
    pub fn yielded(&self) -> usize {
        self.yielded
    }

    fn fail(&mut self, err: anyhow::Error) -> Option<JobSpec> {
        log::error!("trace {:?} line {}: {err:#}", self.name, self.lineno);
        self.error = Some(anyhow::anyhow!("trace line {}: {err:#}", self.lineno));
        None
    }
}

impl WorkloadSource for TraceSource {
    fn name(&self) -> &str {
        &self.name
    }

    /// The parked parse/order error, if the stream was truncated.
    fn take_error(&mut self) -> Option<String> {
        self.error.take().map(|e| format!("{e:#}"))
    }

    fn next_job(&mut self, _rng: &mut Pcg64) -> Option<JobSpec> {
        if self.error.is_some() {
            return None;
        }
        loop {
            self.lineno += 1;
            let line = match self.lines.next()? {
                Ok(line) => line,
                Err(e) => return self.fail(anyhow::anyhow!("read error: {e}")),
            };
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let v = match json::parse(line) {
                Ok(v) => v,
                Err(e) => return self.fail(anyhow::anyhow!("{e}")),
            };
            let job = match job_from_json(&v) {
                Ok(job) => job,
                Err(e) => return self.fail(e),
            };
            if job.submit_time < self.last_submit {
                return self.fail(anyhow::anyhow!(
                    "jobs out of order: submit {} after {} — streaming replay \
                     requires a submission-sorted trace",
                    job.submit_time,
                    self.last_submit
                ));
            }
            self.last_submit = job.submit_time;
            self.yielded += 1;
            return Some(job);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{Pcg64, SeedableRng};
    use crate::workload::swim::FbWorkload;

    #[test]
    fn roundtrip_preserves_jobs() {
        let w = FbWorkload::default().generate(&mut Pcg64::seed_from_u64(17));
        let text = to_jsonl(&w);
        let w2 = from_jsonl("fb-dataset", &text).unwrap();
        assert_eq!(w.len(), w2.len());
        for (a, b) in w.jobs.iter().zip(&w2.jobs) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.class, b.class);
            assert_eq!(a.name, b.name);
            assert!((a.submit_time - b.submit_time).abs() < 1e-9);
            assert_eq!(a.map_durations.len(), b.map_durations.len());
            for (x, y) in a.map_durations.iter().zip(&b.map_durations) {
                assert!((x - y).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(from_jsonl("t", "not json\n").is_err());
        assert!(from_jsonl("t", "{}\n").is_err());
        assert!(from_jsonl("t", "").is_err());
        // Negative duration.
        let bad = r#"{"id":1,"name":"x","class":"small","submit":0,"maps":[-5],"reduces":[]}"#;
        assert!(from_jsonl("t", bad).is_err());
        // Unknown class.
        let bad = r#"{"id":1,"name":"x","class":"huge","submit":0,"maps":[5],"reduces":[]}"#;
        assert!(from_jsonl("t", bad).is_err());
    }

    #[test]
    fn tenant_keys_roundtrip_and_default_is_omitted() {
        let mut j = crate::workload::synthetic::fig7_workload().jobs[0].clone();
        let plain = job_to_json(&j).to_string_compact();
        assert!(!plain.contains("pool"), "default tenant emits no keys: {plain}");
        j.tenant = TenantId::new(3, 71);
        let v = json::parse(&job_to_json(&j).to_string_compact()).unwrap();
        let back = job_from_json(&v).unwrap();
        assert_eq!(back.tenant, TenantId::new(3, 71));
        // Malformed tenant values are hard errors.
        let bad = r#"{"id":1,"name":"x","class":"small","submit":0,"maps":[5],"reduces":[],"pool":-3}"#;
        assert!(from_jsonl("t", bad).is_err());
    }

    #[test]
    fn skips_blank_lines() {
        let line = r#"{"id":1,"name":"x","class":"small","submit":0,"maps":[5],"reduces":[]}"#;
        let w = from_jsonl("t", &format!("\n{line}\n\n")).unwrap();
        assert_eq!(w.len(), 1);
    }

    #[test]
    fn file_roundtrip() {
        let w = crate::workload::synthetic::fig7_workload();
        let dir = std::env::temp_dir().join("hfsp-trace-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fig7.jsonl");
        write_trace(&w, &path).unwrap();
        let w2 = read_trace(&path).unwrap();
        assert_eq!(w2.len(), 5);
        assert_eq!(w2.name, "fig7");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_duplicate_ids_as_error_not_panic() {
        let line = r#"{"id":1,"name":"x","class":"small","submit":0,"maps":[5],"reduces":[]}"#;
        let err = from_jsonl("t", &format!("{line}\n{line}\n")).unwrap_err();
        assert!(err.to_string().contains("duplicate job id"), "{err}");
    }

    #[test]
    fn trace_source_streams_the_same_jobs_as_read_trace() {
        let w = FbWorkload {
            n_small: 6,
            n_medium: 3,
            n_large: 1,
            ..Default::default()
        }
        .generate(&mut Pcg64::seed_from_u64(5));
        let dir = std::env::temp_dir().join("hfsp-trace-stream-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("stream.jsonl");
        write_trace(&w, &path).unwrap();

        let mut src = TraceSource::open(&path).unwrap();
        assert_eq!(src.name(), "stream");
        let mut rng = Pcg64::seed_from_u64(0);
        let mut streamed = Vec::new();
        while let Some(job) = src.next_job(&mut rng) {
            streamed.push(job);
        }
        assert!(src.take_error().is_none());
        assert_eq!(src.yielded(), w.len());
        assert_eq!(streamed.len(), w.len());
        for (a, b) in w.jobs.iter().zip(&streamed) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.class, b.class);
            assert_eq!(a.map_durations.len(), b.map_durations.len());
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn trace_source_parks_errors_and_ends_the_stream() {
        let dir = std::env::temp_dir().join("hfsp-trace-stream-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.jsonl");
        let good = r#"{"id":1,"name":"x","class":"small","submit":0,"maps":[5],"reduces":[]}"#;
        std::fs::write(&path, format!("{good}\nnot json\n{good}\n")).unwrap();
        let mut src = TraceSource::open(&path).unwrap();
        let mut rng = Pcg64::seed_from_u64(0);
        assert!(src.next_job(&mut rng).is_some());
        assert!(src.next_job(&mut rng).is_none(), "bad line ends the stream");
        assert!(src.next_job(&mut rng).is_none(), "stream stays ended");
        let err = src.take_error().expect("error parked");
        assert!(err.to_string().contains("line 2"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn trace_source_rejects_out_of_order_arrivals() {
        let dir = std::env::temp_dir().join("hfsp-trace-stream-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("unsorted.jsonl");
        let a = r#"{"id":1,"name":"x","class":"small","submit":10,"maps":[5],"reduces":[]}"#;
        let b = r#"{"id":2,"name":"y","class":"small","submit":3,"maps":[5],"reduces":[]}"#;
        std::fs::write(&path, format!("{a}\n{b}\n")).unwrap();
        let mut src = TraceSource::open(&path).unwrap();
        let mut rng = Pcg64::seed_from_u64(0);
        assert!(src.next_job(&mut rng).is_some());
        assert!(src.next_job(&mut rng).is_none());
        let err = src.take_error().expect("error parked");
        assert!(err.to_string().contains("out of order"), "{err}");
        std::fs::remove_file(&path).ok();
    }
}
