//! Open arrival-process workload generation.
//!
//! The paper's evaluation (and the FB-dataset) is *closed*: a fixed job
//! list with recorded submission times. The size-based scheduling
//! literature it builds on — Dell'Amico et al.'s simulator study and
//! PSBS — instead evaluates disciplines under **open, rate-controlled
//! arrivals**: jobs arrive as a Poisson process of configurable
//! intensity and the metric of interest is steady-state behaviour as a
//! function of load. [`OpenArrivals`] supplies that scenario axis as a
//! [`WorkloadSource`]: jobs are *generated on pull*, one at a time, so
//! a 10⁶-job run never holds more than the active jobs in memory.
//!
//! Intensity can be constant or diurnally modulated (a sinusoid around
//! the base rate, sampled by Lewis–Shedler thinning), which reproduces
//! the day/night load swings of production traces.
//!
//! Job *shapes* are drawn by a [`JobMix`]: either the §4.1 FB class mix
//! (small/medium/large with the published shape statistics, reusing the
//! [`FbWorkload`] duration parameters) or a fixed uniform shape for
//! micro-benchmarks.

use super::source::WorkloadSource;
use super::swim::FbWorkload;
use crate::job::{JobClass, JobSpec, TenantId};
use crate::util::rng::{exponential, weighted_choice, Pcg64, Rng};

/// Per-job shape sampler for open generators.
#[derive(Clone, Debug)]
pub enum JobMix {
    /// The §4.1 FB-dataset class mix (53/41/6 small/medium/large), with
    /// shapes and durations drawn by the same rules as
    /// [`FbWorkload::generate`].
    Fb(FbWorkload),
    /// Identical map-only jobs (micro-benchmarks, bounded-memory smoke
    /// tests): `maps` tasks of `task_s` seconds each.
    Uniform { maps: usize, task_s: f64 },
}

impl JobMix {
    /// The default FB mix.
    pub fn fb() -> Self {
        JobMix::Fb(FbWorkload::default())
    }

    /// Mean serialized work per job, seconds — used to express a rate
    /// as a load factor. For the FB mix this is a coarse analytic
    /// estimate of the class-weighted mean (log-uniform map counts,
    /// log-normal task durations).
    pub fn mean_job_size_s(&self) -> f64 {
        match self {
            JobMix::Uniform { maps, task_s } => *maps as f64 * task_s,
            JobMix::Fb(p) => {
                let n = (p.n_small + p.n_medium + p.n_large) as f64;
                let mean_map = p.map_task_median_s * (p.map_task_sigma.powi(2) / 2.0).exp();
                let mean_red = p.reduce_task_median_s * (p.reduce_task_sigma.powi(2) / 2.0).exp();
                // Log-uniform mean counts: (hi - lo) / ln(hi / lo).
                let lu = |lo: f64, hi: f64| (hi - lo) / (hi / lo).ln();
                let small = 1.25 * mean_map;
                let medium = lu(5.0, 500.0) * mean_map + 0.5 * lu(2.0, 100.0) * mean_red;
                let large = (2.0 * 3000.0 * mean_map
                    + 3.0 * (1100.0 * mean_map + 200.0 * mean_red)
                    + (200.0 * mean_map + 1000.0 * mean_red))
                    / 6.0;
                (p.n_small as f64 * small + p.n_medium as f64 * medium + p.n_large as f64 * large)
                    / n
            }
        }
    }

    /// Draw one job spec.
    pub fn sample(&self, rng: &mut Pcg64, id: u64, submit: f64) -> JobSpec {
        match self {
            JobMix::Uniform { maps, task_s } => JobSpec {
                id,
                name: format!("open-uni-{id}"),
                class: JobClass::Small,
                tenant: TenantId::default(),
                submit_time: submit,
                map_durations: vec![*task_s; *maps],
                reduce_durations: vec![],
            },
            // Class drawn by the configured frequencies; shapes and
            // durations come from the shared §4.1 samplers in
            // [`FbWorkload`] — one implementation for the closed
            // generator and this open path.
            JobMix::Fb(p) => {
                let class = match weighted_choice(
                    rng,
                    &[p.n_small as f64, p.n_medium as f64, p.n_large as f64],
                ) {
                    0 => JobClass::Small,
                    1 => JobClass::Medium,
                    _ => JobClass::Large,
                };
                let (n_maps, n_reduces) = match class {
                    JobClass::Small => FbWorkload::sample_small_shape(rng),
                    JobClass::Medium => FbWorkload::sample_medium_shape(rng),
                    JobClass::Large => FbWorkload::sample_large_archetype(rng),
                };
                p.make_job(rng, id, class, submit, n_maps, n_reduces)
            }
        }
    }
}

/// Poisson (optionally diurnally modulated) open arrival generator.
///
/// Jobs arrive at mean rate [`rate`](OpenArrivals::rate) until the
/// submission horizon or the job cap is reached; shapes come from the
/// [`JobMix`]. The struct is a *template*: cloning it yields a fresh
/// generator positioned at t = 0, which is how the sweep engine gives
/// every cell its own stream.
#[derive(Clone, Debug)]
pub struct OpenArrivals {
    name: String,
    /// Mean arrival rate, jobs per simulated second.
    pub rate: f64,
    /// Stop submitting after this simulated time (the cluster then
    /// drains). `f64::INFINITY` leaves only the job cap.
    pub horizon_s: f64,
    /// Hard cap on submitted jobs (`u64::MAX` = uncapped).
    pub max_jobs: u64,
    /// Shape sampler.
    pub mix: JobMix,
    /// Relative amplitude of the diurnal rate modulation in `[0, 1]`;
    /// 0 = homogeneous Poisson.
    pub diurnal_amplitude: f64,
    /// Period of the modulation, seconds (default 24 h).
    pub diurnal_period_s: f64,
    clock: f64,
    emitted: u64,
}

impl OpenArrivals {
    /// Homogeneous Poisson arrivals of the FB job mix at `rate` jobs/s
    /// until `horizon_s`.
    pub fn poisson(rate: f64, horizon_s: f64) -> Self {
        assert!(rate > 0.0, "arrival rate must be positive");
        Self {
            name: format!("open-r{rate}"),
            rate,
            horizon_s,
            max_jobs: u64::MAX,
            mix: JobMix::fb(),
            diurnal_amplitude: 0.0,
            diurnal_period_s: 24.0 * 3600.0,
            clock: 0.0,
            emitted: 0,
        }
    }

    /// Replace the job mix (builder style).
    pub fn mix(mut self, mix: JobMix) -> Self {
        self.mix = mix;
        self
    }

    /// Cap the number of submitted jobs (builder style).
    pub fn max_jobs(mut self, max: u64) -> Self {
        self.max_jobs = max;
        self
    }

    /// Enable diurnal rate modulation (builder style). `amplitude` is
    /// clamped into `[0, 1]`.
    pub fn diurnal(mut self, amplitude: f64, period_s: f64) -> Self {
        assert!(period_s > 0.0, "diurnal period must be positive");
        self.diurnal_amplitude = amplitude.clamp(0.0, 1.0);
        self.diurnal_period_s = period_s;
        self.name = format!("{}-diurnal", self.name);
        self
    }

    /// Override the display name (sweep labels).
    pub fn named(mut self, name: impl Into<String>) -> Self {
        self.name = name.into();
        self
    }

    /// Jobs emitted so far.
    pub fn emitted(&self) -> u64 {
        self.emitted
    }

    /// The offered load factor on a cluster with `slots` task slots:
    /// `rate · E[job size] / slots`. Values ≥ 1 mean the queue grows
    /// without bound until the horizon.
    pub fn load_factor(&self, slots: usize) -> f64 {
        self.rate * self.mix.mean_job_size_s() / slots.max(1) as f64
    }

    /// Whether the stream terminates on its own: a finite submission
    /// horizon or a job cap. An unbounded generator is only usable
    /// under an external stop (a halting [`Probe`]); contexts without
    /// one — the sweep engine, [`WorkloadSpec::realize`] — must reject
    /// it up front instead of hanging.
    ///
    /// [`Probe`]: crate::metrics::Probe
    /// [`WorkloadSpec::realize`]: crate::sweep::grid::WorkloadSpec::realize
    pub fn is_bounded(&self) -> bool {
        self.horizon_s.is_finite() || self.max_jobs < u64::MAX
    }
}

impl WorkloadSource for OpenArrivals {
    fn name(&self) -> &str {
        &self.name
    }

    fn next_job(&mut self, rng: &mut Pcg64) -> Option<JobSpec> {
        if self.emitted >= self.max_jobs {
            return None;
        }
        // Lewis–Shedler thinning against the peak rate; with zero
        // amplitude every proposal is accepted (plain inversion).
        let peak = self.rate * (1.0 + self.diurnal_amplitude);
        loop {
            self.clock += exponential(rng, 1.0 / peak);
            if self.clock > self.horizon_s {
                return None;
            }
            if self.diurnal_amplitude == 0.0 {
                break;
            }
            let phase = std::f64::consts::TAU * self.clock / self.diurnal_period_s;
            let lambda = self.rate * (1.0 + self.diurnal_amplitude * phase.sin());
            if rng.next_f64() * peak < lambda {
                break;
            }
        }
        let id = self.emitted;
        self.emitted += 1;
        Some(self.mix.sample(rng, id, self.clock))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SeedableRng;

    fn drain(src: &mut OpenArrivals, seed: u64) -> Vec<JobSpec> {
        let mut rng = Pcg64::seed_from_u64(seed);
        std::iter::from_fn(|| src.next_job(&mut rng)).collect()
    }

    #[test]
    fn arrivals_are_ordered_unique_and_rate_controlled() {
        let mut src = OpenArrivals::poisson(2.0, 5_000.0).mix(JobMix::Uniform {
            maps: 1,
            task_s: 1.0,
        });
        let jobs = drain(&mut src, 7);
        let n = jobs.len() as f64;
        assert!(
            (n - 10_000.0).abs() < 500.0,
            "≈ rate × horizon arrivals, got {n}"
        );
        let mut last = 0.0;
        for (i, j) in jobs.iter().enumerate() {
            assert_eq!(j.id, i as u64, "dense unique ids");
            assert!(j.submit_time >= last);
            assert!(j.submit_time <= 5_000.0);
            last = j.submit_time;
        }
    }

    #[test]
    fn generation_is_deterministic_per_seed() {
        let tpl = OpenArrivals::poisson(1.0, 500.0);
        let a = drain(&mut tpl.clone(), 42);
        let b = drain(&mut tpl.clone(), 42);
        let c = drain(&mut tpl.clone(), 43);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.submit_time, y.submit_time);
            assert_eq!(x.map_durations, y.map_durations);
        }
        assert!(
            a.iter()
                .zip(&c)
                .any(|(x, y)| x.submit_time.total_cmp(&y.submit_time).is_ne()),
            "different seeds differ"
        );
    }

    #[test]
    fn max_jobs_caps_the_stream() {
        let mut src = OpenArrivals::poisson(10.0, f64::INFINITY).max_jobs(100);
        let jobs = drain(&mut src, 1);
        assert_eq!(jobs.len(), 100);
        assert_eq!(src.emitted(), 100);
    }

    #[test]
    fn fb_mix_respects_class_shapes() {
        let mut src = OpenArrivals::poisson(5.0, 1_000.0);
        let jobs = drain(&mut src, 3);
        assert!(jobs.len() > 1_000, "enough samples");
        for j in &jobs {
            match j.class {
                JobClass::Small => {
                    assert!(j.n_maps() == 1 || j.n_maps() == 2);
                    assert_eq!(j.n_reduces(), 0);
                }
                JobClass::Medium => {
                    assert!((5..=500).contains(&j.n_maps()));
                    assert!(j.n_reduces() == 0 || (2..=100).contains(&j.n_reduces()));
                }
                JobClass::Large => {
                    let huge = j.n_maps() >= 2800 && j.n_reduces() == 0;
                    let mid = (700..=1500).contains(&j.n_maps())
                        && (150..=250).contains(&j.n_reduces());
                    let wide = j.n_maps() == 200 && j.n_reduces() == 1000;
                    assert!(huge || mid || wide, "unknown large shape");
                }
            }
        }
        let small = jobs.iter().filter(|j| j.class == JobClass::Small).count();
        let frac = small as f64 / jobs.len() as f64;
        assert!((frac - 0.53).abs() < 0.07, "small fraction {frac}");
    }

    #[test]
    fn diurnal_modulation_shifts_mass_toward_the_peak() {
        let period = 1_000.0;
        let mut src = OpenArrivals::poisson(2.0, 10_000.0)
            .mix(JobMix::Uniform { maps: 1, task_s: 1.0 })
            .diurnal(0.9, period);
        assert!(src.name().contains("diurnal"));
        let jobs = drain(&mut src, 11);
        // First half-period of each cycle (sin > 0) should hold well
        // over half the arrivals.
        let peak_half = jobs
            .iter()
            .filter(|j| (j.submit_time % period) < period / 2.0)
            .count();
        let frac = peak_half as f64 / jobs.len() as f64;
        assert!(frac > 0.6, "peak-half fraction {frac}");
    }

    #[test]
    fn load_factor_is_rate_times_size_over_slots() {
        let src = OpenArrivals::poisson(2.0, 100.0).mix(JobMix::Uniform {
            maps: 4,
            task_s: 5.0,
        });
        // 2 jobs/s × 20 s work / 80 slots = 0.5.
        assert!((src.load_factor(80) - 0.5).abs() < 1e-12);
        assert!(src.load_factor(0).is_finite(), "slot clamp");
    }
}
