//! Zipf tenant-population workload generation.
//!
//! Multi-tenant clusters serve a long-tailed user population: a few
//! heavy hitters submit most of the jobs while thousands of occasional
//! users fill the tail. [`TenantPopulation`] models that as an open
//! Poisson arrival process (like [`OpenArrivals`](super::OpenArrivals))
//! whose submitting *user* is drawn per job from a Zipf distribution
//! over `n_users` identities, each user hashing stably onto one of
//! `n_pools` pools. The resulting [`JobSpec::tenant`] drives the
//! hierarchical scheduler's pool routing and the per-tenant fairness
//! metrics.
//!
//! Memory does not scale with the population: user identities are
//! *sampled*, never enumerated (the table-free
//! [`ZipfStreaming`] sampler draws ranks in O(1) memory, and the
//! user → pool map is a stateless hash), so 10⁶ users across thousands
//! of pools cost the same as one.
//!
//! ## Determinism
//!
//! The *who submits what* sequence — user, pool, job shape — is drawn
//! from a private RNG derived from the dedicated
//! [`StreamId::Population`] substream of the generator's seed. Only the
//! inter-arrival gaps come from the driver-supplied arrivals RNG. The
//! tenant/shape sequence is therefore byte-identical no matter how the
//! arrival clock is consumed and regardless of the faults or placement
//! substreams — a property the determinism suite pins down.

use super::open::JobMix;
use super::source::WorkloadSource;
use crate::job::{JobSpec, TenantId};
use crate::util::rng::{exponential, Pcg64, RngStreams, StreamId, ZipfStreaming};

/// Stateless user → pool assignment: a splitmix64 finalizer keeps pool
/// membership stable for any user id without per-user state, and
/// scatters consecutive ranks so the heavy hitters don't all land in
/// pool 0.
fn pool_of(user: u64, n_pools: u32) -> u32 {
    let mut z = user.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    (z % u64::from(n_pools)) as u32
}

/// Open Poisson arrivals from a Zipf-distributed user population.
///
/// Like [`OpenArrivals`](super::OpenArrivals), the struct is a
/// *template*: cloning yields a fresh generator positioned at t = 0
/// with an unconsumed identity stream, which is how the sweep engine
/// gives every cell its own run.
#[derive(Clone, Debug)]
pub struct TenantPopulation {
    name: String,
    /// Population size (Zipf support); users are ranks `0..n_users`.
    pub n_users: u64,
    /// Number of pools users hash onto.
    pub n_pools: u32,
    /// Zipf skew exponent over user activity.
    pub zipf_s: f64,
    /// Mean arrival rate, jobs per simulated second.
    pub rate: f64,
    /// Stop submitting after this simulated time.
    pub horizon_s: f64,
    /// Hard cap on submitted jobs (`u64::MAX` = uncapped).
    pub max_jobs: u64,
    /// Shape sampler.
    pub mix: JobMix,
    seed: u64,
    zipf: ZipfStreaming,
    /// Private identity/shape RNG ([`StreamId::Population`]); never the
    /// driver's arrivals stream.
    tenant_rng: Pcg64,
    clock: f64,
    emitted: u64,
}

impl TenantPopulation {
    /// A population of `n_users` users over `n_pools` pools submitting
    /// at `rate` jobs/s until `horizon_s`, with the default 0.5 skew of
    /// the multi-tenant trace literature.
    pub fn new(n_users: u64, n_pools: u32, rate: f64, horizon_s: f64, seed: u64) -> Self {
        assert!(n_users > 0, "population needs at least one user");
        assert!(
            n_users <= u64::from(u32::MAX),
            "user ids are u32 ({n_users} users requested)"
        );
        assert!(n_pools > 0, "population needs at least one pool");
        assert!(rate > 0.0, "arrival rate must be positive");
        let zipf_s = 0.5;
        Self {
            name: format!("pop-u{n_users}-p{n_pools}-r{rate}"),
            n_users,
            n_pools,
            zipf_s,
            rate,
            horizon_s,
            max_jobs: u64::MAX,
            mix: JobMix::fb(),
            seed,
            zipf: ZipfStreaming::new(n_users, zipf_s),
            tenant_rng: RngStreams::new(seed).stream(StreamId::Population),
            clock: 0.0,
            emitted: 0,
        }
    }

    /// Replace the Zipf exponent (builder style).
    pub fn skew(mut self, s: f64) -> Self {
        self.zipf_s = s;
        self.zipf = ZipfStreaming::new(self.n_users, s);
        self
    }

    /// Replace the job mix (builder style).
    pub fn mix(mut self, mix: JobMix) -> Self {
        self.mix = mix;
        self
    }

    /// Cap the number of submitted jobs (builder style).
    pub fn max_jobs(mut self, max: u64) -> Self {
        self.max_jobs = max;
        self
    }

    /// Override the display name (sweep labels).
    pub fn named(mut self, name: impl Into<String>) -> Self {
        self.name = name.into();
        self
    }

    /// Re-derive the identity stream from a new seed (the CLI passes
    /// the run seed so `--seed` governs the tenant sequence too).
    pub fn reseed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self.tenant_rng = RngStreams::new(seed).stream(StreamId::Population);
        self
    }

    /// Jobs emitted so far.
    pub fn emitted(&self) -> u64 {
        self.emitted
    }

    /// Whether the stream terminates on its own (see
    /// [`OpenArrivals::is_bounded`](super::OpenArrivals::is_bounded)).
    pub fn is_bounded(&self) -> bool {
        self.horizon_s.is_finite() || self.max_jobs < u64::MAX
    }
}

impl WorkloadSource for TenantPopulation {
    fn name(&self) -> &str {
        &self.name
    }

    fn next_job(&mut self, rng: &mut Pcg64) -> Option<JobSpec> {
        if self.emitted >= self.max_jobs {
            return None;
        }
        self.clock += exponential(rng, 1.0 / self.rate);
        if self.clock > self.horizon_s {
            return None;
        }
        let id = self.emitted;
        self.emitted += 1;
        // Identity and shape from the private population stream only.
        let user = self.zipf.sample(&mut self.tenant_rng) - 1;
        let mut spec = self.mix.sample(&mut self.tenant_rng, id, self.clock);
        spec.tenant = TenantId::new(pool_of(user, self.n_pools), user as u32);
        spec.name = format!("pop-{id}-u{user}");
        Some(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SeedableRng;

    fn drain(src: &mut TenantPopulation, arrivals: &mut Pcg64) -> Vec<JobSpec> {
        std::iter::from_fn(|| src.next_job(arrivals)).collect()
    }

    #[test]
    fn population_arrivals_are_ordered_dense_and_bounded() {
        let tpl = TenantPopulation::new(1_000, 10, 2.0, 500.0, 7)
            .mix(JobMix::Uniform { maps: 1, task_s: 1.0 });
        assert!(tpl.is_bounded());
        let mut rng = Pcg64::seed_from_u64(7);
        let jobs = drain(&mut tpl.clone(), &mut rng);
        assert!((jobs.len() as f64 - 1_000.0).abs() < 200.0, "{}", jobs.len());
        let mut last = 0.0;
        for (i, j) in jobs.iter().enumerate() {
            assert_eq!(j.id, i as u64);
            assert!(j.submit_time >= last && j.submit_time <= 500.0);
            last = j.submit_time;
            assert!(u64::from(j.tenant.user) < 1_000);
            assert!(j.tenant.pool < 10);
        }
    }

    #[test]
    fn activity_is_zipf_skewed_and_pools_spread() {
        let tpl = TenantPopulation::new(10_000, 100, 10.0, 2_000.0, 3)
            .mix(JobMix::Uniform { maps: 1, task_s: 1.0 });
        let mut rng = Pcg64::seed_from_u64(3);
        let jobs = drain(&mut tpl.clone(), &mut rng);
        assert!(jobs.len() > 10_000);
        let mut by_user = std::collections::HashMap::<u32, usize>::new();
        let mut pools = std::collections::HashSet::new();
        for j in &jobs {
            *by_user.entry(j.tenant.user).or_default() += 1;
            pools.insert(j.tenant.pool);
        }
        // Long tail: far fewer distinct users than jobs, and the top
        // user dwarfs the median.
        assert!(by_user.len() < jobs.len() / 2);
        let top = by_user.values().copied().max().unwrap();
        assert!(top > jobs.len() / 200, "top user {top} of {}", jobs.len());
        // The hash spreads users over (nearly) all pools.
        assert!(pools.len() > 90, "only {} pools hit", pools.len());
    }

    #[test]
    fn tenant_sequence_is_independent_of_the_arrival_stream() {
        // Same template, two *different* arrival RNGs: submit times
        // differ, but the (user, pool, shape) sequence is identical —
        // the identity stream is private.
        let tpl = TenantPopulation::new(50_000, 64, 5.0, 1_000.0, 42);
        let mut ra = Pcg64::seed_from_u64(1);
        let mut rb = Pcg64::seed_from_u64(999);
        let a = drain(&mut tpl.clone(), &mut ra);
        let b = drain(&mut tpl.clone(), &mut rb);
        let n = a.len().min(b.len());
        assert!(n > 1_000);
        let mut times_differ = false;
        for (x, y) in a[..n].iter().zip(&b[..n]) {
            assert_eq!(x.tenant, y.tenant);
            assert_eq!(x.map_durations, y.map_durations);
            assert_eq!(x.reduce_durations, y.reduce_durations);
            times_differ |= x.submit_time.total_cmp(&y.submit_time).is_ne();
        }
        assert!(times_differ, "different arrival RNGs must shift the clock");
    }

    #[test]
    fn reseed_changes_the_identity_stream_deterministically() {
        let tpl = TenantPopulation::new(1_000, 8, 5.0, 200.0, 1);
        let mut r1 = Pcg64::seed_from_u64(5);
        let mut r2 = Pcg64::seed_from_u64(5);
        let a = drain(&mut tpl.clone(), &mut r1);
        let b = drain(&mut tpl.clone().reseed(1), &mut r2);
        assert_eq!(a.len(), b.len(), "reseed(same) is a no-op");
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tenant, y.tenant);
        }
        let mut r3 = Pcg64::seed_from_u64(5);
        let c = drain(&mut tpl.clone().reseed(2), &mut r3);
        let n = a.len().min(c.len());
        assert!(
            a[..n].iter().zip(&c[..n]).any(|(x, y)| x.tenant != y.tenant),
            "different seeds draw different tenants"
        );
    }

    #[test]
    fn pool_hash_is_stable_and_in_range() {
        for u in [0u64, 1, 999_999, u64::MAX] {
            let p = pool_of(u, 100);
            assert_eq!(p, pool_of(u, 100), "stable");
            assert!(p < 100);
        }
        // 1-pool degenerate case maps everyone to pool 0.
        assert_eq!(pool_of(123, 1), 0);
    }
}
