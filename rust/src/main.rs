//! `hfsp` — launcher CLI for the HFSP reproduction.
//!
//! Subcommands:
//!
//! * `workload-gen` — synthesize an FB-dataset trace (SWIM-like, §4.1);
//! * `simulate` — run one scheduler over a workload and report sojourn
//!   statistics;
//! * `compare` — run FIFO, FAIR and HFSP on the *same* workload and print
//!   the paper-style comparison table;
//! * `fsp-demo` — the Fig. 1/2 PS-vs-FSP intuition timelines.

use hfsp::cluster::driver::{run_simulation, SimConfig, SimOutcome};
use hfsp::cluster::ClusterConfig;
use hfsp::job::JobClass;
use hfsp::report;
use hfsp::scheduler::hfsp::{EstimatorKind, HfspConfig, MaxMinKind, PreemptionPrimitive};
use hfsp::scheduler::SchedulerKind;
use hfsp::util::cli::{Cli, Command, Parsed};
use hfsp::util::json::Json;
use hfsp::util::rng::{Pcg64, SeedableRng};
use hfsp::workload::swim::FbWorkload;
use hfsp::workload::{synthetic, trace, Workload};
use std::path::{Path, PathBuf};

fn cli() -> Cli {
    Cli {
        about: "hfsp — Hadoop Fair Sojourn Protocol reproduction",
        commands: vec![
            Command::new("workload-gen", "synthesize an FB-dataset workload trace")
                .flag("seed", "42", "rng seed")
                .flag("scale", "1.0", "scale job counts by this factor")
                .flag("out", "", "output trace path (JSONL, required)"),
            Command::new("simulate", "run one scheduler over a workload")
                .flag("scheduler", "hfsp", "fifo | fair | hfsp")
                .flag("nodes", "100", "cluster size")
                .flag("map-slots", "4", "map slots per node")
                .flag("reduce-slots", "2", "reduce slots per node")
                .flag("seed", "42", "rng seed (workload + placement)")
                .flag("trace", "", "replay this JSONL trace instead of generating")
                .flag("preemption", "suspend", "hfsp preemption: suspend | wait | kill")
                .flag("estimator", "native", "hfsp estimator: native | mean | xla")
                .flag("maxmin", "native", "hfsp max-min backend: native | xla")
                .flag("artifacts", "artifacts", "artifact dir for xla backends")
                .flag("out", "", "write JSON outcome summary here")
                .switch("timelines", "record per-job slot timelines")
                .switch("per-class", "print per-class sojourn breakdown"),
            Command::new("compare", "run FIFO, FAIR and HFSP on the same workload")
                .flag("nodes", "100", "cluster size")
                .flag("seed", "42", "rng seed")
                .flag("trace", "", "replay this JSONL trace instead of generating")
                .flag("out", "", "write JSON outcome summary here"),
            Command::new("fsp-demo", "PS vs FSP intuition (paper Fig. 1/2)")
                .flag("slots", "4", "single-node slot count"),
        ],
    }
}

fn main() {
    hfsp::util::logging::init_from_env();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn run(argv: &[String]) -> anyhow::Result<()> {
    match cli().parse(argv)? {
        Parsed::Help(text) => {
            println!("{text}");
            Ok(())
        }
        Parsed::Command("workload-gen", args) => {
            let seed: u64 = args.require("seed")?;
            let scale: f64 = args.require("scale")?;
            let out: PathBuf = args.require("out")?;
            let wl = FbWorkload::scaled(scale).generate(&mut Pcg64::seed_from_u64(seed));
            trace::write_trace(&wl, &out)?;
            println!(
                "wrote {} jobs ({} tasks, {:.0} s serialized work) to {}",
                wl.len(),
                wl.total_tasks(),
                wl.total_work(),
                out.display()
            );
            Ok(())
        }
        Parsed::Command("simulate", args) => {
            let kind = scheduler_from_args(&args)?;
            let (cfg, wl) = sim_setup(&args)?;
            let outcome = run_simulation(&cfg, kind, &wl);
            print_outcome(&outcome, args.get_bool("per-class"));
            maybe_write_json(args.get("out"), &[&outcome])?;
            Ok(())
        }
        Parsed::Command("compare", args) => {
            let (cfg, wl) = sim_setup(&args)?;
            let outcomes: Vec<SimOutcome> = [
                SchedulerKind::Fifo,
                SchedulerKind::Fair(Default::default()),
                SchedulerKind::Hfsp(HfspConfig::default()),
            ]
            .into_iter()
            .map(|kind| run_simulation(&cfg, kind, &wl))
            .collect();
            let rows: Vec<Vec<String>> = outcomes
                .iter()
                .map(|o| {
                    vec![
                        o.scheduler.to_string(),
                        format!("{:.0}", o.sojourn.mean()),
                        format!("{:.0}", o.sojourn.mean_class(JobClass::Small)),
                        format!("{:.0}", o.sojourn.mean_class(JobClass::Medium)),
                        format!("{:.0}", o.sojourn.mean_class(JobClass::Large)),
                        format!("{:.1}%", o.locality.fraction_local() * 100.0),
                        format!("{:.0}", o.makespan),
                    ]
                })
                .collect();
            println!(
                "{}",
                report::table(
                    &[
                        "scheduler",
                        "mean sojourn (s)",
                        "small (s)",
                        "medium (s)",
                        "large (s)",
                        "map locality",
                        "makespan (s)"
                    ],
                    &rows
                )
            );
            let refs: Vec<&SimOutcome> = outcomes.iter().collect();
            maybe_write_json(args.get("out"), &refs)?;
            Ok(())
        }
        Parsed::Command("fsp-demo", args) => {
            let slots: usize = args.require("slots")?;
            fsp_demo(slots);
            Ok(())
        }
        Parsed::Command(other, _) => anyhow::bail!("unhandled subcommand {other}"),
    }
}

fn scheduler_from_args(args: &hfsp::util::cli::Args) -> anyhow::Result<SchedulerKind> {
    let name = args.get("scheduler").unwrap_or("hfsp");
    let mut kind = SchedulerKind::from_name(name)?;
    if let SchedulerKind::Hfsp(cfg) = &mut kind {
        cfg.preemption = PreemptionPrimitive::from_name(args.get("preemption").unwrap_or("suspend"))?;
        let artifacts = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
        cfg.estimator = match args.get("estimator").unwrap_or("native") {
            "native" => EstimatorKind::Native,
            "mean" => EstimatorKind::Mean,
            "xla" => EstimatorKind::Xla {
                artifact_dir: artifacts.clone(),
            },
            other => anyhow::bail!("unknown estimator {other:?}"),
        };
        cfg.maxmin = match args.get("maxmin").unwrap_or("native") {
            "native" => MaxMinKind::Native,
            "xla" => MaxMinKind::Xla {
                artifact_dir: artifacts,
            },
            other => anyhow::bail!("unknown maxmin backend {other:?}"),
        };
    }
    Ok(kind)
}

fn sim_setup(args: &hfsp::util::cli::Args) -> anyhow::Result<(SimConfig, Workload)> {
    let seed: u64 = args.require("seed")?;
    let nodes: usize = args.require("nodes")?;
    let mut cluster = ClusterConfig {
        nodes,
        ..Default::default()
    };
    if let Some(ms) = args.get_parsed::<usize>("map-slots")? {
        cluster.map_slots = ms;
    }
    if let Some(rs) = args.get_parsed::<usize>("reduce-slots")? {
        cluster.reduce_slots = rs;
    }
    let cfg = SimConfig {
        cluster,
        seed,
        record_timelines: args.get_bool("timelines"),
        ..Default::default()
    };
    let wl = match args.get("trace") {
        Some(path) => trace::read_trace(Path::new(path))?,
        None => FbWorkload::default().generate(&mut Pcg64::seed_from_u64(seed)),
    };
    Ok((cfg, wl))
}

fn print_outcome(o: &SimOutcome, per_class: bool) {
    println!(
        "{} on {:<14} mean sojourn {:>8.1} s | {} jobs | locality {:.1}% | makespan {:.0} s | {} events in {:.0} ms",
        o.scheduler,
        o.workload,
        o.sojourn.mean(),
        o.sojourn.len(),
        o.locality.fraction_local() * 100.0,
        o.makespan,
        o.events_processed,
        o.wall_ms
    );
    if per_class {
        for class in JobClass::ALL {
            let m = o.sojourn.mean_class(class);
            if !m.is_nan() {
                println!("  {:<8} mean sojourn {:>8.1} s", class.name(), m);
            }
        }
        let c = o.counters;
        println!(
            "  launches {} suspends {} resumes {} kills {} swap-ins {}",
            c.launches, c.suspends, c.resumes, c.kills, c.swap_ins
        );
    }
}

fn maybe_write_json(path: Option<&str>, outcomes: &[&SimOutcome]) -> anyhow::Result<()> {
    let Some(path) = path else { return Ok(()) };
    let arr: Vec<Json> = outcomes
        .iter()
        .map(|o| {
            let mut j = o.sojourn.to_json();
            j.set("scheduler", o.scheduler.into());
            j.set("workload", o.workload.as_str().into());
            j.set("makespan_s", o.makespan.into());
            j.set("locality", o.locality.to_json());
            j.set("events", o.events_processed.into());
            j
        })
        .collect();
    std::fs::write(path, Json::Arr(arr).to_string_pretty())?;
    println!("wrote outcome summary to {path}");
    Ok(())
}

/// Print the Fig. 1 / Fig. 2 PS-vs-FSP intuition using the simulator on a
/// single node.
fn fsp_demo(slots: usize) {
    let cluster = ClusterConfig {
        nodes: 1,
        map_slots: slots,
        reduce_slots: 1,
        heartbeat_s: 0.5,
        ..Default::default()
    };
    let cfg = SimConfig {
        cluster,
        record_timelines: true,
        ..Default::default()
    };
    for (label, wl) in [
        ("Fig.1 (full-width jobs)", synthetic::fig1_workload(slots, 6)),
        ("Fig.2 (fractional jobs)", synthetic::fig2_workload(slots, 6)),
    ] {
        println!("=== {label} ===");
        for kind in [
            SchedulerKind::Fair(Default::default()),
            SchedulerKind::Hfsp(HfspConfig::default()),
        ] {
            let o = run_simulation(&cfg, kind, &wl);
            println!(
                "--- {} (mean sojourn {:.1} s) ---",
                o.scheduler,
                o.sojourn.mean()
            );
            print!("{}", o.timelines.ascii_chart(0.0, o.makespan, 72));
        }
    }
}
