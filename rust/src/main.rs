//! `hfsp` — launcher CLI for the HFSP reproduction.
//!
//! Subcommands:
//!
//! * `workload-gen` — synthesize an FB-dataset trace (SWIM-like, §4.1);
//! * `simulate` — run one scheduler over a workload and report sojourn
//!   statistics (any registered discipline: fifo, fair, hfsp, srpt,
//!   las, psbs);
//! * `compare` — run FIFO, FAIR and HFSP on the *same* workload (in
//!   parallel, via the sweep engine) and print the paper-style
//!   comparison table;
//! * `sweep` — run a declarative scheduler × nodes × faults × seed
//!   experiment grid across a thread pool and emit the aggregated table
//!   + JSON report (`--grid faults` adds the robustness scenarios);
//! * `bench` — time the standard scenarios and emit `BENCH_sim.json`
//!   (events/sec, wall-clock, queue stats and peak RSS per scenario —
//!   the perf trajectory file); `--compare old.json` prints deltas
//!   against a baseline and exits non-zero past `--threshold`;
//! * `fsp-demo` — the Fig. 1/2 PS-vs-FSP intuition timelines;
//! * `lint` — the `simlint` determinism-contract static-analysis pass
//!   over `rust/src` (std hash containers, `partial_cmp` comparators,
//!   wall-clock reads, naked RNG seeding, undocumented `unsafe`);
//!   `--deny` is the CI gate mode.

use hfsp::cluster::driver::{run_session, run_simulation, SimConfig, SimOutcome};
use hfsp::cluster::ClusterConfig;
use hfsp::faults::FaultSpec;
use hfsp::job::JobClass;
use hfsp::report;
use hfsp::scheduler::core::{EstimatorKind, HfspConfig, MaxMinKind, PreemptionPrimitive};
use hfsp::scheduler::hierarchy::{HierarchyConfig, Topology};
use hfsp::scheduler::{SchedulerKind, REGISTRY};
use hfsp::sim::{MergeMode, QueueKind, ShardSpec, StopReason, WindowArg};
use hfsp::sweep::{run_grid, run_grid_threads, ExperimentGrid, WorkloadSpec};
use hfsp::util::cli::{Cli, Command, Parsed};
use hfsp::util::config::Config as FileConfig;
use hfsp::util::json::Json;
use hfsp::util::rng::RngStreams;
use hfsp::workload::swim::FbWorkload;
use hfsp::workload::{synthetic, trace, JobMix, OpenArrivals, TenantPopulation, Workload};
use std::path::{Path, PathBuf};

fn cli() -> Cli {
    Cli {
        about: "hfsp — Hadoop Fair Sojourn Protocol reproduction",
        commands: vec![
            Command::new("workload-gen", "synthesize an FB-dataset workload trace")
                .flag("seed", "42", "rng seed")
                .flag("scale", "1.0", "scale job counts by this factor")
                .flag("out", "", "output trace path (JSONL, required)"),
            Command::new("simulate", "run one scheduler over a workload")
                .flag("scheduler", "hfsp", SchedulerKind::cli_help())
                .flag("nodes", "100", "cluster size")
                .flag("map-slots", "4", "map slots per node")
                .flag("reduce-slots", "2", "reduce slots per node")
                .flag("seed", "42", "rng seed (workload + placement + faults + arrivals)")
                .flag("trace", "", "replay this JSONL trace instead of generating")
                .flag("arrivals", "closed", "closed (job list) | open (Poisson) | population (Zipf multi-tenant)")
                .flag("rate", "0.08", "open/population arrivals: mean jobs per second (FB mix; paper load ≈ 0.08)")
                .flag("duration", "3600", "open/population arrivals: submission horizon, seconds")
                .flag("max-jobs", "0", "open/population arrivals: stop after this many submissions (0 = horizon only)")
                .flag("pools", "", "hier scheduler: pool topology — single | example | <topology.json>")
                .flag("users", "10000", "population arrivals: Zipf user population size")
                .flag("tenant-pools", "100", "population arrivals: number of pools users hash onto")
                .flag("zipf-s", "0.5", "population arrivals: Zipf skew exponent (> 0; smaller = flatter)")
                .flag("preemption", "suspend", "hfsp preemption: suspend | wait | kill")
                .flag("estimator", "native", "hfsp estimator: native | mean | xla")
                .flag("maxmin", "native", "hfsp max-min backend: native | xla")
                .flag("artifacts", "artifacts", "artifact dir for xla backends")
                .flag("faults", "", "fault scenario: none | churn | stragglers | error | full (default: from --config, else none)")
                .flag("event-limit", "0", "override the event-count guard (0 = default)")
                .flag("config", "", "TOML-subset config file; its [sim]/[cluster] keys override --seed/--nodes/--map-slots/--reduce-slots")
                .flag("queue", "", "event queue backend: calendar | heap (default: from --config, else calendar)")
                .flag("shards", "", "partition the cluster across this many shards (default: from --config, else 1 = serial)")
                .flag("merge", "", "shard merge mode: deterministic (byte-identical to serial) | fast (threaded window barrier)")
                .flag("window", "", "fast merge: barrier window, simulated seconds, or auto[:min,max] for adaptive sizing (default: one heartbeat period)")
                .flag("out", "", "write JSON outcome summary here")
                .switch("stream", "replay --trace through the streaming TraceSource (constant memory)")
                .switch("timelines", "record per-job slot timelines")
                .switch("per-class", "print per-class sojourn breakdown"),
            Command::new("compare", "run FIFO, FAIR and HFSP on the same workload")
                .flag("nodes", "100", "cluster size")
                .flag("seed", "42", "rng seed")
                .flag("trace", "", "replay this JSONL trace instead of generating")
                .flag("out", "", "write JSON outcome summary here"),
            Command::new("sweep", "run a scheduler x nodes x faults x seed experiment grid")
                .flag("schedulers", "fifo,fair,hfsp", SchedulerKind::cli_help_list())
                .flag("nodes", "100", "comma-separated cluster sizes")
                .flag("seeds", "42,7,1234", "comma-separated seeds")
                .flag("workload", "fb", "fb | fb-map-only | fig7 | open (Poisson) | population (Zipf multi-tenant)")
                .flag("scale", "1.0", "scale FB-dataset job counts by this factor")
                .flag("rates", "0.08", "open/population workload: comma-separated arrival rates (jobs/s) — one load point each")
                .flag("duration", "3600", "open/population workload: submission horizon, seconds")
                .flag("pools", "", "hier schedulers: pool topology — single | example | <topology.json>")
                .flag("users", "10000", "population workload: Zipf user population size")
                .flag("tenant-pools", "100", "population workload: number of pools users hash onto")
                .flag("zipf-s", "0.5", "population workload: Zipf skew exponent (> 0)")
                .flag("grid", "none", "extra axis preset: none | faults (the robustness grid)")
                .flag("faults", "", "explicit comma-separated fault scenarios (overrides --grid)")
                .flag("threads", "0", "worker threads (0 = all cores)")
                .flag("event-limit", "0", "override the event-count guard (0 = default)")
                .flag("queue", "", "event queue backend: calendar | heap (default: calendar)")
                .flag("name", "cli-sweep", "sweep name recorded in the report")
                .flag("out", "reports/sweep.json", "aggregated JSON report path"),
            Command::new("bench", "time the standard scenarios; emit BENCH_sim.json")
                .flag("scale", "0.3", "scale FB-dataset job counts by this factor")
                .flag("nodes", "20", "cluster size")
                .flag("seed", "42", "rng seed")
                .flag("profile", "quick", "scenario set: quick | full (adds the open-1e6 streaming run)")
                .flag("compare", "", "baseline BENCH_sim.json: print events/sec deltas and fail past --threshold")
                .flag("threshold", "0.30", "max tolerated fractional events/sec regression for --compare")
                .flag("queue", "", "event queue backend: calendar | heap (default: calendar)")
                .flag("shards", "4", "shard count for the par-open-* fast-merge scenarios")
                .flag("window", "auto", "par-open-* scenarios: barrier window, simulated seconds, or auto[:min,max]")
                .flag("merge-baseline", "", "rewrite the committed --out trajectory from this CI-measured artifact (no scenarios run)")
                .flag("out", "BENCH_sim.json", "benchmark JSON output path")
                .switch("scaling", "emit a par-open shard-count scaling sweep (1/2/4/8) with per-shard speedup lines")
                .switch("require-baseline", "fail --compare when the baseline shares no scenarios (arms the CI gate against an empty baseline)"),
            Command::new("fsp-demo", "PS vs FSP intuition (paper Fig. 1/2)")
                .flag("slots", "4", "single-node slot count"),
            Command::new("lint", "simlint: determinism-contract static analysis over rust/src")
                .flag("src", "", "source root to scan (default: ./src, or ./rust/src from the repo root)")
                .flag("allow", "", "allowlist file (default: simlint.allow next to Cargo.toml, when present)")
                .switch("json", "emit a machine-readable JSON report instead of text diagnostics")
                .switch("deny", "exit non-zero on any violation (the CI gate mode)"),
        ],
    }
}

fn main() {
    hfsp::util::logging::init_from_env();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn run(argv: &[String]) -> anyhow::Result<()> {
    match cli().parse(argv)? {
        Parsed::Help(text) => {
            println!("{text}");
            Ok(())
        }
        Parsed::Command("workload-gen", args) => {
            let seed: u64 = args.require("seed")?;
            let scale: f64 = args.require("scale")?;
            let out: PathBuf = args.require("out")?;
            let wl = FbWorkload::scaled(scale).generate(&mut RngStreams::workload(seed));
            trace::write_trace(&wl, &out)?;
            println!(
                "wrote {} jobs ({} tasks, {:.0} s serialized work) to {}",
                wl.len(),
                wl.total_tasks(),
                wl.total_work(),
                out.display()
            );
            Ok(())
        }
        Parsed::Command("simulate", args) => {
            let mut kind = scheduler_from_args(&args)?;
            let cfg = sim_config(&args)?;
            // The fault scenario's estimation error lives inside HFSP's
            // training module (same wiring as sweep cells; gated by the
            // `enabled` master switch).
            kind.apply_fault_error(cfg.faults.effective_error_sigma(), cfg.seed);
            let outcome = match args.get("arrivals").unwrap_or("closed") {
                "closed" if args.get_bool("stream") => {
                    let Some(path) = args.get("trace") else {
                        anyhow::bail!("--stream requires --trace <file>");
                    };
                    let mut src = trace::TraceSource::open(Path::new(path))?;
                    // A truncated stream surfaces via outcome.stream_error
                    // (checked below for every arrival mode).
                    run_session(&cfg, kind, &mut src, Vec::new())
                }
                "closed" => {
                    let wl = closed_workload(&args, &cfg)?;
                    run_simulation(&cfg, kind, &wl)
                }
                "open" => {
                    anyhow::ensure!(
                        args.get("trace").is_none(),
                        "--arrivals open generates its own jobs; replay traces with \
                         --arrivals closed [--stream]"
                    );
                    anyhow::ensure!(
                        !args.get_bool("stream"),
                        "--stream applies to trace replay; it does nothing with --arrivals open"
                    );
                    let rate: f64 = args.require("rate")?;
                    let duration: f64 = args.require("duration")?;
                    anyhow::ensure!(rate > 0.0 && rate.is_finite(), "--rate must be positive and finite");
                    let max_jobs: u64 = args.require("max-jobs")?;
                    anyhow::ensure!(
                        (duration > 0.0 && duration.is_finite()) || max_jobs > 0,
                        "--duration must be positive and finite (or pass --max-jobs to bound the session)"
                    );
                    // With a job cap, a non-positive/infinite --duration
                    // means "no horizon" rather than "no jobs".
                    let horizon = if duration > 0.0 && duration.is_finite() {
                        duration
                    } else {
                        f64::INFINITY
                    };
                    let mut src = OpenArrivals::poisson(rate, horizon);
                    if max_jobs > 0 {
                        src = src.max_jobs(max_jobs);
                    }
                    let slots = cfg.cluster.nodes * cfg.cluster.map_slots;
                    println!(
                        "open session: {rate} jobs/s for {duration} s (offered load ≈ {:.2} on {} map slots)",
                        src.load_factor(slots),
                        slots
                    );
                    let outcome = run_session(&cfg, kind, &mut src, Vec::new());
                    println!(
                        "  {} jobs arrived, {} finished, peak {} live jobs",
                        outcome.jobs_arrived,
                        outcome.sojourn.len(),
                        outcome.peak_live_jobs
                    );
                    outcome
                }
                "population" => {
                    anyhow::ensure!(
                        args.get("trace").is_none(),
                        "--arrivals population generates its own jobs; replay traces with \
                         --arrivals closed [--stream]"
                    );
                    anyhow::ensure!(
                        !args.get_bool("stream"),
                        "--stream applies to trace replay; it does nothing with --arrivals population"
                    );
                    let rate: f64 = args.require("rate")?;
                    let duration: f64 = args.require("duration")?;
                    anyhow::ensure!(rate > 0.0 && rate.is_finite(), "--rate must be positive and finite");
                    let users: u64 = args.require("users")?;
                    let tenant_pools: u32 = args.require("tenant-pools")?;
                    let zipf_s: f64 = args.require("zipf-s")?;
                    anyhow::ensure!(
                        users > 0 && users <= u64::from(u32::MAX),
                        "--users must be in 1..=2^32-1"
                    );
                    anyhow::ensure!(tenant_pools > 0, "--tenant-pools must be positive");
                    anyhow::ensure!(
                        zipf_s > 0.0 && zipf_s.is_finite(),
                        "--zipf-s must be positive and finite"
                    );
                    let max_jobs: u64 = args.require("max-jobs")?;
                    anyhow::ensure!(
                        (duration > 0.0 && duration.is_finite()) || max_jobs > 0,
                        "--duration must be positive and finite (or pass --max-jobs to bound the session)"
                    );
                    let horizon = if duration > 0.0 && duration.is_finite() {
                        duration
                    } else {
                        f64::INFINITY
                    };
                    let mut src =
                        TenantPopulation::new(users, tenant_pools, rate, horizon, cfg.seed)
                            .skew(zipf_s);
                    if max_jobs > 0 {
                        src = src.max_jobs(max_jobs);
                    }
                    println!(
                        "population session: {rate} jobs/s from {users} Zipf(s={zipf_s}) users \
                         across {tenant_pools} pools"
                    );
                    let outcome = run_session(&cfg, kind, &mut src, Vec::new());
                    println!(
                        "  {} jobs arrived, {} finished, peak {} live jobs",
                        outcome.jobs_arrived,
                        outcome.sojourn.len(),
                        outcome.peak_live_jobs
                    );
                    outcome
                }
                other => anyhow::bail!("unknown --arrivals mode {other:?} (closed|open|population)"),
            };
            print_outcome(&outcome, args.get_bool("per-class"));
            maybe_write_json(args.get("out"), &[&outcome])?;
            if let Some(err) = &outcome.stream_error {
                anyhow::bail!("invalid workload stream: {err}");
            }
            anyhow::ensure!(
                !outcome.truncated(),
                "simulation truncated by the event-count guard ({} events) — \
                 raise --event-limit or sim.event_limit",
                cfg.event_limit
            );
            Ok(())
        }
        Parsed::Command("compare", args) => {
            // A compare is a 1-workload, 1-seed scheduler sweep: declare
            // the grid and let the engine run the three cells in
            // parallel.
            let (cfg, wl) = sim_setup(&args)?;
            let grid = ExperimentGrid::new("compare")
                .base_config(cfg)
                .workload(WorkloadSpec::Fixed(wl));
            let results = run_grid(&grid);
            let rows: Vec<Vec<String>> = results
                .outcomes()
                .map(|o| {
                    vec![
                        o.scheduler.to_string(),
                        format!("{:.0}", o.sojourn.mean()),
                        format!("{:.0}", o.sojourn.mean_class(JobClass::Small)),
                        format!("{:.0}", o.sojourn.mean_class(JobClass::Medium)),
                        format!("{:.0}", o.sojourn.mean_class(JobClass::Large)),
                        format!("{:.1}%", o.locality.fraction_local() * 100.0),
                        format!("{:.0}", o.makespan),
                    ]
                })
                .collect();
            println!(
                "{}",
                report::table(
                    &[
                        "scheduler",
                        "mean sojourn (s)",
                        "small (s)",
                        "medium (s)",
                        "large (s)",
                        "map locality",
                        "makespan (s)"
                    ],
                    &rows
                )
            );
            let refs: Vec<&SimOutcome> = results.outcomes().collect();
            maybe_write_json(args.get("out"), &refs)?;
            Ok(())
        }
        Parsed::Command("sweep", args) => run_sweep(&args),
        Parsed::Command("bench", args) => run_bench(&args),
        Parsed::Command("fsp-demo", args) => {
            let slots: usize = args.require("slots")?;
            fsp_demo(slots);
            Ok(())
        }
        Parsed::Command("lint", args) => {
            hfsp::lint::cli_main(
                args.get("src").filter(|s| !s.trim().is_empty()),
                args.get("allow").filter(|s| !s.trim().is_empty()),
                args.get_bool("json"),
                args.get_bool("deny"),
            )?;
            Ok(())
        }
        Parsed::Command(other, _) => anyhow::bail!("unhandled subcommand {other}"),
    }
}

fn scheduler_from_args(args: &hfsp::util::cli::Args) -> anyhow::Result<SchedulerKind> {
    let name = args.get("scheduler").unwrap_or("hfsp");
    let mut kind = SchedulerKind::from_name(name)?;
    // `--pools` selects the hierarchy's topology; a malformed topology
    // (unknown parent, non-positive weight, duplicate name, cycle) is a
    // hard error surfaced here, before any simulation starts.
    let pools = args.get("pools").filter(|p| !p.trim().is_empty());
    if let Some(arg) = pools {
        match &mut kind {
            SchedulerKind::Hierarchical(h) => h.topology = Topology::from_arg(arg)?,
            _ => anyhow::bail!("--pools requires --scheduler hier (got {name:?})"),
        }
    }
    // The mechanism flags apply to every size-based discipline, not just
    // HFSP: `--preemption kill` SRPT or `--estimator mean` PSBS are
    // legitimate configurations. The hierarchical scheduler shares the
    // same mechanism through its base config, so the flags reach its
    // leaf pools too.
    let cfg = match &mut kind {
        SchedulerKind::SizeBased(cfg) => cfg,
        SchedulerKind::Hierarchical(h) => &mut h.base,
        _ => return Ok(kind),
    };
    cfg.preemption = PreemptionPrimitive::from_name(args.get("preemption").unwrap_or("suspend"))?;
    let artifacts = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    cfg.estimator = match args.get("estimator").unwrap_or("native") {
        "native" => EstimatorKind::Native,
        "mean" => EstimatorKind::Mean,
        "xla" => EstimatorKind::Xla {
            artifact_dir: artifacts.clone(),
        },
        other => anyhow::bail!("unknown estimator {other:?}"),
    };
    cfg.maxmin = match args.get("maxmin").unwrap_or("native") {
        "native" => MaxMinKind::Native,
        "xla" => MaxMinKind::Xla {
            artifact_dir: artifacts,
        },
        other => anyhow::bail!("unknown maxmin backend {other:?}"),
    };
    Ok(kind)
}

fn sim_config(args: &hfsp::util::cli::Args) -> anyhow::Result<SimConfig> {
    let seed: u64 = args.require("seed")?;
    let nodes: usize = args.require("nodes")?;
    let mut cluster = ClusterConfig {
        nodes,
        ..Default::default()
    };
    if let Some(ms) = args.get_parsed::<usize>("map-slots")? {
        cluster.map_slots = ms;
    }
    if let Some(rs) = args.get_parsed::<usize>("reduce-slots")? {
        cluster.reduce_slots = rs;
    }
    let mut cfg = SimConfig {
        cluster,
        seed,
        record_timelines: args.get_bool("timelines"),
        ..Default::default()
    };
    // The config file is applied on top of the flag-derived base: its
    // `[sim]`/`[cluster]` keys override --seed/--nodes/--map-slots/
    // --reduce-slots (the flag parser cannot distinguish explicit flags
    // from their defaults, so the file wins — documented in the flag
    // help). `--faults`/`--event-limit` have no seeded defaults and are
    // re-applied after the file, so they always win when given.
    if let Some(path) = args.get("config") {
        cfg.apply_config(&FileConfig::load(Path::new(path))?);
    }
    if let Some(name) = args.get("faults") {
        cfg.faults = FaultSpec::from_name(name)?.config;
    }
    if let Some(name) = args.get("queue") {
        cfg.queue = QueueKind::from_name(name)?;
    }
    if let Some(limit) = args.get_parsed::<u64>("event-limit")? {
        if limit > 0 {
            cfg.event_limit = limit;
        }
    }
    // Sharding flags (commands that don't define them fall through to
    // the config file / serial default).
    if let Some(n) = args.get_parsed::<usize>("shards")? {
        if n > 0 {
            cfg.shards.count = n;
        }
    }
    if let Some(name) = args.get("merge").filter(|m| !m.trim().is_empty()) {
        cfg.shards.merge = MergeMode::from_name(name)?;
    }
    if let Some(w) = args.get("window").filter(|w| !w.trim().is_empty()) {
        match WindowArg::parse(w.trim())? {
            WindowArg::Fixed(w) => {
                cfg.shards.window_s = Some(w);
                cfg.shards.auto_window = None;
            }
            WindowArg::Auto(bounds) => cfg.shards.auto_window = Some(bounds),
        }
    }
    Ok(cfg)
}

/// The closed job list for one run: a replayed trace, or the FB-dataset
/// synthesized from the *effective* seed (so a config-file `sim.seed`
/// governs the whole run, not just placement and faults).
fn closed_workload(args: &hfsp::util::cli::Args, cfg: &SimConfig) -> anyhow::Result<Workload> {
    match args.get("trace") {
        Some(path) => trace::read_trace(Path::new(path)),
        None => Ok(FbWorkload::default().generate(&mut RngStreams::workload(cfg.seed))),
    }
}

fn sim_setup(args: &hfsp::util::cli::Args) -> anyhow::Result<(SimConfig, Workload)> {
    let cfg = sim_config(args)?;
    let wl = closed_workload(args, &cfg)?;
    Ok((cfg, wl))
}

fn print_outcome(o: &SimOutcome, per_class: bool) {
    println!(
        "{} on {:<14} mean sojourn {:>8.1} s | {} jobs | locality {:.1}% | makespan {:.0} s | {} events in {:.0} ms",
        o.scheduler,
        o.workload,
        o.sojourn.mean(),
        o.sojourn.len(),
        o.locality.fraction_local() * 100.0,
        o.makespan,
        o.events_processed,
        o.wall_ms
    );
    if per_class {
        for class in JobClass::ALL {
            let m = o.sojourn.mean_class(class);
            if !m.is_nan() {
                println!("  {:<8} mean sojourn {:>8.1} s", class.name(), m);
            }
        }
        let c = o.counters;
        println!(
            "  launches {} suspends {} resumes {} kills {} swap-ins {}",
            c.launches, c.suspends, c.resumes, c.kills, c.swap_ins
        );
    }
    if o.events_skipped > 0 {
        println!("  {} stale heartbeat events skipped (lazy deletion)", o.events_skipped);
    }
    let f = o.faults;
    if f.crashes > 0 || f.straggler_nodes > 0 || o.counters.speculative_launches > 0 {
        println!(
            "  faults: {} crashes ({} permanent) | {} stragglers | {} task kills | \
             {} re-executions | {:.0} s wasted | speculation {}/{} won",
            f.crashes,
            f.permanent_losses,
            f.straggler_nodes,
            f.crash_task_kills,
            f.re_executed_tasks,
            f.wasted_work_s,
            o.counters.speculative_wins,
            o.counters.speculative_launches
        );
    }
}

/// The `sweep` subcommand: declarative grid → parallel run → aggregated
/// table + deterministic JSON report.
fn run_sweep(args: &hfsp::util::cli::Args) -> anyhow::Result<()> {
    let scheduler_list: String = args.require("schedulers")?;
    let mut schedulers: Vec<SchedulerKind> = csv_items(&scheduler_list)
        .into_iter()
        .map(SchedulerKind::from_name)
        .collect::<anyhow::Result<_>>()?;
    anyhow::ensure!(
        !schedulers.is_empty(),
        "--schedulers must list at least one scheduler"
    );
    // `--pools` retargets every hierarchical scheduler in the list; a
    // malformed topology is a hard error before any cell runs.
    if let Some(arg) = args.get("pools").filter(|p| !p.trim().is_empty()) {
        let topology = Topology::from_arg(arg)?;
        let mut applied = false;
        for kind in &mut schedulers {
            if let SchedulerKind::Hierarchical(h) = kind {
                h.topology = topology.clone();
                applied = true;
            }
        }
        anyhow::ensure!(applied, "--pools requires a hier entry in --schedulers");
    }
    let nodes = parse_csv::<usize>(&args.require::<String>("nodes")?, "nodes")?;
    let seeds = parse_csv::<u64>(&args.require::<String>("seeds")?, "seeds")?;
    let scale: f64 = args.require("scale")?;
    let threads: usize = args.require("threads")?;
    let name: String = args.require("name")?;
    let out: PathBuf = args.require("out")?;
    let workload_name: String = args.require("workload")?;
    let workloads: Vec<WorkloadSpec> = match workload_name.as_str() {
        "fb" => vec![WorkloadSpec::Fb(FbWorkload::scaled(scale))],
        "fb-map-only" => vec![WorkloadSpec::FbMapOnly(FbWorkload::scaled(scale))],
        "fig7" => vec![WorkloadSpec::Fig7],
        // A load-factor sweep: one open-arrival workload axis value per
        // rate, each streamed (never materialized) by its cells.
        "open" => {
            let rates = parse_csv::<f64>(&args.require::<String>("rates")?, "rates")?;
            let duration: f64 = args.require("duration")?;
            anyhow::ensure!(
                duration > 0.0 && duration.is_finite(),
                "--duration must be positive and finite"
            );
            anyhow::ensure!(
                rates.iter().all(|r| *r > 0.0 && r.is_finite()),
                "--rates must all be positive and finite"
            );
            rates
                .into_iter()
                .map(|rate| WorkloadSpec::Open(OpenArrivals::poisson(rate, duration)))
                .collect()
        }
        // Zipf multi-tenant arrivals: same load-point axis as "open",
        // but every job carries a (pool, user) tenant identity drawn
        // from the population's private RNG substream.
        "population" => {
            let rates = parse_csv::<f64>(&args.require::<String>("rates")?, "rates")?;
            let duration: f64 = args.require("duration")?;
            let users: u64 = args.require("users")?;
            let tenant_pools: u32 = args.require("tenant-pools")?;
            let zipf_s: f64 = args.require("zipf-s")?;
            anyhow::ensure!(
                duration > 0.0 && duration.is_finite(),
                "--duration must be positive and finite"
            );
            anyhow::ensure!(
                rates.iter().all(|r| *r > 0.0 && r.is_finite()),
                "--rates must all be positive and finite"
            );
            anyhow::ensure!(
                users > 0 && users <= u64::from(u32::MAX),
                "--users must be in 1..=2^32-1"
            );
            anyhow::ensure!(tenant_pools > 0, "--tenant-pools must be positive");
            anyhow::ensure!(
                zipf_s > 0.0 && zipf_s.is_finite(),
                "--zipf-s must be positive and finite"
            );
            rates
                .into_iter()
                .map(|rate| {
                    WorkloadSpec::Population(
                        // Seed 0 is a placeholder: each sweep cell
                        // reseeds the template with its own seed.
                        TenantPopulation::new(users, tenant_pools, rate, duration, 0)
                            .skew(zipf_s),
                    )
                })
                .collect()
        }
        other => anyhow::bail!("unknown workload {other:?} (fb|fb-map-only|fig7|open|population)"),
    };

    // Faults axis: an explicit --faults list wins over the --grid preset.
    let fault_specs: Vec<FaultSpec> = match args.get("faults") {
        Some(list) if !list.trim().is_empty() => csv_items(list)
            .into_iter()
            .map(FaultSpec::from_name)
            .collect::<anyhow::Result<_>>()?,
        _ => match args.get("grid").unwrap_or("none") {
            "none" => Vec::new(),
            "faults" => FaultSpec::grid(),
            other => anyhow::bail!("unknown grid preset {other:?} (none|faults)"),
        },
    };

    let mut base = SimConfig::default();
    if let Some(name) = args.get("queue") {
        base.queue = QueueKind::from_name(name)?;
    }
    if let Some(limit) = args.get_parsed::<u64>("event-limit")? {
        if limit > 0 {
            base.event_limit = limit;
        }
    }

    let mut grid = ExperimentGrid::new(name)
        .base_config(base)
        .nodes(&nodes)
        .seeds(&seeds)
        .fault_scenarios(&fault_specs);
    for workload in workloads {
        grid = grid.workload(workload);
    }
    for kind in schedulers {
        grid = grid.scheduler(kind);
    }

    let results = run_grid_threads(&grid, threads);
    let report = results.aggregate();
    println!("{}", report.table());
    println!(
        "{} cells on {} threads in {:.0} ms ({} simulated events)",
        results.len(),
        results.threads,
        results.wall_ms,
        results.total_events()
    );

    if let Some(parent) = out.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(&out, report.to_json().to_string_pretty())?;
    println!("wrote aggregated sweep report to {}", out.display());

    // Truncated or stream-errored cells invalidate the aggregates:
    // surface a hard error (after writing the report, so the partial
    // data remains inspectable).
    let truncated: Vec<usize> = results
        .cells
        .iter()
        .filter(|c| c.outcome.stop == StopReason::EventLimit)
        .map(|c| c.spec.index)
        .collect();
    anyhow::ensure!(
        truncated.is_empty(),
        "{} cell(s) hit the event-count guard (indices {:?}) — raise --event-limit",
        truncated.len(),
        truncated
    );
    if let Some(c) = results.cells.iter().find(|c| c.outcome.stream_error.is_some()) {
        anyhow::bail!(
            "cell {} had an invalid workload stream: {}",
            c.spec.index,
            c.outcome.stream_error.as_deref().unwrap_or("unknown")
        );
    }
    Ok(())
}

/// The `bench` subcommand: timed simulations over the standard scenario
/// set, emitting the perf-trajectory record `BENCH_sim.json` (schema
/// `hfsp-bench/v2`: per scenario events/sec, wall-clock, queue stats and
/// peak RSS) and optionally gating against a committed baseline
/// (`--compare old.json --threshold 0.30`).
///
/// Scenarios (quick profile):
/// * `fb-{scale}x{nodes}` — the scaled closed FB workload, once per
///   registered scheduler (the historical v1 rows);
/// * `fig7-preemption` — the preemption microbenchmark on HFSP;
/// * `closed-fb2009` — the full-scale (1x) FB-2009 macro workload;
/// * `hot-churn` — the scaled FB workload under node-churn faults
///   (stale-chain lazy deletion + crash/requeue on the hot path);
/// * `open-1e5` — 100k tiny jobs streamed through an open HFSP session
///   at ≈60 % utilization (the headline streaming number);
/// * `hier-zipf` — the hierarchical scheduler under the Zipf
///   multi-tenant population source (10k users across 100 pools): the
///   share-tree + per-leaf discipline hot path;
/// * `sweep-4disc` — a single-threaded 4-discipline sweep cell
///   (mechanism + every ordering policy through the sweep engine);
/// * `par-open-1e6-serial` / `par-open-1e6` — a million streamed jobs
///   run serially and again under the fast shard merge on `--shards`
///   threads: the parallel-speedup row pair;
/// * `par-open-1e7-quick` — the production-scale scenario's quick
///   variant: a million jobs streamed over a 1000-node cluster under
///   the fast merge (ignores `--nodes`; the cluster size is the
///   scenario).
///
/// `--profile full` adds `open-1e6` (a million streamed jobs, serial,
/// the historical row) and `par-open-1e7` (ten million jobs over a
/// 10k-node cluster under the fast merge — the ROADMAP scale target).
///
/// `--scaling` adds the `par-scale-s{1,2,4,8}` shard-count sweep over
/// the million-job open stream and prints one `scaling speedup:` line
/// per shard count (the CI monotone-speedup assertion greps these).
///
/// `--merge-baseline new.json` runs no scenarios: it rewrites the
/// committed trajectory at `--out` from a CI-measured artifact (see
/// `merge_baseline_file`).
#[allow(clippy::too_many_lines)]
fn run_bench(args: &hfsp::util::cli::Args) -> anyhow::Result<()> {
    use hfsp::bench::{
        baseline_config_mismatch, compare_trajectories, parse_trajectory_text,
        trajectory_to_json, worst_regression, ScenarioRecord,
    };
    use hfsp::faults::FaultConfig;

    let scale: f64 = args.require("scale")?;
    let nodes: usize = args.require("nodes")?;
    let seed: u64 = args.require("seed")?;
    let out: PathBuf = args.require("out")?;
    // --merge-baseline: rewrite the committed trajectory from a
    // CI-measured artifact; no scenarios run.
    if let Some(artifact) = args.get("merge-baseline").filter(|p| !p.trim().is_empty()) {
        return merge_baseline_file(&out, artifact);
    }
    let threshold: f64 = args.require("threshold")?;
    let shards: usize = args.require("shards")?;
    anyhow::ensure!(shards > 0, "--shards must be positive");
    let queue = match args.get("queue") {
        Some(name) => QueueKind::from_name(name)?,
        None => QueueKind::default(),
    };
    let profile = args.get("profile").unwrap_or("quick");
    anyhow::ensure!(
        matches!(profile, "quick" | "full"),
        "unknown bench profile {profile:?} (quick|full)"
    );
    anyhow::ensure!(
        (0.0..=1.0).contains(&threshold),
        "--threshold must be a fraction in [0, 1]"
    );
    let cfg = SimConfig {
        cluster: ClusterConfig {
            nodes,
            ..Default::default()
        },
        seed,
        queue,
        ..Default::default()
    };
    let fb = FbWorkload::scaled(scale).generate(&mut RngStreams::workload(seed));
    let fig7 = synthetic::fig7_workload();

    /// An open HFSP session streaming `jobs` tiny jobs at ≈60 %
    /// utilization of the bench cluster: the WorkloadSource + probe
    /// path specifically.
    fn open_record(cfg: &SimConfig, jobs: u64, name: &'static str) -> ScenarioRecord {
        let task_s = 4.0;
        let slots = (cfg.cluster.nodes * cfg.cluster.map_slots).max(1) as f64;
        let rate = 0.6 * slots / task_s;
        let mut open = OpenArrivals::poisson(rate, f64::INFINITY)
            .mix(JobMix::Uniform { maps: 1, task_s })
            .max_jobs(jobs)
            .named(name);
        let outcome = run_session(cfg, SchedulerKind::hfsp(), &mut open, Vec::new());
        ScenarioRecord::from_outcome(name, &outcome)
    }

    let mut records: Vec<ScenarioRecord> = Vec::new();
    for entry in REGISTRY {
        let outcome = run_simulation(&cfg, entry.make(), &fb);
        records.push(ScenarioRecord::from_outcome(
            format!("fb-{scale}x{nodes}"),
            &outcome,
        ));
    }
    records.push(ScenarioRecord::from_outcome(
        "fig7-preemption",
        &run_simulation(&cfg, SchedulerKind::hfsp(), &fig7),
    ));
    // The paper's macro workload at full scale, closed replay.
    {
        let full = FbWorkload::default().generate(&mut RngStreams::workload(seed));
        records.push(ScenarioRecord::from_outcome(
            "closed-fb2009",
            &run_simulation(&cfg, SchedulerKind::hfsp(), &full),
        ));
    }
    // Node churn (no permanent losses, so the run always drains):
    // crash/requeue handling, chain invalidation and lazy deletion on
    // the hot path.
    {
        let churn = SimConfig {
            faults: FaultConfig {
                enabled: true,
                mtbf_s: 600.0,
                repair_s: 60.0,
                permanent_fraction: 0.0,
                ..FaultConfig::disabled()
            },
            ..cfg.clone()
        };
        records.push(ScenarioRecord::from_outcome(
            "hot-churn",
            &run_simulation(&churn, SchedulerKind::hfsp(), &fb),
        ));
    }
    records.push(open_record(&cfg, 100_000, "open-1e5"));
    if profile == "full" {
        records.push(open_record(&cfg, 1_000_000, "open-1e6"));
    }
    // Sharded throughput: the same million-job open stream run serially
    // and under the fast merge on `--shards` worker threads — the row
    // pair behind CI's parallel-speedup assertion. The barrier window
    // comes from --window (default: adaptive, base 30 s); cross-shard
    // tie order is relaxed here, with serial equivalence pinned
    // separately by the deterministic mode.
    let fast_shards = |count: usize| -> anyhow::Result<ShardSpec> {
        let mut spec = ShardSpec {
            count,
            merge: MergeMode::Fast,
            window_s: Some(30.0),
            auto_window: None,
        };
        match WindowArg::parse(args.get("window").unwrap_or("auto").trim())? {
            WindowArg::Fixed(w) => spec.window_s = Some(w),
            WindowArg::Auto(bounds) => spec.auto_window = Some(bounds),
        }
        Ok(spec)
    };
    {
        records.push(open_record(&cfg, 1_000_000, "par-open-1e6-serial"));
        let sharded = SimConfig {
            shards: fast_shards(shards)?,
            ..cfg.clone()
        };
        records.push(open_record(&sharded, 1_000_000, "par-open-1e6"));
        let eps = |name: &str| {
            records
                .iter()
                .find(|r| r.scenario == name)
                .map_or(0.0, |r| r.events_per_sec)
        };
        let serial_eps = eps("par-open-1e6-serial");
        if serial_eps > 0.0 {
            println!(
                "parallel speedup: {:.2}x ({shards} shards, fast merge)",
                eps("par-open-1e6") / serial_eps
            );
        }
    }
    // The production-scale target (ROADMAP item 1): an open stream over
    // a 10k-node cluster. The full profile drives the headline 10M-job
    // run; the quick profile keeps a scaled-down variant (1k nodes, 1M
    // jobs) under the armed compare gate so the scenario cannot rot
    // between full-profile runs. Both ignore --nodes: the cluster size
    // is the scenario.
    {
        let big = |nodes: usize, count: usize| -> anyhow::Result<SimConfig> {
            Ok(SimConfig {
                cluster: ClusterConfig {
                    nodes,
                    ..Default::default()
                },
                shards: fast_shards(count)?,
                ..cfg.clone()
            })
        };
        records.push(open_record(
            &big(1_000, shards)?,
            1_000_000,
            "par-open-1e7-quick",
        ));
        if profile == "full" {
            records.push(open_record(&big(10_000, shards)?, 10_000_000, "par-open-1e7"));
        }
    }
    // --scaling: the shard-count scaling sweep over the million-job
    // open stream — the speedup curve is measured, not asserted. One
    // row and one greppable line per shard count; wall time comes from
    // each outcome's own wall_ms (no extra clock reads here).
    if args.get_bool("scaling") {
        let counts = [1usize, 2, 4, 8];
        let names = ["par-scale-s1", "par-scale-s2", "par-scale-s4", "par-scale-s8"];
        for (&count, name) in counts.iter().zip(names) {
            let swept = SimConfig {
                shards: fast_shards(count)?,
                ..cfg.clone()
            };
            records.push(open_record(&swept, 1_000_000, name));
        }
        let base = records
            .iter()
            .find(|r| r.scenario == "par-scale-s1")
            .map_or(0.0, |r| r.events_per_sec);
        if base > 0.0 {
            for (&count, name) in counts.iter().zip(names) {
                let eps = records
                    .iter()
                    .find(|r| r.scenario == name)
                    .map_or(0.0, |r| r.events_per_sec);
                println!("scaling speedup: {:.2}x at {count} shards (fast merge)", eps / base);
            }
        }
    }
    // The hierarchy hot path: Zipf tenants from a 10k-user population
    // hashed across 100 pools, scheduled by the example 3-pool tree at
    // ≈60 % utilization (same load shape as open-1e5 so the two rows
    // are comparable).
    {
        let task_s = 4.0;
        let slots = (cfg.cluster.nodes * cfg.cluster.map_slots).max(1) as f64;
        let rate = 0.6 * slots / task_s;
        let mut pop = TenantPopulation::new(10_000, 100, rate, f64::INFINITY, seed)
            .mix(JobMix::Uniform { maps: 1, task_s })
            .max_jobs(20_000)
            .named("hier-zipf");
        let kind = SchedulerKind::Hierarchical(HierarchyConfig::default());
        let outcome = run_session(&cfg, kind, &mut pop, Vec::new());
        records.push(ScenarioRecord::from_outcome("hier-zipf", &outcome));
    }
    // One sweep cell per size-based discipline, single-threaded (the
    // sweep engine's per-cell overhead is part of what's measured).
    {
        let mut grid = ExperimentGrid::new("bench-4disc")
            .base_config(cfg.clone())
            .workload(WorkloadSpec::Fb(FbWorkload::scaled(scale)))
            .nodes(&[nodes])
            .seeds(&[seed]);
        for name in ["hfsp", "srpt", "las", "psbs"] {
            grid = grid.scheduler(SchedulerKind::from_name(name)?);
        }
        let results = run_grid_threads(&grid, 1);
        let events = results.total_events();
        let wall_ms = results.wall_ms;
        records.push(ScenarioRecord {
            scenario: "sweep-4disc".to_string(),
            scheduler: "ALL".to_string(),
            events,
            wall_ms,
            events_per_sec: if wall_ms > 0.0 {
                events as f64 / (wall_ms / 1e3)
            } else {
                0.0
            },
            makespan_s: 0.0,
            events_pushed: None,
            heap_peak: None,
            peak_rss_mb: hfsp::util::rss::peak_rss_mb(),
            queue: None,
        });
    }
    // Every row carries the backend it was measured under, so mixed-
    // backend baselines join per backend in --compare.
    let records: Vec<ScenarioRecord> = records
        .into_iter()
        .map(|r| r.with_queue(queue.name()))
        .collect();

    let fmt_opt_u64 = |v: Option<u64>| v.map_or_else(|| "-".to_string(), |x| x.to_string());
    let rows: Vec<Vec<String>> = records
        .iter()
        .map(|r| {
            vec![
                r.scenario.clone(),
                r.scheduler.clone(),
                r.events.to_string(),
                format!("{:.1}", r.wall_ms),
                format!("{:.0}", r.events_per_sec),
                fmt_opt_u64(r.events_pushed),
                fmt_opt_u64(r.heap_peak),
                r.peak_rss_mb
                    .map_or_else(|| "-".to_string(), |x| format!("{x:.0}")),
            ]
        })
        .collect();
    println!(
        "{}",
        report::table(
            &[
                "scenario",
                "scheduler",
                "events",
                "wall (ms)",
                "events/sec",
                "pushed",
                "heap peak",
                "peak RSS (MB)"
            ],
            &rows
        )
    );

    let mut j = trajectory_to_json(&records);
    j.set("profile", profile.into());
    j.set("nodes", nodes.into());
    j.set("scale", scale.into());
    j.set("seed", seed.into());
    j.set("queue", queue.name().into());
    j.set("shards", shards.into());
    if let Some(parent) = out.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(&out, j.to_string_pretty())?;
    println!("wrote benchmark record to {}", out.display());

    // --compare: delta table + regression gate against a baseline file.
    if let Some(path) = args.get("compare").filter(|p| !p.trim().is_empty()) {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading baseline {path}: {e}"))?;
        let (baseline_json, baseline) =
            parse_trajectory_text(&text).map_err(|e| anyhow::anyhow!("baseline {path}: {e}"))?;
        // Scenario names do not encode the bench configuration, so a
        // baseline recorded under different --nodes/--scale/--profile
        // would gate on a config artifact, not a code regression. A
        // mismatch means the baseline must be re-recorded. (The queue
        // backend is deliberately NOT checked here: it is a per-row join
        // key, so mixed-backend baselines compare per backend.)
        if let Some(diff) = baseline_config_mismatch(
            &baseline_json,
            &[
                ("nodes", Json::from(nodes)),
                ("scale", Json::from(scale)),
                ("profile", Json::from(profile)),
                ("shards", Json::from(shards)),
            ],
        ) {
            anyhow::bail!(
                "baseline {path} configuration mismatch ({diff}) — events/sec is not \
                 comparable across configurations; re-record the baseline with the \
                 current flags"
            );
        }
        let deltas = compare_trajectories(&baseline, &records);
        if deltas.is_empty() {
            anyhow::ensure!(
                !args.get_bool("require-baseline"),
                "bench --compare --require-baseline: baseline {path} shares no \
                 (scenario, scheduler, queue) rows with this run ({} baseline rows) — \
                 the regression gate would be vacuous; re-record the baseline",
                baseline.len()
            );
            println!(
                "bench --compare: no scenarios shared with {path} (empty seed baseline?) — \
                 nothing to gate"
            );
            return Ok(());
        }
        let delta_rows: Vec<Vec<String>> = deltas
            .iter()
            .map(|d| {
                vec![
                    d.scenario.clone(),
                    d.scheduler.clone(),
                    format!("{:.0}", d.old_events_per_sec),
                    format!("{:.0}", d.new_events_per_sec),
                    format!("{:+.1}%", d.delta() * 100.0),
                ]
            })
            .collect();
        println!(
            "{}",
            report::table(
                &["scenario", "scheduler", "old ev/s", "new ev/s", "delta"],
                &delta_rows
            )
        );
        let worst = worst_regression(&deltas);
        anyhow::ensure!(
            worst <= threshold,
            "events/sec regressed {:.1}% on the worst scenario (gate: {:.0}%) — \
             baseline {path}",
            worst * 100.0,
            threshold * 100.0
        );
        println!(
            "bench --compare: worst regression {:.1}% within the {:.0}% gate",
            worst * 100.0,
            threshold * 100.0
        );
    }
    Ok(())
}

/// `bench --merge-baseline new.json`: rewrite the committed trajectory
/// at `--out` from a CI-measured artifact. Rows join on (scenario,
/// scheduler, queue); artifact rows replace their committed
/// counterparts, unmatched artifact rows (freshly added scenarios) are
/// appended, and committed rows the artifact never measured (e.g. the
/// full profile's extra scenarios) are preserved. Config stamps must
/// agree (skip-if-absent semantics, same as `--compare`); the artifact's
/// stamps are carried into the rewritten file.
fn merge_baseline_file(out: &Path, artifact_path: &str) -> anyhow::Result<()> {
    use hfsp::bench::{
        baseline_config_mismatch, merge_baselines, parse_trajectory_text, trajectory_to_json,
    };
    let committed_text = std::fs::read_to_string(out)
        .map_err(|e| anyhow::anyhow!("reading committed trajectory {}: {e}", out.display()))?;
    let (committed_json, mut rows) = parse_trajectory_text(&committed_text)
        .map_err(|e| anyhow::anyhow!("committed trajectory {}: {e}", out.display()))?;
    let artifact_text = std::fs::read_to_string(artifact_path)
        .map_err(|e| anyhow::anyhow!("reading artifact {artifact_path}: {e}"))?;
    let (artifact_json, artifact_rows) = parse_trajectory_text(&artifact_text)
        .map_err(|e| anyhow::anyhow!("artifact {artifact_path}: {e}"))?;
    anyhow::ensure!(
        !artifact_rows.is_empty(),
        "artifact {artifact_path} has no trajectory rows — nothing to merge"
    );
    // The artifact must have been measured under the committed file's
    // configuration, else the merged rows would gate on a config
    // artifact rather than a code change.
    const STAMPS: [&str; 6] = ["nodes", "scale", "profile", "seed", "queue", "shards"];
    let current: Vec<(&str, Json)> = STAMPS
        .iter()
        .filter_map(|k| artifact_json.get(k).map(|v| (*k, v.clone())))
        .collect();
    if let Some(diff) = baseline_config_mismatch(&committed_json, &current) {
        anyhow::bail!(
            "artifact {artifact_path} configuration mismatch ({diff}) — re-measure the \
             artifact under the committed trajectory's flags"
        );
    }
    let (replaced, appended) = merge_baselines(&mut rows, &artifact_rows);
    let mut j = trajectory_to_json(&rows);
    for key in STAMPS {
        if let Some(v) = artifact_json.get(key).or_else(|| committed_json.get(key)) {
            j.set(key, v.clone());
        }
    }
    j.set(
        "note",
        "CI-measured perf-trajectory baseline for `hfsp bench --compare` (config per the \
         top-level stamps). Refresh after an intentional perf change: download the \
         BENCH_new.json artifact from the bench CI job and run `hfsp bench \
         --merge-baseline BENCH_new.json --out BENCH_sim.json`."
            .into(),
    );
    std::fs::write(out, j.to_string_pretty())?;
    println!(
        "merged {artifact_path} into {}: {replaced} row(s) replaced, {appended} appended",
        out.display()
    );
    Ok(())
}

/// Split a comma-separated flag value into trimmed, non-empty items.
fn csv_items(s: &str) -> Vec<&str> {
    s.split(',').map(str::trim).filter(|x| !x.is_empty()).collect()
}

/// Parse a comma-separated flag value into typed items.
fn parse_csv<T: std::str::FromStr>(s: &str, flag: &str) -> anyhow::Result<Vec<T>> {
    let items = csv_items(s);
    anyhow::ensure!(!items.is_empty(), "--{flag} must list at least one value");
    items
        .into_iter()
        .map(|item| {
            item.parse::<T>()
                .map_err(|_| anyhow::anyhow!("invalid value {item:?} for --{flag}"))
        })
        .collect()
}

fn maybe_write_json(path: Option<&str>, outcomes: &[&SimOutcome]) -> anyhow::Result<()> {
    let Some(path) = path else { return Ok(()) };
    let arr: Vec<Json> = outcomes
        .iter()
        .map(|o| {
            let mut j = o.sojourn.to_json();
            j.set("scheduler", o.scheduler.into());
            j.set("workload", o.workload.as_str().into());
            j.set("makespan_s", o.makespan.into());
            j.set("locality", o.locality.to_json());
            j.set("events", o.events_processed.into());
            j
        })
        .collect();
    std::fs::write(path, Json::Arr(arr).to_string_pretty())?;
    println!("wrote outcome summary to {path}");
    Ok(())
}

/// Print the Fig. 1 / Fig. 2 PS-vs-FSP intuition using the simulator on a
/// single node.
fn fsp_demo(slots: usize) {
    let cluster = ClusterConfig {
        nodes: 1,
        map_slots: slots,
        reduce_slots: 1,
        heartbeat_s: 0.5,
        ..Default::default()
    };
    let cfg = SimConfig {
        cluster,
        record_timelines: true,
        ..Default::default()
    };
    for (label, wl) in [
        ("Fig.1 (full-width jobs)", synthetic::fig1_workload(slots, 6)),
        ("Fig.2 (fractional jobs)", synthetic::fig2_workload(slots, 6)),
    ] {
        println!("=== {label} ===");
        for kind in [
            SchedulerKind::Fair(Default::default()),
            SchedulerKind::SizeBased(HfspConfig::default()),
        ] {
            let o = run_simulation(&cfg, kind, &wl);
            println!(
                "--- {} (mean sojourn {:.1} s) ---",
                o.scheduler,
                o.sojourn.mean()
            );
            print!("{}", o.timelines.ascii_chart(0.0, o.makespan, 72));
        }
    }
}
