//! Parallel grid execution: a work-stealing thread-pool fan-out over
//! independent cells.
//!
//! Cells are claimed from a shared atomic cursor (longest cells do not
//! stall a static partition) and each runs a full streaming session
//! ([`run_session`](crate::cluster::driver::run_session) over the
//! cell's [`WorkloadSpec::source`](crate::sweep::grid::WorkloadSpec))
//! on its own OS thread — open-arrival cells therefore never
//! materialize their job lists, even under full fan-out. Results are
//! written into a slot vector indexed by
//! [`CellSpec::index`], so [`SweepResults::cells`] is always in grid
//! order and every downstream aggregate is independent of thread count
//! and completion timing (asserted by `tests/integration_sweep.rs`).

use super::aggregate::SweepReport;
use super::grid::{CellSpec, ExperimentGrid};
use crate::cluster::driver::SimOutcome;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// One completed cell: its spec and the simulation outcome.
#[derive(Debug)]
pub struct CellResult {
    pub spec: CellSpec,
    pub outcome: SimOutcome,
}

/// All cells of one grid run, in grid (cell-index) order.
#[derive(Debug)]
pub struct SweepResults {
    pub name: String,
    pub cells: Vec<CellResult>,
    /// Worker threads actually used.
    pub threads: usize,
    /// Host wall-clock for the whole sweep, milliseconds.
    pub wall_ms: f64,
}

impl SweepResults {
    pub fn len(&self) -> usize {
        self.cells.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    /// Outcomes in grid order.
    pub fn outcomes(&self) -> impl Iterator<Item = &SimOutcome> {
        self.cells.iter().map(|c| &c.outcome)
    }

    /// Look up one cell's outcome by its axes. Intended for
    /// single-workload grids (every fig/table bench); with several
    /// workload axis values the lookup is ambiguous — use
    /// [`SweepResults::outcome_in`] instead (debug builds assert).
    pub fn outcome(&self, scheduler_label: &str, nodes: usize, seed: u64) -> Option<&SimOutcome> {
        let mut matches = self.cells.iter().filter(|c| {
            c.spec.scheduler_label == scheduler_label
                && c.spec.nodes == nodes
                && c.spec.seed == seed
        });
        let first = matches.next()?;
        debug_assert!(
            matches.all(|c| c.spec.workload.label() == first.spec.workload.label()),
            "ambiguous outcome({scheduler_label}, {nodes}, {seed}): \
             multiple workloads match; use outcome_in()"
        );
        Some(&first.outcome)
    }

    /// Look up one cell's outcome by all four axes (multi-workload
    /// grids).
    pub fn outcome_in(
        &self,
        workload_label: &str,
        scheduler_label: &str,
        nodes: usize,
        seed: u64,
    ) -> Option<&SimOutcome> {
        self.cells
            .iter()
            .find(|c| {
                c.spec.workload.label() == workload_label
                    && c.spec.scheduler_label == scheduler_label
                    && c.spec.nodes == nodes
                    && c.spec.seed == seed
            })
            .map(|c| &c.outcome)
    }

    /// Fold the per-cell outcomes into across-seed group statistics.
    pub fn aggregate(&self) -> SweepReport {
        SweepReport::from_cells(&self.name, &self.cells)
    }

    /// Total simulated events across all cells.
    pub fn total_events(&self) -> u64 {
        self.cells.iter().map(|c| c.outcome.events_processed).sum()
    }
}

/// Run a grid with one worker per available CPU (see
/// [`run_grid_threads`]).
pub fn run_grid(grid: &ExperimentGrid) -> SweepResults {
    run_grid_threads(grid, 0)
}

/// Run a grid on `threads` workers (`0` = available parallelism,
/// clamped to the cell count). Deterministic: the result vector and
/// every aggregate derived from it are identical for any thread count.
pub fn run_grid_threads(grid: &ExperimentGrid, threads: usize) -> SweepResults {
    let t0 = std::time::Instant::now();
    let cells = grid.cells();
    let n_cells = cells.len();
    let threads = effective_threads(threads, n_cells);
    log::info!(
        "sweep {:?}: {} cells on {} threads",
        grid.name(),
        n_cells,
        threads
    );

    let cells = if threads <= 1 {
        // Serial fallback (also the n_cells <= 1 path): no pool needed.
        cells
            .into_iter()
            .map(|spec| {
                let outcome = spec.run(grid.base());
                CellResult { spec, outcome }
            })
            .collect()
    } else {
        run_pool(grid, cells, threads)
    };

    SweepResults {
        name: grid.name().to_string(),
        cells,
        threads,
        wall_ms: t0.elapsed().as_secs_f64() * 1e3,
    }
}

fn effective_threads(requested: usize, n_cells: usize) -> usize {
    let hw = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let t = if requested == 0 { hw } else { requested };
    t.clamp(1, n_cells.max(1))
}

fn run_pool(grid: &ExperimentGrid, cells: Vec<CellSpec>, threads: usize) -> Vec<CellResult> {
    let slots: Vec<Mutex<Option<CellResult>>> =
        (0..cells.len()).map(|_| Mutex::new(None)).collect();
    let cursor = AtomicUsize::new(0);
    {
        let cells = &cells;
        let slots = &slots;
        let cursor = &cursor;
        let base = grid.base();
        std::thread::scope(|scope| {
            for _ in 0..threads {
                scope.spawn(move || loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= cells.len() {
                        break;
                    }
                    let spec = cells[i].clone();
                    let outcome = spec.run(base);
                    *slots[i].lock().unwrap() = Some(CellResult { spec, outcome });
                });
            }
        });
    }
    slots
        .into_iter()
        .map(|slot| {
            slot.into_inner()
                .expect("worker panicked while holding a result slot")
                .expect("every cell index was claimed and completed")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::SchedulerKind;
    use crate::sweep::grid::WorkloadSpec;

    fn tiny_grid() -> ExperimentGrid {
        ExperimentGrid::new("executor-test")
            .scheduler(SchedulerKind::Fifo)
            .scheduler(SchedulerKind::SizeBased(Default::default()))
            .workload(WorkloadSpec::UniformBatch {
                jobs: 2,
                maps_per_job: 3,
                task_s: 5.0,
            })
            .nodes(&[2])
            .seeds(&[1, 2])
    }

    #[test]
    fn serial_and_parallel_agree() {
        let grid = tiny_grid();
        let serial = run_grid_threads(&grid, 1);
        let parallel = run_grid_threads(&grid, 4);
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.cells.iter().zip(&parallel.cells) {
            assert_eq!(a.spec.index, b.spec.index);
            assert_eq!(a.spec.scheduler_label, b.spec.scheduler_label);
            assert_eq!(a.outcome.makespan, b.outcome.makespan);
            assert_eq!(a.outcome.events_processed, b.outcome.events_processed);
        }
    }

    #[test]
    fn results_are_in_grid_order() {
        let grid = tiny_grid();
        let results = run_grid_threads(&grid, 3);
        for (i, c) in results.cells.iter().enumerate() {
            assert_eq!(c.spec.index, i);
        }
        assert!(results.threads >= 1);
        assert!(results.total_events() > 0);
    }

    #[test]
    fn outcome_lookup_by_axes() {
        let grid = tiny_grid();
        let results = run_grid_threads(&grid, 2);
        assert!(results.outcome("FIFO", 2, 1).is_some());
        assert!(results.outcome("HFSP", 2, 2).is_some());
        assert!(results.outcome("FAIR", 2, 1).is_none());
        assert!(results.outcome("FIFO", 3, 1).is_none());
    }
}
