//! Across-seed aggregation of sweep cells into report-ready statistics.
//!
//! Cells are grouped by (workload, nodes, faults, scheduler); the seed
//! axis is folded into the statistics. Two kinds of aggregates are kept:
//!
//! * **across-seed moments** of per-seed scalars (mean sojourn, mean
//!   slowdown, locality fraction, makespan), from which a normal-
//!   approximation 95 % confidence interval is derived
//!   (`1.96 · s / √n`);
//! * **pooled per-job sojourns** across all seeds in the group, from
//!   which p50/p95/p99 are read (the distribution view behind the
//!   paper's ECDF figures).
//!
//! Everything is deterministic: groups are sorted by key, per-seed
//! values are folded in cell-index order, and wall-clock measurements
//! are excluded — so the JSON rendering of a report is byte-identical
//! across reruns and thread counts.
//!
//! Open-arrival cells ([`WorkloadSpec::Open`](super::grid::WorkloadSpec))
//! fold exactly like closed ones — their workload label (`open-r…`)
//! is the group key's workload axis, so sweeping several rates yields
//! one group per load point (the PSBS-style load-factor table).

use super::executor::CellResult;
use crate::job::JobClass;
use crate::report;
use crate::util::json::Json;
use crate::util::stats::{percentile, Moments};
use std::collections::BTreeMap;

/// Grouping key: everything but the seed axis. Field order is the sort
/// order; `faults` is `"none"` for fault-free groups, so grids without a
/// faults axis sort (and render) exactly as before.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct GroupKey {
    pub workload: String,
    pub nodes: usize,
    pub faults: String,
    pub scheduler: String,
}

/// Aggregated statistics for one (workload, nodes, scheduler) group.
#[derive(Clone, Debug)]
pub struct GroupStats {
    pub key: GroupKey,
    /// Seeds folded into this group, in cell order.
    pub seeds: Vec<u64>,
    /// Total finished jobs pooled over all seeds.
    pub jobs: usize,
    /// Across-seed moments of the per-seed mean sojourn (seconds).
    pub mean_sojourn: Moments,
    /// Across-seed moments of per-seed mean slowdown
    /// (sojourn / serialized job size; ≥ 1 up to scheduling overlap).
    pub mean_slowdown: Moments,
    /// Across-seed moments of the per-seed map-locality fraction
    /// (seeds with no map tasks are skipped).
    pub locality: Moments,
    /// Across-seed moments of the makespan (seconds).
    pub makespan: Moments,
    /// Across-seed moments of the per-seed per-class mean sojourn.
    pub class_means: BTreeMap<&'static str, Moments>,
    /// Across-seed moments of wasted work (seconds of discarded task
    /// progress — crash kills, preemption kills, speculative losers).
    pub wasted_work: Moments,
    /// Total re-executed task launches pooled over all seeds.
    pub re_executed: u64,
    /// Total node crashes pooled over all seeds.
    pub crashes: u64,
    /// Total speculative clone launches / wins pooled over all seeds.
    pub spec_launches: u64,
    pub spec_wins: u64,
    /// Mean-sojourn ratio vs the fault-free group with the same
    /// workload/nodes/scheduler (1.0 = no degradation); `None` for
    /// fault-free groups or when no baseline exists in the sweep.
    pub vs_fault_free: Option<f64>,
    /// All per-job sojourns in the group, sorted ascending.
    pooled_sojourns: Vec<f64>,
}

impl GroupStats {
    fn new(key: GroupKey) -> Self {
        Self {
            key,
            seeds: Vec::new(),
            jobs: 0,
            mean_sojourn: Moments::new(),
            mean_slowdown: Moments::new(),
            locality: Moments::new(),
            makespan: Moments::new(),
            class_means: BTreeMap::new(),
            wasted_work: Moments::new(),
            re_executed: 0,
            crashes: 0,
            spec_launches: 0,
            spec_wins: 0,
            vs_fault_free: None,
            pooled_sojourns: Vec::new(),
        }
    }

    /// Whether this group ran under a fault scenario.
    pub fn is_faulted(&self) -> bool {
        self.key.faults != "none"
    }

    fn fold(&mut self, cell: &CellResult) {
        let o = &cell.outcome;
        self.seeds.push(cell.spec.seed);
        self.jobs += o.sojourn.len();
        if !o.sojourn.is_empty() {
            self.mean_sojourn.push(o.sojourn.mean());
        }
        let mut slowdown = Moments::new();
        for rec in o.sojourn.records() {
            slowdown.push(rec.sojourn() / rec.true_size.max(1e-9));
        }
        if slowdown.count() > 0 {
            self.mean_slowdown.push(slowdown.mean());
        }
        let local = o.locality.fraction_local();
        if !local.is_nan() {
            self.locality.push(local);
        }
        self.makespan.push(o.makespan);
        self.wasted_work.push(o.faults.wasted_work_s);
        self.re_executed += o.faults.re_executed_tasks;
        self.crashes += o.faults.crashes;
        self.spec_launches += o.counters.speculative_launches;
        self.spec_wins += o.counters.speculative_wins;
        for class in JobClass::ALL {
            let m = o.sojourn.mean_class(class);
            if !m.is_nan() {
                self.class_means
                    .entry(class.name())
                    .or_insert_with(Moments::new)
                    .push(m);
            }
        }
        self.pooled_sojourns.extend(o.sojourn.sojourns());
    }

    fn finalize(&mut self) {
        self.pooled_sojourns.sort_by(|a, b| a.total_cmp(b));
    }

    /// Half-width of the normal-approximation 95 % confidence interval
    /// on the across-seed mean sojourn; 0 with fewer than two seeds.
    pub fn ci95_mean_sojourn(&self) -> f64 {
        let n = self.mean_sojourn.count();
        if n < 2 {
            0.0
        } else {
            1.96 * (self.mean_sojourn.sample_variance() / n as f64).sqrt()
        }
    }

    /// Percentile of the pooled per-job sojourns (`q` in `[0, 100]`);
    /// NaN for an empty group.
    pub fn sojourn_percentile(&self, q: f64) -> f64 {
        if self.pooled_sojourns.is_empty() {
            f64::NAN
        } else {
            percentile(&self.pooled_sojourns, q)
        }
    }

    /// The pooled, sorted per-job sojourns.
    pub fn pooled_sojourns(&self) -> &[f64] {
        &self.pooled_sojourns
    }

    fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("workload", self.key.workload.as_str().into());
        o.set("nodes", self.key.nodes.into());
        o.set("scheduler", self.key.scheduler.as_str().into());
        o.set("seeds", self.seeds.clone().into());
        o.set("jobs", self.jobs.into());
        o.set("mean_sojourn_s", self.mean_sojourn.mean().into());
        o.set("ci95_sojourn_s", self.ci95_mean_sojourn().into());
        o.set("p50_sojourn_s", self.sojourn_percentile(50.0).into());
        o.set("p95_sojourn_s", self.sojourn_percentile(95.0).into());
        o.set("p99_sojourn_s", self.sojourn_percentile(99.0).into());
        o.set("mean_slowdown", self.mean_slowdown.mean().into());
        o.set("map_locality", self.locality.mean().into());
        o.set("makespan_s", self.makespan.mean().into());
        let mut classes = Json::obj();
        for (name, m) in &self.class_means {
            classes.set(name, m.mean().into());
        }
        o.set("mean_sojourn_by_class_s", classes);
        // Fault metrics are emitted only for faulted groups, so grids
        // without a faults axis keep their historical byte-identical
        // JSON rendering.
        if self.is_faulted() {
            o.set("faults", self.key.faults.as_str().into());
            o.set("wasted_work_s", self.wasted_work.mean().into());
            o.set("re_executed_tasks", self.re_executed.into());
            o.set("crashes", self.crashes.into());
            o.set("speculative_launches", self.spec_launches.into());
            o.set("speculative_wins", self.spec_wins.into());
            if let Some(r) = self.vs_fault_free {
                o.set("sojourn_vs_fault_free", r.into());
            }
        }
        o
    }
}

/// A full aggregated sweep: one [`GroupStats`] per (workload, nodes,
/// scheduler), sorted by key.
#[derive(Clone, Debug)]
pub struct SweepReport {
    pub name: String,
    pub groups: Vec<GroupStats>,
}

impl SweepReport {
    /// Group and fold `cells` (in the given order, which the executor
    /// guarantees is grid order).
    pub fn from_cells(name: &str, cells: &[CellResult]) -> Self {
        let mut groups: BTreeMap<GroupKey, GroupStats> = BTreeMap::new();
        for cell in cells {
            let key = GroupKey {
                workload: cell.spec.workload.label(),
                nodes: cell.spec.nodes,
                faults: cell.spec.faults.label.clone(),
                scheduler: cell.spec.scheduler_label.clone(),
            };
            groups
                .entry(key.clone())
                .or_insert_with(|| GroupStats::new(key))
                .fold(cell);
        }
        let mut groups: Vec<GroupStats> = groups.into_values().collect();
        for g in &mut groups {
            g.finalize();
        }
        // Faulted groups report their sojourn degradation against the
        // fault-free group sharing the other axes, when the sweep ran one.
        let baselines: BTreeMap<(String, usize, String), f64> = groups
            .iter()
            .filter(|g| !g.is_faulted() && g.mean_sojourn.count() > 0)
            .map(|g| {
                (
                    (
                        g.key.workload.clone(),
                        g.key.nodes,
                        g.key.scheduler.clone(),
                    ),
                    g.mean_sojourn.mean(),
                )
            })
            .collect();
        for g in &mut groups {
            if g.is_faulted() && g.mean_sojourn.count() > 0 {
                let key = (
                    g.key.workload.clone(),
                    g.key.nodes,
                    g.key.scheduler.clone(),
                );
                if let Some(&base) = baselines.get(&key) {
                    if base > 0.0 {
                        g.vs_fault_free = Some(g.mean_sojourn.mean() / base);
                    }
                }
            }
        }
        Self {
            name: name.to_string(),
            groups,
        }
    }

    /// Find a group by its axes (fault-free groups only — the historical
    /// lookup; use [`SweepReport::group_faulted`] on faulted grids).
    pub fn group(&self, workload: &str, nodes: usize, scheduler: &str) -> Option<&GroupStats> {
        self.group_faulted(workload, nodes, "none", scheduler)
    }

    /// Find a group by all four axes.
    pub fn group_faulted(
        &self,
        workload: &str,
        nodes: usize,
        faults: &str,
        scheduler: &str,
    ) -> Option<&GroupStats> {
        self.groups.iter().find(|g| {
            g.key.workload == workload
                && g.key.nodes == nodes
                && g.key.faults == faults
                && g.key.scheduler == scheduler
        })
    }

    /// Render the paper-style aligned comparison table. Fault columns
    /// appear only when the sweep actually ran a fault scenario, keeping
    /// fault-free output identical to the historical rendering.
    pub fn table(&self) -> String {
        // Every stat can be absent (a group where no job finished, no
        // map task ran, ...): render those cells as "-" instead of NaN.
        let fmt_or_dash = |x: f64, f: &dyn Fn(f64) -> String| {
            if x.is_nan() {
                "-".to_string()
            } else {
                f(x)
            }
        };
        let faulted = self.groups.iter().any(GroupStats::is_faulted);
        let mut headers = vec!["workload", "nodes"];
        if faulted {
            headers.push("faults");
        }
        headers.extend_from_slice(&[
            "scheduler",
            "seeds",
            "jobs",
            "mean sojourn (s)",
            "ci95 (s)",
            "p50 (s)",
            "p99 (s)",
            "slowdown",
            "locality",
            "makespan (s)",
        ]);
        if faulted {
            headers.extend_from_slice(&["wasted (s)", "re-exec", "spec w/l", "vs none"]);
        }
        let rows: Vec<Vec<String>> = self
            .groups
            .iter()
            .map(|g| {
                let mut row = vec![g.key.workload.clone(), g.key.nodes.to_string()];
                if faulted {
                    row.push(g.key.faults.clone());
                }
                row.extend_from_slice(&[
                    g.key.scheduler.clone(),
                    g.seeds.len().to_string(),
                    g.jobs.to_string(),
                    fmt_or_dash(g.mean_sojourn.mean(), &|x| format!("{x:.1}")),
                    fmt_or_dash(g.ci95_mean_sojourn(), &|x| format!("{x:.1}")),
                    fmt_or_dash(g.sojourn_percentile(50.0), &|x| format!("{x:.1}")),
                    fmt_or_dash(g.sojourn_percentile(99.0), &|x| format!("{x:.1}")),
                    fmt_or_dash(g.mean_slowdown.mean(), &|x| format!("{x:.2}")),
                    fmt_or_dash(g.locality.mean(), &|x| format!("{:.1}%", x * 100.0)),
                    fmt_or_dash(g.makespan.mean(), &|x| format!("{x:.0}")),
                ]);
                if faulted {
                    row.push(if g.is_faulted() {
                        fmt_or_dash(g.wasted_work.mean(), &|x| format!("{x:.0}"))
                    } else {
                        "-".to_string()
                    });
                    row.push(if g.is_faulted() {
                        g.re_executed.to_string()
                    } else {
                        "-".to_string()
                    });
                    row.push(if g.is_faulted() {
                        format!("{}/{}", g.spec_wins, g.spec_launches)
                    } else {
                        "-".to_string()
                    });
                    row.push(match g.vs_fault_free {
                        Some(r) => format!("{r:.2}x"),
                        None => "-".to_string(),
                    });
                }
                row
            })
            .collect();
        report::table(&headers, &rows)
    }

    /// Deterministic JSON rendering (stable key and group order;
    /// wall-clock excluded), suitable for golden-file comparison.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("sweep", self.name.as_str().into());
        o.set(
            "groups",
            Json::Arr(self.groups.iter().map(GroupStats::to_json).collect()),
        );
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::SchedulerKind;
    use crate::sweep::executor::run_grid_threads;
    use crate::sweep::grid::{ExperimentGrid, WorkloadSpec};

    fn small_results() -> crate::sweep::executor::SweepResults {
        let grid = ExperimentGrid::new("agg-test")
            .scheduler(SchedulerKind::Fifo)
            .scheduler(SchedulerKind::SizeBased(Default::default()))
            .workload(WorkloadSpec::UniformBatch {
                jobs: 3,
                maps_per_job: 2,
                task_s: 4.0,
            })
            .nodes(&[2])
            .seeds(&[1, 2, 3]);
        run_grid_threads(&grid, 2)
    }

    #[test]
    fn groups_fold_seeds() {
        let report = small_results().aggregate();
        assert_eq!(report.groups.len(), 2, "one group per scheduler");
        for g in &report.groups {
            assert_eq!(g.seeds, vec![1, 2, 3]);
            assert_eq!(g.jobs, 9, "3 jobs x 3 seeds");
            assert_eq!(g.mean_sojourn.count(), 3);
            assert!(g.mean_sojourn.mean() > 0.0);
            assert!(g.sojourn_percentile(50.0) <= g.sojourn_percentile(99.0));
            assert!(g.mean_slowdown.mean() > 0.0);
        }
        assert!(report.group("uniform-3x2", 2, "FIFO").is_some());
        assert!(report.group("uniform-3x2", 2, "FAIR").is_none());
    }

    #[test]
    fn ci_is_zero_for_single_seed() {
        let grid = ExperimentGrid::new("one-seed")
            .scheduler(SchedulerKind::Fifo)
            .workload(WorkloadSpec::UniformBatch {
                jobs: 2,
                maps_per_job: 2,
                task_s: 3.0,
            })
            .nodes(&[2])
            .seeds(&[5]);
        let report = run_grid_threads(&grid, 1).aggregate();
        assert_eq!(report.groups[0].ci95_mean_sojourn(), 0.0);
    }

    #[test]
    fn json_and_table_render() {
        let report = small_results().aggregate();
        let json = report.to_json().to_string_pretty();
        assert!(json.contains("\"sweep\""));
        assert!(json.contains("\"mean_sojourn_s\""));
        let table = report.table();
        assert!(table.contains("FIFO"));
        assert!(table.contains("HFSP"));
        assert!(table.contains("mean sojourn (s)"));
    }

    #[test]
    fn fault_free_reports_carry_no_fault_keys_or_columns() {
        let report = small_results().aggregate();
        let json = report.to_json().to_string_pretty();
        assert!(!json.contains("\"faults\""));
        assert!(!json.contains("wasted_work_s"));
        assert!(!json.contains("sojourn_vs_fault_free"));
        let table = report.table();
        assert!(!table.contains("vs none"));
        assert!(!table.contains("wasted (s)"));
    }

    #[test]
    fn faulted_groups_report_metrics_and_degradation() {
        use crate::faults::FaultSpec;
        let grid = ExperimentGrid::new("faulted-agg")
            .scheduler(SchedulerKind::Fifo)
            .workload(WorkloadSpec::UniformBatch {
                jobs: 4,
                maps_per_job: 3,
                task_s: 30.0,
            })
            .nodes(&[3])
            .seeds(&[1, 2])
            .fault_scenario(FaultSpec::none())
            .fault_scenario(FaultSpec::stragglers());
        let report = run_grid_threads(&grid, 2).aggregate();
        assert_eq!(report.groups.len(), 2);
        let base = report
            .group_faulted("uniform-4x3", 3, "none", "FIFO")
            .expect("fault-free group");
        let faulted = report
            .group_faulted("uniform-4x3", 3, "stragglers", "FIFO")
            .expect("straggler group");
        assert!(!base.is_faulted());
        assert!(faulted.is_faulted());
        assert_eq!(base.vs_fault_free, None);
        let ratio = faulted.vs_fault_free.expect("baseline present");
        assert!(ratio > 0.0);
        // group() keeps finding the fault-free group.
        assert_eq!(
            report.group("uniform-4x3", 3, "FIFO").unwrap().key.faults,
            "none"
        );
        let json = report.to_json().to_string_pretty();
        assert!(json.contains("\"faults\""));
        assert!(json.contains("sojourn_vs_fault_free"));
        let table = report.table();
        assert!(table.contains("vs none"));
        assert!(table.contains("stragglers"));
    }
}
