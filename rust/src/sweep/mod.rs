//! Experiment sweep engine: declarative, parallel experiment grids.
//!
//! The paper's evidence is a *grid* of experiments — scheduler ×
//! workload × cluster size × preemption strategy — summarized as
//! sojourn-time statistics (Figs. 3–7, Tables). Before this module,
//! every figure was its own bench binary re-implementing the same run
//! loops serially; now a figure is a ~20-line **grid declaration**:
//!
//! ```no_run
//! use hfsp::prelude::*;
//!
//! let grid = ExperimentGrid::new("demo")
//!     .scheduler(SchedulerKind::Fifo)
//!     .scheduler(SchedulerKind::SizeBased(HfspConfig::default()))
//!     .workload(WorkloadSpec::Fb(FbWorkload::default()))
//!     .nodes(&[20, 100])
//!     .seeds(&[1, 2, 3]);
//! let results = run_grid(&grid);
//! println!("{}", results.aggregate().table());
//! ```
//!
//! Three layers:
//!
//! * [`grid`] — [`ExperimentGrid`], a builder over the cartesian product
//!   of scheduler kinds, [`WorkloadSpec`]s, cluster sizes, fault
//!   scenarios ([`crate::faults::FaultSpec`]) and seeds; each product
//!   element is a [`CellSpec`] with deterministic RNG seeding (the cell
//!   seed drives workload synthesis, HDFS placement and the fault plan
//!   through independent substreams, so a cell's outcome is a pure
//!   function of its spec);
//! * [`executor`] — [`run_grid`]/[`run_grid_threads`], a work-stealing
//!   thread-pool fan-out that runs independent cells concurrently.
//!   Results are stored by cell index, so the output order — and every
//!   aggregate derived from it — is **independent of thread timing**;
//! * [`aggregate`] — [`SweepReport`], folding per-cell
//!   [`SimOutcome`](crate::cluster::driver::SimOutcome)s into per-group
//!   (workload × nodes × scheduler) statistics across seeds: mean
//!   sojourn with a 95 % confidence interval, pooled sojourn
//!   percentiles, per-class means, mean slowdown, map locality and
//!   makespan — rendered through [`crate::report`] as an aligned table
//!   and as deterministic JSON (stable key order, byte-identical across
//!   reruns with the same grid).

pub mod aggregate;
pub mod executor;
pub mod grid;

pub use aggregate::{GroupStats, SweepReport};
pub use executor::{run_grid, run_grid_threads, CellResult, SweepResults};
pub use grid::{CellSpec, ExperimentGrid, WorkloadSpec};
