//! Experiment grid declaration: the cartesian product of scheduler,
//! workload, cluster size, fault scenario and seed, expanded into
//! runnable cells.
//!
//! A cell's outcome is a pure function of its [`CellSpec`] plus the
//! grid's base [`SimConfig`]: the cell seed is used both to synthesize
//! seed-dependent workloads ([`WorkloadSpec::realize`]) and as the
//! simulation master seed (HDFS placement), so re-running a grid with
//! the same seeds reproduces identical outcomes cell by cell.

use crate::cluster::driver::{run_session, SimConfig, SimOutcome};
use crate::faults::FaultSpec;
use crate::scheduler::SchedulerKind;
use crate::util::rng::{RngStreams, StreamId};
use crate::workload::swim::FbWorkload;
use crate::workload::{
    synthetic, ClosedSource, OpenArrivals, TenantPopulation, Workload, WorkloadSource,
};

/// A workload axis value: how to obtain the job trace for one cell.
///
/// Seed-dependent specs (`Fb`, `FbMapOnly`) synthesize a fresh workload
/// from the cell seed, so different seeds compare schedulers on
/// different (but per-seed identical) job sequences. Fixed specs ignore
/// the seed and present the exact same jobs to every cell.
#[derive(Clone, Debug)]
pub enum WorkloadSpec {
    /// SWIM-like FB-dataset synthesis (§4.1), generated from the cell
    /// seed.
    Fb(FbWorkload),
    /// FB-dataset with the reduce phase stripped (the paper's Fig. 6
    /// map-only variant), generated from the cell seed.
    FbMapOnly(FbWorkload),
    /// The Fig. 7 preemption micro-benchmark (5 reduce-only jobs);
    /// seed-independent.
    Fig7,
    /// `jobs` identical map-only jobs arriving together;
    /// seed-independent.
    UniformBatch {
        jobs: usize,
        maps_per_job: usize,
        task_s: f64,
    },
    /// Back-to-back jobs of geometrically decreasing size (§3.3
    /// hysteresis stressor); seed-independent.
    DecreasingSize {
        jobs: usize,
        width: usize,
        base_task_s: f64,
    },
    /// A pre-built workload (e.g. a replayed JSONL trace), presented
    /// as-is to every cell regardless of seed.
    Fixed(Workload),
    /// An open arrival-process template ([`OpenArrivals`]): each cell
    /// streams a fresh generator seeded from the cell seed's dedicated
    /// arrival substream. Several `Open` axis values with different
    /// rates express a PSBS-style load-factor sweep.
    Open(OpenArrivals),
    /// A Zipf tenant-population template ([`TenantPopulation`]): open
    /// arrivals whose jobs carry pool/user tenant ids, for the
    /// hierarchical-scheduler axis. The template is re-seeded from the
    /// cell seed, so the tenant sequence is a per-cell deterministic
    /// function of the grid seeds.
    Population(TenantPopulation),
}

impl WorkloadSpec {
    /// Stable label used in reports and group keys.
    pub fn label(&self) -> String {
        match self {
            WorkloadSpec::Fb(_) => "fb-dataset".to_string(),
            WorkloadSpec::FbMapOnly(_) => "fb-dataset-map-only".to_string(),
            WorkloadSpec::Fig7 => "fig7-preemption".to_string(),
            WorkloadSpec::UniformBatch {
                jobs, maps_per_job, ..
            } => format!("uniform-{jobs}x{maps_per_job}"),
            WorkloadSpec::DecreasingSize { jobs, .. } => format!("decreasing-{jobs}"),
            WorkloadSpec::Fixed(wl) => wl.name.clone(),
            WorkloadSpec::Open(template) => template.name().to_string(),
            WorkloadSpec::Population(template) => template.name().to_string(),
        }
    }

    /// Materialize the workload for one cell. Draws from the workload
    /// RNG stream ([`RngStreams::workload`] — the root generator, kept
    /// bit-compatible with the original derivation), which is independent
    /// of the placement and fault substreams. `Open` specs materialize
    /// by draining a fresh generator on the cell's arrival substream —
    /// the exact jobs a session for this cell would see (inspection
    /// only; [`CellSpec::run`] streams instead of materializing).
    pub fn realize(&self, seed: u64) -> Workload {
        match self {
            WorkloadSpec::Fb(params) => params.generate(&mut RngStreams::workload(seed)),
            WorkloadSpec::FbMapOnly(params) => {
                params.generate(&mut RngStreams::workload(seed)).map_only()
            }
            WorkloadSpec::Fig7 => synthetic::fig7_workload(),
            WorkloadSpec::UniformBatch {
                jobs,
                maps_per_job,
                task_s,
            } => synthetic::uniform_batch(*jobs, *maps_per_job, *task_s),
            WorkloadSpec::DecreasingSize {
                jobs,
                width,
                base_task_s,
            } => synthetic::decreasing_size_workload(*jobs, *width, *base_task_s),
            WorkloadSpec::Fixed(wl) => wl.clone(),
            WorkloadSpec::Open(template) => {
                assert!(
                    template.is_bounded(),
                    "open workload {:?} has no horizon or job cap — it would \
                     generate forever (sweep cells attach no halting probe)",
                    template.name()
                );
                let mut src = template.clone();
                let mut rng = RngStreams::new(seed).stream(StreamId::Arrivals);
                let jobs = std::iter::from_fn(|| src.next_job(&mut rng)).collect();
                Workload::new(src.name(), jobs).expect("open generator assigns unique ids")
            }
            WorkloadSpec::Population(template) => {
                assert!(
                    template.is_bounded(),
                    "population workload {:?} has no horizon or job cap — it \
                     would generate forever (sweep cells attach no halting probe)",
                    template.name()
                );
                let mut src = template.clone().reseed(seed);
                let mut rng = RngStreams::new(seed).stream(StreamId::Arrivals);
                let jobs = std::iter::from_fn(|| src.next_job(&mut rng)).collect();
                Workload::new(src.name(), jobs).expect("population assigns unique ids")
            }
        }
    }

    /// The streaming source a session for one cell consumes: closed
    /// specs replay their materialized job vector, `Open` specs hand
    /// out a fresh generator clone.
    pub fn source(&self, seed: u64) -> Box<dyn WorkloadSource> {
        match self {
            WorkloadSpec::Open(template) => {
                assert!(
                    template.is_bounded(),
                    "open workload {:?} has no horizon or job cap — a sweep \
                     cell could never drain it (no halting probe attached)",
                    template.name()
                );
                Box::new(template.clone())
            }
            WorkloadSpec::Population(template) => {
                assert!(
                    template.is_bounded(),
                    "population workload {:?} has no horizon or job cap — a \
                     sweep cell could never drain it (no halting probe attached)",
                    template.name()
                );
                Box::new(template.clone().reseed(seed))
            }
            closed => Box::new(ClosedSource::from(closed.realize(seed))),
        }
    }
}

/// One element of the cartesian product: a fully specified simulation.
#[derive(Clone, Debug)]
pub struct CellSpec {
    /// Position in the grid's deterministic cell order.
    pub index: usize,
    /// Display label of the scheduler axis value (distinguishes e.g.
    /// three HFSP preemption variants that all report `HFSP`).
    pub scheduler_label: String,
    pub scheduler: SchedulerKind,
    pub workload: WorkloadSpec,
    /// Cluster size for this cell (overrides the base config's).
    pub nodes: usize,
    /// Master seed: workload synthesis + HDFS placement + fault plan.
    pub seed: u64,
    /// Fault scenario for this cell (overrides the base config's;
    /// [`FaultSpec::none`] on grids without a faults axis).
    pub faults: FaultSpec,
}

impl CellSpec {
    /// The cell's effective simulation config.
    pub fn config(&self, base: &SimConfig) -> SimConfig {
        let mut cfg = base.clone();
        cfg.cluster.nodes = self.nodes;
        cfg.seed = self.seed;
        cfg.faults = self.faults.config.clone();
        cfg
    }

    /// Run this cell to completion (deterministic given `base`): the
    /// workload streams through its [`WorkloadSpec::source`], so open
    /// cells never materialize their job list.
    pub fn run(&self, base: &SimConfig) -> SimOutcome {
        let mut source = self.workload.source(self.seed);
        let mut scheduler = self.scheduler.clone();
        // The scenario's estimation error lives inside HFSP's training
        // module: wire it into the scheduler config, seeded from the cell
        // seed so it is reproducible but independent across seeds.
        // Explicit per-scheduler error settings (e.g. the Fig. 6 bench)
        // win over the scenario; the `enabled` master switch gates it.
        scheduler.apply_fault_error(self.faults.config.effective_error_sigma(), self.seed);
        run_session(&self.config(base), scheduler, source.as_mut(), Vec::new())
    }
}

/// Builder for an experiment grid.
///
/// Empty axes fall back to sensible defaults when the grid is expanded
/// (see [`ExperimentGrid::cells`]): all three schedulers, the default
/// FB-dataset workload, the base config's cluster size, and the base
/// config's seed. A full paper table is therefore expressible as
/// `ExperimentGrid::new("t").nodes(&[100, 50, 30]).seeds(&[42, 7, 1234])`.
#[derive(Clone, Debug)]
pub struct ExperimentGrid {
    name: String,
    schedulers: Vec<(String, SchedulerKind)>,
    workloads: Vec<WorkloadSpec>,
    nodes: Vec<usize>,
    seeds: Vec<u64>,
    faults: Vec<FaultSpec>,
    base: SimConfig,
}

impl ExperimentGrid {
    pub fn new(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            schedulers: Vec::new(),
            workloads: Vec::new(),
            nodes: Vec::new(),
            seeds: Vec::new(),
            faults: Vec::new(),
            base: SimConfig::default(),
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// The config template cells are derived from.
    pub fn base(&self) -> &SimConfig {
        &self.base
    }

    /// Replace the base config (cluster shape, Δ, timeline recording…).
    /// Per-cell `nodes` and `seed` still override it.
    pub fn base_config(mut self, base: SimConfig) -> Self {
        self.base = base;
        self
    }

    /// Add a scheduler axis value labelled with [`SchedulerKind::label`].
    pub fn scheduler(self, kind: SchedulerKind) -> Self {
        let label = kind.label().to_string();
        self.scheduler_labeled(label, kind)
    }

    /// Add a scheduler axis value with an explicit label (needed when
    /// several configurations of the same scheduler are compared).
    pub fn scheduler_labeled(mut self, label: impl Into<String>, kind: SchedulerKind) -> Self {
        self.schedulers.push((label.into(), kind));
        self
    }

    /// Add a workload axis value.
    pub fn workload(mut self, spec: WorkloadSpec) -> Self {
        self.workloads.push(spec);
        self
    }

    /// Add cluster sizes to the nodes axis.
    pub fn nodes(mut self, sizes: &[usize]) -> Self {
        self.nodes.extend_from_slice(sizes);
        self
    }

    /// Add seeds to the seed axis.
    pub fn seeds(mut self, seeds: &[u64]) -> Self {
        self.seeds.extend_from_slice(seeds);
        self
    }

    /// Add one fault scenario to the faults axis. An empty axis defaults
    /// to the single fault-free scenario ([`FaultSpec::none`]), which
    /// expands to exactly the cells a pre-faults grid produced.
    pub fn fault_scenario(mut self, spec: FaultSpec) -> Self {
        self.faults.push(spec);
        self
    }

    /// Add several fault scenarios (e.g. [`FaultSpec::grid`]).
    pub fn fault_scenarios(mut self, specs: &[FaultSpec]) -> Self {
        self.faults.extend_from_slice(specs);
        self
    }

    fn effective_schedulers(&self) -> Vec<(String, SchedulerKind)> {
        if self.schedulers.is_empty() {
            [
                SchedulerKind::Fifo,
                SchedulerKind::Fair(Default::default()),
                SchedulerKind::SizeBased(Default::default()),
            ]
            .into_iter()
            .map(|k| (k.label().to_string(), k))
            .collect()
        } else {
            self.schedulers.clone()
        }
    }

    fn effective_workloads(&self) -> Vec<WorkloadSpec> {
        if self.workloads.is_empty() {
            vec![WorkloadSpec::Fb(FbWorkload::default())]
        } else {
            self.workloads.clone()
        }
    }

    fn effective_nodes(&self) -> Vec<usize> {
        if self.nodes.is_empty() {
            vec![self.base.cluster.nodes]
        } else {
            self.nodes.clone()
        }
    }

    fn effective_seeds(&self) -> Vec<u64> {
        if self.seeds.is_empty() {
            vec![self.base.seed]
        } else {
            self.seeds.clone()
        }
    }

    fn effective_faults(&self) -> Vec<FaultSpec> {
        if self.faults.is_empty() {
            vec![FaultSpec::none()]
        } else {
            self.faults.clone()
        }
    }

    /// Number of cells the grid expands to (the cartesian product size).
    pub fn len(&self) -> usize {
        self.effective_workloads().len()
            * self.effective_nodes().len()
            * self.effective_faults().len()
            * self.effective_seeds().len()
            * self.effective_schedulers().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Expand the cartesian product into cells, in deterministic order:
    /// workload (outer) × nodes × faults × seed × scheduler (inner).
    pub fn cells(&self) -> Vec<CellSpec> {
        let schedulers = self.effective_schedulers();
        let workloads = self.effective_workloads();
        let nodes = self.effective_nodes();
        let seeds = self.effective_seeds();
        let faults = self.effective_faults();
        let mut cells = Vec::with_capacity(self.len());
        for workload in &workloads {
            for &n in &nodes {
                for fault in &faults {
                    for &seed in &seeds {
                        for (label, kind) in &schedulers {
                            cells.push(CellSpec {
                                index: cells.len(),
                                scheduler_label: label.clone(),
                                scheduler: kind.clone(),
                                workload: workload.clone(),
                                nodes: n,
                                seed,
                                faults: fault.clone(),
                            });
                        }
                    }
                }
            }
        }
        cells
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cell_count_is_cartesian_product() {
        let grid = ExperimentGrid::new("t")
            .scheduler(SchedulerKind::Fifo)
            .scheduler(SchedulerKind::SizeBased(Default::default()))
            .workload(WorkloadSpec::Fig7)
            .nodes(&[2, 4, 8])
            .seeds(&[1, 2]);
        assert_eq!(grid.len(), 12); // 1 workload x 3 nodes x 2 seeds x 2 schedulers
        let cells = grid.cells();
        assert_eq!(cells.len(), grid.len());
        for (i, c) in cells.iter().enumerate() {
            assert_eq!(c.index, i);
        }
    }

    #[test]
    fn empty_axes_fall_back_to_defaults() {
        let grid = ExperimentGrid::new("defaults");
        // 3 schedulers x 1 workload x 1 nodes x 1 seed.
        assert_eq!(grid.len(), 3);
        let cells = grid.cells();
        assert_eq!(cells[0].nodes, grid.base().cluster.nodes);
        assert_eq!(cells[0].seed, grid.base().seed);
        assert_eq!(cells[0].scheduler_label, "FIFO");
        assert_eq!(cells[2].scheduler_label, "HFSP");
    }

    #[test]
    fn scheduler_varies_fastest() {
        let grid = ExperimentGrid::new("order")
            .scheduler(SchedulerKind::Fifo)
            .scheduler(SchedulerKind::Fair(Default::default()))
            .workload(WorkloadSpec::Fig7)
            .nodes(&[2, 4])
            .seeds(&[9]);
        let cells = grid.cells();
        assert_eq!(cells[0].scheduler_label, "FIFO");
        assert_eq!(cells[1].scheduler_label, "FAIR");
        assert_eq!(cells[0].nodes, 2);
        assert_eq!(cells[2].nodes, 4);
    }

    #[test]
    fn fb_realization_is_seed_deterministic() {
        let spec = WorkloadSpec::Fb(FbWorkload {
            n_small: 4,
            n_medium: 2,
            n_large: 0,
            ..Default::default()
        });
        let a = spec.realize(11);
        let b = spec.realize(11);
        let c = spec.realize(12);
        assert_eq!(a.len(), b.len());
        for (ja, jb) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(ja.submit_time, jb.submit_time);
            assert_eq!(ja.map_durations, jb.map_durations);
        }
        // A different seed must change the arrival pattern.
        assert!(a
            .jobs
            .iter()
            .zip(&c.jobs)
            .any(|(x, y)| x.submit_time.total_cmp(&y.submit_time).is_ne()));
    }

    #[test]
    fn cell_config_overrides_nodes_and_seed() {
        let grid = ExperimentGrid::new("cfg").nodes(&[7]).seeds(&[99]);
        let cells = grid.cells();
        let cfg = cells[0].config(grid.base());
        assert_eq!(cfg.cluster.nodes, 7);
        assert_eq!(cfg.seed, 99);
        assert!(!cfg.faults.enabled, "default faults axis is fault-free");
        assert_eq!(cells[0].faults.label, "none");
    }

    #[test]
    fn faults_axis_multiplies_the_grid() {
        let grid = ExperimentGrid::new("faulted")
            .scheduler(SchedulerKind::Fifo)
            .workload(WorkloadSpec::Fig7)
            .nodes(&[2])
            .seeds(&[1, 2])
            .fault_scenario(FaultSpec::none())
            .fault_scenario(FaultSpec::churn());
        assert_eq!(grid.len(), 4, "1 wl x 1 nodes x 2 faults x 2 seeds x 1 sched");
        let cells = grid.cells();
        // Faults vary slower than seeds: none/none then churn/churn.
        assert_eq!(cells[0].faults.label, "none");
        assert_eq!(cells[1].faults.label, "none");
        assert_eq!(cells[2].faults.label, "churn");
        assert_eq!(cells[3].faults.label, "churn");
        assert!(cells[2].config(grid.base()).faults.enabled);
    }

    #[test]
    fn open_spec_streams_the_jobs_realize_materializes() {
        use crate::workload::JobMix;
        let template = OpenArrivals::poisson(1.0, 50.0).mix(JobMix::Uniform {
            maps: 1,
            task_s: 2.0,
        });
        let spec = WorkloadSpec::Open(template);
        assert_eq!(spec.label(), "open-r1");
        let materialized = spec.realize(9);
        assert!(!materialized.is_empty());
        let grid = ExperimentGrid::new("open")
            .scheduler(SchedulerKind::Fifo)
            .workload(spec)
            .nodes(&[2])
            .seeds(&[9]);
        let outcome = grid.cells()[0].run(grid.base());
        // The streamed session sees exactly the jobs realize() lists.
        assert_eq!(outcome.jobs_arrived, materialized.len());
        assert_eq!(outcome.sojourn.len(), materialized.len());
        assert_eq!(outcome.workload, "open-r1");
        assert!(outcome.peak_live_jobs <= materialized.len());
    }

    #[test]
    fn error_scenario_wires_sigma_into_hfsp_cells() {
        let grid = ExperimentGrid::new("err")
            .scheduler(SchedulerKind::SizeBased(Default::default()))
            .workload(WorkloadSpec::UniformBatch {
                jobs: 2,
                maps_per_job: 2,
                task_s: 3.0,
            })
            .nodes(&[2])
            .seeds(&[4])
            .fault_scenario(FaultSpec::estimation_error());
        let cells = grid.cells();
        // The wiring happens inside run(); just exercise it end-to-end.
        let outcome = cells[0].run(grid.base());
        assert_eq!(outcome.sojourn.len(), 2, "jobs still finish under error");
    }
}
