//! # HFSP — the Hadoop Fair Sojourn Protocol
//!
//! A reproduction of *"Practical Size-based Scheduling for MapReduce
//! Workloads"* (a.k.a. *"HFSP: The Hadoop Fair Sojourn Protocol"*,
//! Pastorelli, Barbuzzi, Carra, Michiardi, 2013).
//!
//! HFSP is a size-based, preemptive job scheduler for Hadoop MapReduce.
//! It extends the Fair Sojourn Protocol (FSP) of Friedman & Henderson to a
//! multi-processor, two-phase (MAP/REDUCE) slotted cluster. The paper
//! notes that "the architecture underlying HFSP is suitable for any
//! size-based scheduling discipline" — this crate takes that literally
//! and splits the scheduler layer into **mechanism** and **policy**:
//!
//! * the shared **mechanism** ([`scheduler::core`]): on-line job-size
//!   estimation (Training module + pluggable estimator,
//!   [`scheduler::core::training`], [`scheduler::core::estimator`]), the
//!   max-min-fair **virtual cluster** PS reference
//!   ([`scheduler::core::virtual_cluster`]), and SUSPEND/RESUME/KILL
//!   **preemption** with a hysteresis guard on suspended-task memory
//!   pressure ([`scheduler::core::preemption`]);
//! * pluggable ordering **disciplines** ([`scheduler::disciplines`]):
//!   FSP (= the paper's HFSP), SRPT, size-oblivious LAS, and a
//!   PSBS-style late-binding virtual-time variant — all served by the
//!   one mechanism and selectable by name through the scheduler
//!   registry ([`scheduler::REGISTRY`]).
//!
//! Experiments run as **sessions** ([`session::Simulation`]): a pull-based
//! [`workload::WorkloadSource`] feeds jobs to the driver one arrival at a
//! time — a closed [`workload::Workload`] replay, an open Poisson/diurnal
//! generator ([`workload::OpenArrivals`]), or a streaming JSONL trace —
//! while [`metrics::Probe`]s observe the run incrementally and may stop
//! it early. Working memory scales with *concurrently active* jobs —
//! the workload's per-task duration vectors are never materialized, and
//! only a compact per-finished-job sojourn record accumulates — so open
//! runs of millions of jobs are first-class.
//!
//! The crate is organised as a three-layer system:
//!
//! * **L3 (this crate)** — the coordinator: a discrete-event Hadoop cluster
//!   simulator ([`sim`], [`cluster`]), the schedulers ([`scheduler`]:
//!   FIFO, FAIR and the size-based discipline family), the SWIM-like
//!   workload generator ([`workload`]),
//!   the fault & perturbation subsystem ([`faults`]: node churn,
//!   stragglers, speculative execution, estimation-error injection),
//!   metrics and report generation ([`metrics`], [`report`]).
//! * **L2/L1 (python, build time only)** — the estimator compute graph and
//!   its Pallas kernels, AOT-lowered to HLO text artifacts.
//! * **runtime** — loads the artifacts through PJRT and executes them from
//!   the scheduler hot path ([`runtime`]).
//!
//! Experiment grids (scheduler × workload × cluster size × seed) are
//! declared and executed through the [`sweep`] subsystem, which fans the
//! independent cells out over a thread pool and folds the outcomes into
//! across-seed statistics.
//!
//! ## Quickstart
//!
//! Run one session through the builder:
//!
//! ```no_run
//! use hfsp::prelude::*;
//!
//! let workload = FbWorkload::default().generate(&mut Pcg64::seed_from_u64(42));
//! let outcome = Simulation::new(SimConfig::default())
//!     .scheduler(SchedulerKind::SizeBased(HfspConfig::default()))
//!     .workload(workload.into_source())
//!     .run();
//! println!("mean sojourn: {:.1}s", outcome.sojourn.mean());
//! ```
//!
//! Open, rate-controlled arrivals (the PSBS/Dell'Amico scenario axis)
//! stream with O(active jobs) working state; a probe can stop at
//! steady state:
//!
//! ```no_run
//! use hfsp::prelude::*;
//!
//! let mut halt = JobLimitProbe::new(100_000);
//! let outcome = Simulation::new(SimConfig::default())
//!     .scheduler(SchedulerKind::hfsp())
//!     .workload(OpenArrivals::poisson(0.08, 1e9).max_jobs(1_000_000))
//!     .probe(&mut halt)
//!     .run();
//! println!("{} jobs, peak {} live", outcome.sojourn.len(), outcome.peak_live_jobs);
//! ```
//!
//! Any registered discipline is one `from_name` away (`"fifo"`,
//! `"fair"`, `"hfsp"`, `"srpt"`, `"las"`, `"psbs"`), and the closed-path
//! compat shim [`run_simulation`](cluster::driver::run_simulation) still
//! exists:
//!
//! ```no_run
//! use hfsp::prelude::*;
//!
//! let workload = FbWorkload::default().generate(&mut Pcg64::seed_from_u64(42));
//! let srpt = SchedulerKind::from_name("srpt").unwrap();
//! let outcome = run_simulation(&SimConfig::default(), srpt, &workload);
//! assert_eq!(outcome.scheduler, "SRPT");
//! ```
//!
//! Or declare a whole experiment grid and let the sweep engine run it in
//! parallel with across-seed confidence intervals:
//!
//! ```no_run
//! use hfsp::prelude::*;
//!
//! let grid = ExperimentGrid::new("fifo-vs-hfsp")
//!     .scheduler(SchedulerKind::Fifo)
//!     .scheduler(SchedulerKind::SizeBased(HfspConfig::default()))
//!     .workload(WorkloadSpec::Fb(FbWorkload::default()))
//!     .nodes(&[100, 50])
//!     .seeds(&[42, 7, 1234]);
//! let results = run_grid(&grid);
//! println!("{}", results.aggregate().table());
//! ```

pub mod bench;
pub mod cluster;
pub mod faults;
pub mod job;
pub mod lint;
pub mod metrics;
pub mod report;
pub mod runtime;
pub mod scheduler;
pub mod session;
pub mod sim;
pub mod sweep;
pub mod testkit;
pub mod util;
pub mod workload;

/// Convenient re-exports of the most frequently used types.
pub mod prelude {
    pub use crate::cluster::driver::{run_session, run_simulation, SimConfig, SimOutcome};
    pub use crate::cluster::ClusterConfig;
    pub use crate::faults::{FaultConfig, FaultSpec, FaultStats, SpeculationConfig};
    pub use crate::job::{JobClass, JobId, JobSpec, Phase, TenantId};
    pub use crate::metrics::sojourn::SojournStats;
    pub use crate::metrics::{jain_index, JobLimitProbe, Probe, ProbeEvent, TenantProbe};
    pub use crate::scheduler::core::{
        HfspConfig, PreemptionPrimitive, SizeBasedConfig,
    };
    pub use crate::scheduler::disciplines::DisciplineKind;
    pub use crate::scheduler::hierarchy::{HierarchyConfig, Topology};
    pub use crate::scheduler::SchedulerKind;
    pub use crate::session::Simulation;
    pub use crate::sweep::{
        run_grid, run_grid_threads, ExperimentGrid, SweepReport, SweepResults, WorkloadSpec,
    };
    pub use crate::util::rng::{Pcg64, Rng, SeedableRng};
    pub use crate::workload::swim::FbWorkload;
    pub use crate::workload::{
        ClosedSource, JobMix, OpenArrivals, TenantPopulation, Workload, WorkloadSource,
    };
}
