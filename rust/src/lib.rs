//! # HFSP — the Hadoop Fair Sojourn Protocol
//!
//! A reproduction of *"Practical Size-based Scheduling for MapReduce
//! Workloads"* (a.k.a. *"HFSP: The Hadoop Fair Sojourn Protocol"*,
//! Pastorelli, Barbuzzi, Carra, Michiardi, 2013).
//!
//! HFSP is a size-based, preemptive job scheduler for Hadoop MapReduce.
//! It extends the Fair Sojourn Protocol (FSP) of Friedman & Henderson to a
//! multi-processor, two-phase (MAP/REDUCE) slotted cluster:
//!
//! * a **virtual cluster** simulates max-min-fair processor sharing to
//!   obtain a projected PS completion order ([`scheduler::hfsp::virtual_cluster`]);
//! * the **real cluster** is scheduled in that order, focusing resources on
//!   the job that would finish first under PS ([`scheduler::hfsp`]);
//! * job sizes are **estimated on-line** by a Training module that samples
//!   task runtimes and fits a task-time distribution
//!   ([`scheduler::hfsp::training`], [`scheduler::hfsp::estimator`]);
//! * **preemption** is implemented with SUSPEND/RESUME primitives (with
//!   WAIT and KILL fallbacks and a hysteresis guard on suspended-task
//!   memory pressure) ([`scheduler::hfsp::preemption`]).
//!
//! The crate is organised as a three-layer system:
//!
//! * **L3 (this crate)** — the coordinator: a discrete-event Hadoop cluster
//!   simulator ([`sim`], [`cluster`]), the schedulers ([`scheduler`]:
//!   FIFO, FAIR and HFSP), the SWIM-like workload generator ([`workload`]),
//!   the fault & perturbation subsystem ([`faults`]: node churn,
//!   stragglers, speculative execution, estimation-error injection),
//!   metrics and report generation ([`metrics`], [`report`]).
//! * **L2/L1 (python, build time only)** — the estimator compute graph and
//!   its Pallas kernels, AOT-lowered to HLO text artifacts.
//! * **runtime** — loads the artifacts through PJRT and executes them from
//!   the scheduler hot path ([`runtime`]).
//!
//! Experiment grids (scheduler × workload × cluster size × seed) are
//! declared and executed through the [`sweep`] subsystem, which fans the
//! independent cells out over a thread pool and folds the outcomes into
//! across-seed statistics.
//!
//! ## Quickstart
//!
//! Run a single simulation:
//!
//! ```no_run
//! use hfsp::prelude::*;
//!
//! let cfg = SimConfig::default();
//! let workload = FbWorkload::default().generate(&mut Pcg64::seed_from_u64(42));
//! let outcome = run_simulation(&cfg, SchedulerKind::Hfsp(HfspConfig::default()), &workload);
//! println!("mean sojourn: {:.1}s", outcome.sojourn.mean());
//! ```
//!
//! Or declare a whole experiment grid and let the sweep engine run it in
//! parallel with across-seed confidence intervals:
//!
//! ```no_run
//! use hfsp::prelude::*;
//!
//! let grid = ExperimentGrid::new("fifo-vs-hfsp")
//!     .scheduler(SchedulerKind::Fifo)
//!     .scheduler(SchedulerKind::Hfsp(HfspConfig::default()))
//!     .workload(WorkloadSpec::Fb(FbWorkload::default()))
//!     .nodes(&[100, 50])
//!     .seeds(&[42, 7, 1234]);
//! let results = run_grid(&grid);
//! println!("{}", results.aggregate().table());
//! ```

pub mod bench;
pub mod cluster;
pub mod faults;
pub mod job;
pub mod metrics;
pub mod report;
pub mod runtime;
pub mod scheduler;
pub mod sim;
pub mod sweep;
pub mod testkit;
pub mod util;
pub mod workload;

/// Convenient re-exports of the most frequently used types.
pub mod prelude {
    pub use crate::cluster::driver::{run_simulation, SimConfig, SimOutcome};
    pub use crate::cluster::ClusterConfig;
    pub use crate::faults::{FaultConfig, FaultSpec, FaultStats, SpeculationConfig};
    pub use crate::job::{JobClass, JobId, JobSpec, Phase};
    pub use crate::metrics::sojourn::SojournStats;
    pub use crate::scheduler::hfsp::{HfspConfig, PreemptionPrimitive};
    pub use crate::scheduler::SchedulerKind;
    pub use crate::sweep::{
        run_grid, run_grid_threads, ExperimentGrid, SweepReport, SweepResults, WorkloadSpec,
    };
    pub use crate::util::rng::{Pcg64, Rng, SeedableRng};
    pub use crate::workload::swim::FbWorkload;
    pub use crate::workload::Workload;
}
