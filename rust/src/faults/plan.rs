//! Deterministic fault-plan compilation.
//!
//! A [`FaultPlan`] is the fully materialized perturbation schedule for
//! one simulation run: every node crash/recover instant plus the per-node
//! straggler slowdown multipliers. It is compiled **up front** from the
//! dedicated `Faults` RNG substream, in a fixed per-node draw order, so
//! the plan — and therefore the whole faulted run — is a pure function of
//! `(FaultConfig, node count, horizon, seed)`. The driver injects the
//! events through [`sim::Engine`](crate::sim::Engine) before the run
//! starts.

use super::FaultConfig;
use crate::util::rng::{exponential, log_normal, Pcg64, Rng};

/// What happens to a node at a [`FaultEvent`]'s instant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultEventKind {
    /// The node goes down: running and suspended tasks are killed and
    /// re-enter the pending queue; the node stops heartbeating.
    Crash,
    /// The node comes back empty and resumes heartbeating.
    Recover,
}

/// One scheduled node-state transition.
#[derive(Clone, Copy, Debug)]
pub struct FaultEvent {
    pub time: f64,
    pub node: usize,
    pub kind: FaultEventKind,
    /// For a crash: the node never recovers. Always `false` for recovers.
    pub permanent: bool,
}

/// The compiled perturbation schedule for one run.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    /// Crash/recover events, sorted by (time, node).
    pub events: Vec<FaultEvent>,
    /// Per-node slowdown multiplier (≥ 1; 1 = nominal speed).
    pub slowdowns: Vec<f64>,
    /// Crashes in `events` that are permanent (no matching recover).
    pub permanent_losses: u64,
}

impl FaultPlan {
    /// Compile the schedule for `nodes` nodes over `[0, horizon_s)`.
    ///
    /// Draw order is fixed (per node: straggler Bernoulli, then slowdown
    /// if straggling, then the crash/repair sequence) so the plan is
    /// reproducible and insensitive to which features are consumed later.
    pub fn compile(cfg: &FaultConfig, nodes: usize, horizon_s: f64, rng: &mut Pcg64) -> FaultPlan {
        let mut events = Vec::new();
        let mut slowdowns = vec![1.0; nodes];
        let mut permanent_losses = 0u64;
        for node in 0..nodes {
            if cfg.straggler_fraction > 0.0 && rng.gen_bool(cfg.straggler_fraction) {
                slowdowns[node] =
                    log_normal(rng, cfg.straggler_mu, cfg.straggler_sigma).max(1.0);
            }
            if cfg.mtbf_s > 0.0 {
                let mut t = exponential(rng, cfg.mtbf_s);
                while t < horizon_s {
                    let crash_index = events.len();
                    events.push(FaultEvent {
                        time: t,
                        node,
                        kind: FaultEventKind::Crash,
                        permanent: false,
                    });
                    if cfg.permanent_fraction > 0.0 && rng.gen_bool(cfg.permanent_fraction) {
                        events[crash_index].permanent = true;
                        permanent_losses += 1;
                        break;
                    }
                    let up = t + exponential(rng, cfg.repair_s.max(1.0));
                    if up >= horizon_s {
                        break;
                    }
                    events.push(FaultEvent {
                        time: up,
                        node,
                        kind: FaultEventKind::Recover,
                        permanent: false,
                    });
                    t = up + exponential(rng, cfg.mtbf_s);
                }
            }
        }
        events.sort_by(|a, b| a.time.total_cmp(&b.time).then(a.node.cmp(&b.node)));
        FaultPlan {
            events,
            slowdowns,
            permanent_losses,
        }
    }

    /// Work rate of `node` (1 = nominal, < 1 for stragglers).
    pub fn speed(&self, node: usize) -> f64 {
        1.0 / self.slowdowns[node]
    }

    pub fn n_stragglers(&self) -> u64 {
        self.slowdowns.iter().filter(|&&s| s > 1.0).count() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SeedableRng;

    fn rng(seed: u64) -> Pcg64 {
        Pcg64::seed_from_u64(seed)
    }

    #[test]
    fn disabled_config_compiles_to_empty_plan() {
        let plan = FaultPlan::compile(&FaultConfig::disabled(), 10, 1e6, &mut rng(1));
        assert!(plan.events.is_empty());
        assert!(plan.slowdowns.iter().all(|&s| s.total_cmp(&1.0).is_eq()));
        assert_eq!(plan.permanent_losses, 0);
        assert_eq!(plan.n_stragglers(), 0);
    }

    #[test]
    fn compilation_is_deterministic() {
        let cfg = FaultConfig::full();
        let a = FaultPlan::compile(&cfg, 50, 1e5, &mut rng(7));
        let b = FaultPlan::compile(&cfg, 50, 1e5, &mut rng(7));
        assert_eq!(a.events.len(), b.events.len());
        for (x, y) in a.events.iter().zip(&b.events) {
            assert_eq!(x.time, y.time);
            assert_eq!(x.node, y.node);
            assert_eq!(x.kind, y.kind);
        }
        assert_eq!(a.slowdowns, b.slowdowns);
        assert_eq!(a.permanent_losses, b.permanent_losses);
    }

    #[test]
    fn events_are_time_sorted_and_alternating_per_node() {
        let cfg = FaultConfig {
            enabled: true,
            mtbf_s: 1000.0,
            repair_s: 100.0,
            ..FaultConfig::disabled()
        };
        let plan = FaultPlan::compile(&cfg, 20, 50_000.0, &mut rng(3));
        assert!(!plan.events.is_empty(), "20 nodes x ~50 MTBFs must crash");
        for w in plan.events.windows(2) {
            assert!(w[0].time <= w[1].time, "events sorted by time");
        }
        // Per node the kinds strictly alternate, starting with Crash.
        for node in 0..20 {
            let kinds: Vec<FaultEventKind> = plan
                .events
                .iter()
                .filter(|e| e.node == node)
                .map(|e| e.kind)
                .collect();
            for (i, k) in kinds.iter().enumerate() {
                let expect = if i % 2 == 0 {
                    FaultEventKind::Crash
                } else {
                    FaultEventKind::Recover
                };
                assert_eq!(*k, expect, "node {node} event {i}");
            }
        }
    }

    #[test]
    fn permanent_crash_ends_a_node_sequence() {
        let cfg = FaultConfig {
            enabled: true,
            mtbf_s: 500.0,
            repair_s: 50.0,
            permanent_fraction: 1.0, // every crash is final
            ..FaultConfig::disabled()
        };
        let plan = FaultPlan::compile(&cfg, 10, 1e6, &mut rng(5));
        // Exactly one crash per node, no recoveries.
        assert_eq!(plan.events.len(), 10);
        assert!(plan
            .events
            .iter()
            .all(|e| e.kind == FaultEventKind::Crash && e.permanent));
        assert_eq!(plan.permanent_losses, 10);
    }

    #[test]
    fn straggler_sampling_respects_fraction_and_floor() {
        let cfg = FaultConfig {
            enabled: true,
            straggler_fraction: 0.5,
            ..FaultConfig::disabled()
        };
        let plan = FaultPlan::compile(&cfg, 1000, 0.0, &mut rng(9));
        let n = plan.n_stragglers();
        assert!((300..700).contains(&(n as usize)), "got {n} stragglers");
        for (i, &s) in plan.slowdowns.iter().enumerate() {
            assert!(s >= 1.0, "node {i} slowdown {s} below 1");
            assert!((plan.speed(i) - 1.0 / s).abs() < 1e-15);
        }
    }

    #[test]
    fn horizon_bounds_the_schedule() {
        let cfg = FaultConfig {
            enabled: true,
            mtbf_s: 100.0,
            repair_s: 10.0,
            ..FaultConfig::disabled()
        };
        let plan = FaultPlan::compile(&cfg, 5, 1_000.0, &mut rng(11));
        assert!(plan.events.iter().all(|e| e.time < 1_000.0));
    }
}
