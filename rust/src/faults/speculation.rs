//! Speculative execution: straggler mitigation by task cloning.
//!
//! Hadoop's speculative execution launches a second attempt of a task
//! whose progress lags its peers; the first attempt to finish wins and
//! the other is discarded. The simulator models it as a scheduling-level
//! policy driven from node heartbeats: after the scheduler's own actions
//! are applied, a node with a spare slot may offer it to a clone of the
//! currently slowest-projecting running task — but only when the clone,
//! restarted from scratch at the offering node's speed, would beat the
//! original's projected finish by a configurable margin.
//!
//! The decision logic lives here (pure function over the job table and
//! the per-node speed vector, so it is unit-testable); the mechanics —
//! slot reservation, the `SpecDone` race, first-finish-wins resolution,
//! wasted-work accounting — live in [`crate::cluster::driver`].

use crate::cluster::Cluster;
use crate::job::task::TaskState;
use crate::job::{JobTable, Phase, TaskRef};
use crate::sim::Time;

/// Speculative-execution policy parameters.
#[derive(Clone, Copy, Debug)]
pub struct SpeculationConfig {
    pub enabled: bool,
    /// Minimum wall-clock age of an attempt before it may be cloned
    /// (Hadoop waits for tasks to establish a progress rate).
    pub min_elapsed_s: f64,
    /// Clone only when `clone_time × margin < projected remaining time`
    /// of the original — guards against cloning near-finished tasks and
    /// against clone/original flapping between similar-speed nodes.
    pub margin: f64,
}

impl Default for SpeculationConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            min_elapsed_s: 60.0,
            margin: 1.2,
        }
    }
}

/// Pick the task to clone onto one free `phase` slot of `offer_node`.
///
/// Returns the running task with the **largest projected remaining wall
/// time** among those the clone would beat, or `None`. The scan walks
/// the cluster's per-node running lists — O(occupied slots), not
/// O(jobs × tasks) — and is deterministic: nodes are visited in id
/// order, running lists in their (deterministic) slot order, and ties
/// keep the first candidate.
#[allow(clippy::too_many_arguments)]
pub fn pick_speculation_candidate(
    cfg: &SpeculationConfig,
    jobs: &JobTable,
    cluster: &Cluster,
    speeds: &[f64],
    offer_node: usize,
    phase: Phase,
    now: Time,
    already_speculated: impl Fn(TaskRef) -> bool,
) -> Option<TaskRef> {
    let offer_speed = speeds[offer_node];
    let mut best: Option<(f64, TaskRef)> = None;
    for node in cluster.nodes() {
        if node.id == offer_node {
            continue;
        }
        for &task in node.running(phase) {
            if already_speculated(task) {
                continue;
            }
            let rt = jobs[&task.job].task(task);
            let TaskState::Running { started, .. } = rt.state else {
                debug_assert!(false, "cluster running list out of sync for {task}");
                continue;
            };
            if now - started < cfg.min_elapsed_s {
                continue;
            }
            let remaining_wall = rt.remaining(now) / speeds[node.id];
            let clone_wall = rt.total_work / offer_speed;
            if clone_wall * cfg.margin >= remaining_wall {
                continue; // the clone would not clearly win the race
            }
            if best.map(|(w, _)| remaining_wall > w).unwrap_or(true) {
                best = Some((remaining_wall, task));
            }
        }
    }
    best.map(|(_, t)| t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;
    use crate::job::{Job, JobClass, JobSpec};

    /// Build one map-only job plus a cluster with its launches applied.
    fn setup(
        n_nodes: usize,
        durations: &[f64],
        launches: &[(u32, usize, Time, f64)], // (index, node, started, speed)
    ) -> (JobTable, Cluster) {
        let mut job = Job::new(JobSpec {
            id: 1,
            name: "j1".into(),
            class: JobClass::Medium,
            tenant: crate::job::TenantId::default(),
            submit_time: 0.0,
            map_durations: durations.to_vec(),
            reduce_durations: vec![],
        });
        let mut cluster = Cluster::new(ClusterConfig {
            nodes: n_nodes,
            map_slots: 2,
            reduce_slots: 1,
            ..Default::default()
        });
        for &(index, node, started, speed) in launches {
            let t = TaskRef {
                job: 1,
                phase: Phase::Map,
                index,
            };
            job.task_mut(t).launch(node, started, false, speed);
            job.counts_mut(Phase::Map).on_launch();
            cluster.node_mut(node).start_task(t);
        }
        let mut jobs = JobTable::new();
        jobs.insert(1, job);
        (jobs, cluster)
    }

    fn cfg() -> SpeculationConfig {
        SpeculationConfig {
            enabled: true,
            min_elapsed_s: 10.0,
            margin: 1.2,
        }
    }

    #[test]
    fn clones_the_straggling_task() {
        // Node 1 runs at 1/4 speed; task 0 started at t=0 with 100 s of
        // work. At t=50 it has 87.5 work left => 350 s of wall remaining.
        // A clone on nominal node 0 takes 100 s: clear win.
        let speeds = [1.0, 0.25];
        let (jobs, cluster) = setup(2, &[100.0, 100.0], &[(0, 1, 0.0, 0.25)]);
        let pick = pick_speculation_candidate(
            &cfg(),
            &jobs,
            &cluster,
            &speeds,
            0,
            Phase::Map,
            50.0,
            |_| false,
        );
        assert_eq!(
            pick,
            Some(TaskRef {
                job: 1,
                phase: Phase::Map,
                index: 0
            })
        );
    }

    #[test]
    fn respects_min_elapsed() {
        let speeds = [1.0, 0.25];
        let (jobs, cluster) = setup(2, &[100.0], &[(0, 1, 0.0, 0.25)]);
        let pick = pick_speculation_candidate(
            &cfg(),
            &jobs,
            &cluster,
            &speeds,
            0,
            Phase::Map,
            5.0,
            |_| false,
        );
        assert_eq!(pick, None, "attempt younger than min_elapsed_s");
    }

    #[test]
    fn no_clone_when_original_would_win() {
        // Nominal-speed original with 100 s work, 80 s already done: 20 s
        // remaining; a clone restarts from scratch (100 s) and loses.
        let speeds = [1.0, 1.0];
        let (jobs, cluster) = setup(2, &[100.0], &[(0, 1, 0.0, 1.0)]);
        let pick = pick_speculation_candidate(
            &cfg(),
            &jobs,
            &cluster,
            &speeds,
            0,
            Phase::Map,
            80.0,
            |_| false,
        );
        assert_eq!(pick, None);
    }

    #[test]
    fn skips_already_speculated_and_same_node() {
        let speeds = [1.0, 0.25];
        let straggler = TaskRef {
            job: 1,
            phase: Phase::Map,
            index: 0,
        };
        let (jobs, cluster) = setup(2, &[100.0], &[(0, 1, 0.0, 0.25)]);
        let pick = pick_speculation_candidate(
            &cfg(),
            &jobs,
            &cluster,
            &speeds,
            0,
            Phase::Map,
            50.0,
            |t| t == straggler,
        );
        assert_eq!(pick, None, "existing clone suppresses another");
        // Offering a slot on the straggler's own node never clones there.
        let pick = pick_speculation_candidate(
            &cfg(),
            &jobs,
            &cluster,
            &speeds,
            1,
            Phase::Map,
            50.0,
            |_| false,
        );
        assert_eq!(pick, None);
    }

    #[test]
    fn picks_the_slowest_of_several() {
        // Two stragglers at different severities: the slower one (node 2,
        // speed 0.1) projects the longer remaining time and is picked.
        let speeds = [1.0, 0.5, 0.1];
        let (jobs, cluster) =
            setup(3, &[100.0, 100.0], &[(0, 1, 0.0, 0.5), (1, 2, 0.0, 0.1)]);
        let pick = pick_speculation_candidate(
            &cfg(),
            &jobs,
            &cluster,
            &speeds,
            0,
            Phase::Map,
            50.0,
            |_| false,
        );
        assert_eq!(
            pick,
            Some(TaskRef {
                job: 1,
                phase: Phase::Map,
                index: 1
            })
        );
    }
}
