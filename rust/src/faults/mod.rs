//! Fault & perturbation subsystem: node churn, stragglers, speculative
//! execution, and size-estimation error injection.
//!
//! HFSP's core claim is that size-based scheduling stays *practical* when
//! reality diverges from its size estimates. This module supplies the
//! divergence, deterministically:
//!
//! * [`FaultPlan`] compiles a [`FaultConfig`] into a node crash/recover
//!   schedule (exponential MTBF, optionally permanent losses) plus
//!   per-node straggler slowdown multipliers, drawn from the dedicated
//!   `Faults` RNG substream ([`crate::util::rng::RngStreams`]) — so
//!   enabling faults never shifts workload or placement draws, and two
//!   runs with the same seed produce byte-identical outcomes;
//! * [`ErrorModel`] perturbs the HFSP estimator's output with a
//!   configurable multiplicative error (the paper's uniform Fig. 6 model
//!   or the log-normal model from Dell'Amico et al.'s robustness
//!   analysis);
//! * [`SpeculationConfig`]/[`pick_speculation_candidate`] implement
//!   Hadoop-style speculative execution: clone the slowest running task
//!   onto a free slot when the clone projects to finish first;
//!   first-finish wins, the loser's work is counted as wasted;
//! * [`FaultStats`] carries the run-level robustness metrics (wasted
//!   work, re-executed tasks, crash counts) into
//!   [`SimOutcome`](crate::cluster::driver::SimOutcome) and the sweep
//!   aggregates.
//!
//! The driver integration lives in [`crate::cluster::driver`]; the sweep
//! axis ([`FaultSpec`] per cell) in [`crate::sweep::grid`].

pub mod error_model;
pub mod plan;
pub mod speculation;

pub use error_model::{ErrorKind, ErrorModel};
pub use plan::{FaultEvent, FaultEventKind, FaultPlan};
pub use speculation::{pick_speculation_candidate, SpeculationConfig};

/// Perturbation-subsystem configuration. Disabled by default — a default
/// config leaves every simulation bit-identical to a fault-free run.
#[derive(Clone, Debug)]
pub struct FaultConfig {
    /// Master switch; `false` disables the whole subsystem regardless of
    /// the other fields.
    pub enabled: bool,
    /// Per-node mean time between crashes, seconds (exponential);
    /// `0` disables churn.
    pub mtbf_s: f64,
    /// Mean node repair time, seconds (exponential).
    pub repair_s: f64,
    /// Probability that a crash is permanent (the node never recovers).
    pub permanent_fraction: f64,
    /// Fraction of nodes that are stragglers.
    pub straggler_fraction: f64,
    /// Straggler slowdown multiplier: log-normal with this underlying
    /// normal mean/std, clamped to ≥ 1.
    pub straggler_mu: f64,
    pub straggler_sigma: f64,
    /// Speculative-execution policy.
    pub speculation: SpeculationConfig,
    /// σ of the log-normal (median-1) multiplicative error injected into
    /// HFSP's size estimates; `0` disables. Applied per HFSP cell by the
    /// sweep (the model lives inside the scheduler's training module).
    pub size_error_sigma: f64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        Self::disabled()
    }
}

impl FaultConfig {
    /// All perturbations off (the default).
    pub fn disabled() -> Self {
        Self {
            enabled: false,
            mtbf_s: 0.0,
            repair_s: 300.0,
            permanent_fraction: 0.0,
            straggler_fraction: 0.0,
            straggler_mu: std::f64::consts::LN_2, // median 2x slowdown
            straggler_sigma: 0.5,
            speculation: SpeculationConfig::default(),
            size_error_sigma: 0.0,
        }
    }

    /// Node churn only: crashes every ~8 h per node, 5 min mean repair,
    /// 5 % of crashes permanent.
    pub fn churn() -> Self {
        Self {
            enabled: true,
            mtbf_s: 8.0 * 3600.0,
            permanent_fraction: 0.05,
            ..Self::disabled()
        }
    }

    /// Straggler nodes (10 %, median 2× slowdown) with speculative
    /// execution enabled as the mitigation.
    pub fn stragglers() -> Self {
        Self {
            enabled: true,
            straggler_fraction: 0.1,
            speculation: SpeculationConfig {
                enabled: true,
                ..SpeculationConfig::default()
            },
            ..Self::disabled()
        }
    }

    /// Log-normal size-estimation error only (σ = 0.5).
    pub fn estimation_error() -> Self {
        Self {
            enabled: true,
            size_error_sigma: 0.5,
            ..Self::disabled()
        }
    }

    /// Whether speculative execution is active (the master switch gates
    /// every sub-feature, per the `enabled` contract).
    pub fn speculation_active(&self) -> bool {
        self.enabled && self.speculation.enabled
    }

    /// The size-estimation error σ actually in force (0 unless the
    /// subsystem as a whole is enabled).
    pub fn effective_error_sigma(&self) -> f64 {
        if self.enabled {
            self.size_error_sigma
        } else {
            0.0
        }
    }

    /// Everything at once: churn + stragglers + speculation + a milder
    /// estimation error (σ = 0.3). The default "faulted" scenario.
    pub fn full() -> Self {
        Self {
            enabled: true,
            mtbf_s: 8.0 * 3600.0,
            permanent_fraction: 0.05,
            straggler_fraction: 0.1,
            speculation: SpeculationConfig {
                enabled: true,
                ..SpeculationConfig::default()
            },
            size_error_sigma: 0.3,
            ..Self::disabled()
        }
    }
}

/// A labelled fault scenario — one value of the sweep's faults axis.
#[derive(Clone, Debug)]
pub struct FaultSpec {
    /// Stable label used in group keys, reports and CLI (`"none"` means
    /// fault-free and suppresses all fault columns/keys in reports).
    pub label: String,
    pub config: FaultConfig,
}

impl FaultSpec {
    pub fn new(label: impl Into<String>, config: FaultConfig) -> Self {
        Self {
            label: label.into(),
            config,
        }
    }

    /// The fault-free scenario (the implicit default axis value).
    pub fn none() -> Self {
        Self::new("none", FaultConfig::disabled())
    }

    pub fn churn() -> Self {
        Self::new("churn", FaultConfig::churn())
    }

    pub fn stragglers() -> Self {
        Self::new("stragglers", FaultConfig::stragglers())
    }

    pub fn estimation_error() -> Self {
        Self::new("error", FaultConfig::estimation_error())
    }

    pub fn full() -> Self {
        Self::new("full", FaultConfig::full())
    }

    /// Parse a scenario name (CLI `--faults` / `--grid faults` values).
    pub fn from_name(name: &str) -> anyhow::Result<FaultSpec> {
        match name.to_ascii_lowercase().as_str() {
            "none" => Ok(Self::none()),
            "churn" => Ok(Self::churn()),
            "stragglers" => Ok(Self::stragglers()),
            "error" => Ok(Self::estimation_error()),
            "full" => Ok(Self::full()),
            other => anyhow::bail!(
                "unknown fault scenario {other:?} (none|churn|stragglers|error|full)"
            ),
        }
    }

    /// The standard robustness grid: fault-free baseline plus every
    /// built-in scenario (`hfsp sweep --grid faults`, `fig_faults`).
    pub fn grid() -> Vec<FaultSpec> {
        vec![
            Self::none(),
            Self::churn(),
            Self::stragglers(),
            Self::estimation_error(),
            Self::full(),
        ]
    }
}

/// Run-level fault & robustness statistics, collected by the driver.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FaultStats {
    /// Node crash events applied.
    pub crashes: u64,
    /// Node recoveries applied.
    pub recoveries: u64,
    /// Crashes that were permanent (node lost for the rest of the run).
    pub permanent_losses: u64,
    /// Nodes with a slowdown multiplier > 1.
    pub straggler_nodes: u64,
    /// Running or suspended task attempts killed by node crashes.
    pub crash_task_kills: u64,
    /// Task launches that were re-executions (attempt ≥ 2, whatever the
    /// cause: crash kill or KILL preemption).
    pub re_executed_tasks: u64,
    /// Serialized work thrown away, seconds: progress of crash-killed and
    /// preemption-killed attempts plus the losing side of every
    /// speculative race.
    pub wasted_work_s: f64,
}

impl FaultStats {
    /// Fold another shard's stats into this one (sharded-run merge; every
    /// field is a sum over disjoint node sets).
    pub fn merge(&mut self, other: &FaultStats) {
        self.crashes += other.crashes;
        self.recoveries += other.recoveries;
        self.permanent_losses += other.permanent_losses;
        self.straggler_nodes += other.straggler_nodes;
        self.crash_task_kills += other.crash_task_kills;
        self.re_executed_tasks += other.re_executed_tasks;
        self.wasted_work_s += other.wasted_work_s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_inert() {
        let c = FaultConfig::default();
        assert!(!c.enabled);
        assert_eq!(c.mtbf_s, 0.0);
        assert_eq!(c.straggler_fraction, 0.0);
        assert!(!c.speculation.enabled);
        assert_eq!(c.size_error_sigma, 0.0);
    }

    #[test]
    fn scenarios_parse_by_name() {
        for name in ["none", "churn", "stragglers", "error", "full"] {
            let spec = FaultSpec::from_name(name).unwrap();
            assert_eq!(spec.label, name);
        }
        assert!(FaultSpec::from_name("bogus").is_err());
        assert!(FaultSpec::from_name("Churn").unwrap().config.enabled);
    }

    #[test]
    fn grid_leads_with_fault_free_baseline() {
        let grid = FaultSpec::grid();
        assert_eq!(grid[0].label, "none");
        assert!(!grid[0].config.enabled);
        assert!(grid.len() >= 4);
        assert!(grid[1..].iter().all(|s| s.config.enabled));
    }

    #[test]
    fn full_scenario_enables_everything() {
        let c = FaultConfig::full();
        assert!(c.enabled);
        assert!(c.mtbf_s > 0.0);
        assert!(c.straggler_fraction > 0.0);
        assert!(c.speculation.enabled);
        assert!(c.size_error_sigma > 0.0);
    }
}
