//! Multiplicative size-estimation error models.
//!
//! The paper's Fig. 6 perturbs HFSP's estimates with a uniform relative
//! error (`θ · (1 + U[-α, α])`); the follow-up robustness literature
//! (Dell'Amico, Carra, Michiardi — "Revisiting Size-Based Scheduling
//! with Estimated Job Sizes") models estimation error as a **log-normal
//! multiplicative factor** `θ · exp(N(0, σ))`, whose median is the exact
//! size and whose tails produce the order-inversions that break naive
//! SRPT-like disciplines. [`ErrorModel`] implements both behind one
//! seeded interface; the HFSP training module applies it to every final
//! estimate it delivers.

use crate::util::rng::{log_normal, Pcg64, Rng, SeedableRng};

/// Which multiplicative error distribution is applied.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ErrorKind {
    /// `factor = 1 + U[-α, α]` — the paper's Fig. 6 model.
    Uniform { alpha: f64 },
    /// `factor = exp(N(0, σ))` — median-1 log-normal error.
    LogNormal { sigma: f64 },
}

/// Seeded multiplicative error injector for job-size estimates.
#[derive(Clone, Debug)]
pub struct ErrorModel {
    kind: ErrorKind,
    rng: Pcg64,
}

impl ErrorModel {
    /// The Fig. 6 uniform model. Draw-compatible with the historical
    /// `ErrorInjector`: same seed + α ⇒ identical perturbation sequence.
    pub fn uniform(alpha: f64, seed: u64) -> Self {
        assert!(
            (0.0..=1.0).contains(&alpha),
            "uniform error alpha must be in [0, 1]"
        );
        Self {
            kind: ErrorKind::Uniform { alpha },
            rng: Pcg64::seed_from_u64(seed),
        }
    }

    /// Median-1 log-normal model with the given σ of the underlying
    /// normal.
    pub fn log_normal(sigma: f64, seed: u64) -> Self {
        assert!(sigma >= 0.0, "log-normal error sigma must be non-negative");
        Self {
            kind: ErrorKind::LogNormal { sigma },
            rng: Pcg64::seed_from_u64(seed),
        }
    }

    pub fn kind(&self) -> ErrorKind {
        self.kind
    }

    /// Apply one multiplicative perturbation (consumes RNG state).
    pub fn perturb(&mut self, size: f64) -> f64 {
        let factor = match self.kind {
            ErrorKind::Uniform { alpha } => 1.0 + self.rng.gen_range_f64(-alpha, alpha),
            ErrorKind::LogNormal { sigma } => log_normal(&mut self.rng, 0.0, sigma),
        };
        (size * factor).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_stays_in_bounds() {
        for seed in 0..20 {
            let mut m = ErrorModel::uniform(0.5, seed);
            for _ in 0..100 {
                let x = m.perturb(1000.0);
                assert!((500.0..=1500.0).contains(&x), "x={x}");
            }
        }
    }

    #[test]
    fn log_normal_is_positive_and_spreads() {
        let mut m = ErrorModel::log_normal(0.5, 3);
        let xs: Vec<f64> = (0..10_000).map(|_| m.perturb(100.0)).collect();
        assert!(xs.iter().all(|&x| x > 0.0));
        let above = xs.iter().filter(|&&x| x > 100.0).count();
        // Median-1 factor: about half the draws land above the true size.
        let frac = above as f64 / xs.len() as f64;
        assert!((0.45..0.55).contains(&frac), "frac above = {frac}");
        assert!(xs.iter().any(|&x| x > 150.0), "σ=0.5 must produce tails");
    }

    #[test]
    fn zero_sigma_is_exact() {
        let mut m = ErrorModel::log_normal(0.0, 1);
        for _ in 0..10 {
            assert_eq!(m.perturb(42.0), 42.0);
        }
    }

    #[test]
    fn same_seed_same_sequence() {
        let mut a = ErrorModel::log_normal(0.7, 9);
        let mut b = ErrorModel::log_normal(0.7, 9);
        for _ in 0..64 {
            assert_eq!(a.perturb(10.0), b.perturb(10.0));
        }
    }
}
