//! Arena-backed job table: the driver's live-job storage.
//!
//! The per-event hot path (heartbeat handling, task completion) looks a
//! job up by id several times per event. The original `BTreeMap` paid a
//! pointer-chasing descent per lookup and per iteration step; this table
//! is a **slab arena** instead:
//!
//! * [`Job`]s live in a dense `Vec<Option<Job>>`; a slot freed by a
//!   finished job is recycled (freelist), so slab indices stay compact
//!   and jobs never move once inserted — on streaming sessions the slab
//!   footprint is O(peak live jobs), not O(total jobs);
//! * id → slot is one [`FastMap`] hash (deterministic fixed-seed FxHash
//!   of a `u64`), making `get`/`get_mut`/`contains_key` O(1);
//! * iteration order is **ascending job id** — exactly the `BTreeMap`
//!   contract schedulers rely on for determinism — maintained as a
//!   sorted `(id, slot)` index updated only on arrival/eviction (the
//!   cold path), so hot-path iteration is a linear walk over a
//!   contiguous vector.
//!
//! The API mirrors the `BTreeMap<JobId, Job>` subset the driver and
//! schedulers used, so call sites read unchanged (`jobs[&id]`,
//! `jobs.get(&id)`, `jobs.values()`); an equivalence property test pins
//! the behavioural match (`tests/integration_perf.rs`).

use super::task::TaskRuntime;
use super::{Job, JobId, JobSpec};
use crate::util::fxmap::FastMap;
use std::ops::Index;

/// Retired task vectors kept per table for reuse (see
/// [`JobTable::build_job`] / [`JobTable::recycle`]). Beyond this many
/// the extras are dropped: open streams rarely hold more distinct
/// live jobs than this, and an unbounded pool would pin the high-water
/// footprint forever.
const TASK_VEC_POOL_CAP: usize = 1024;

/// Dense slab of live jobs with O(1) id lookups and id-ordered
/// iteration. See the module docs for the layout rationale.
#[derive(Default)]
pub struct JobTable {
    /// Slab storage; `None` slots are recyclable.
    slots: Vec<Option<Job>>,
    /// Recycled slot indices.
    free: Vec<u32>,
    /// id → slab slot.
    by_id: FastMap<JobId, u32>,
    /// Live `(id, slot)` pairs, sorted ascending by id.
    ordered: Vec<(JobId, u32)>,
    /// Retired `TaskRuntime` vectors (maps and reduces alike),
    /// recycled into the next [`build_job`](Self::build_job) instead of
    /// allocating fresh. Capacity-only state: contents are cleared
    /// before reuse, so pooling is invisible to simulation behaviour.
    task_vec_pool: Vec<Vec<TaskRuntime>>,
}

impl JobTable {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.ordered.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ordered.is_empty()
    }

    /// Capacity of the slab (diagnostics: high-water mark of live jobs).
    pub fn slab_capacity(&self) -> usize {
        self.slots.len()
    }

    pub fn contains_key(&self, id: &JobId) -> bool {
        self.by_id.contains_key(id)
    }

    pub fn get(&self, id: &JobId) -> Option<&Job> {
        let slot = *self.by_id.get(id)?;
        self.slots[slot as usize].as_ref()
    }

    pub fn get_mut(&mut self, id: &JobId) -> Option<&mut Job> {
        let slot = *self.by_id.get(id)?;
        self.slots[slot as usize].as_mut()
    }

    /// Insert a job under `id`. Replaces and returns any existing entry
    /// (matching the map contract; the driver treats duplicates as a
    /// stream error before ever calling this).
    pub fn insert(&mut self, id: JobId, job: Job) -> Option<Job> {
        if let Some(&slot) = self.by_id.get(&id) {
            return self.slots[slot as usize].replace(job);
        }
        let slot = match self.free.pop() {
            Some(s) => {
                self.slots[s as usize] = Some(job);
                s
            }
            None => {
                let s = u32::try_from(self.slots.len()).expect("job slab exceeds u32 slots");
                self.slots.push(Some(job));
                s
            }
        };
        self.by_id.insert(id, slot);
        let pos = self
            .ordered
            .binary_search_by_key(&id, |&(jid, _)| jid)
            .unwrap_err();
        self.ordered.insert(pos, (id, slot));
        None
    }

    pub fn remove(&mut self, id: &JobId) -> Option<Job> {
        let slot = self.by_id.remove(id)?;
        let pos = self
            .ordered
            .binary_search_by_key(id, |&(jid, _)| jid)
            .expect("indexed job present in ordered view");
        self.ordered.remove(pos);
        self.free.push(slot);
        self.slots[slot as usize].take()
    }

    /// Live jobs in ascending id (= submission) order.
    pub fn values(&self) -> impl Iterator<Item = &Job> {
        self.ordered
            .iter()
            .map(|&(_, slot)| self.slots[slot as usize].as_ref().expect("live slot"))
    }

    /// `(id, job)` pairs in ascending id order.
    pub fn iter(&self) -> impl Iterator<Item = (JobId, &Job)> {
        self.ordered.iter().map(|&(id, slot)| {
            (id, self.slots[slot as usize].as_ref().expect("live slot"))
        })
    }

    /// Live ids in ascending order.
    pub fn keys(&self) -> impl Iterator<Item = JobId> + '_ {
        self.ordered.iter().map(|&(id, _)| id)
    }

    /// Construct a [`Job`] for `spec`, reusing pooled task-vector
    /// capacity when available. Does **not** insert the job — the
    /// driver decides whether it enters the table (zero-task jobs
    /// finish immediately and never do).
    pub fn build_job(&mut self, spec: JobSpec) -> Job {
        let maps = self.task_vec_pool.pop().unwrap_or_default();
        let reduces = self.task_vec_pool.pop().unwrap_or_default();
        Job::new_with_buffers(spec, maps, reduces)
    }

    /// Retire a job removed from the table: its task vectors return to
    /// the pool (cleared, capacity kept) and its spec is handed back —
    /// the only part a cross-shard move needs to ship.
    pub fn recycle(&mut self, job: Job) -> JobSpec {
        let Job {
            spec, maps, reduces, ..
        } = job;
        for mut v in [maps, reduces] {
            if self.task_vec_pool.len() < TASK_VEC_POOL_CAP {
                v.clear();
                self.task_vec_pool.push(v);
            }
        }
        spec
    }

    /// Pooled task vectors currently idle (diagnostics/tests).
    pub fn pooled_task_vecs(&self) -> usize {
        self.task_vec_pool.len()
    }
}

impl Index<&JobId> for JobTable {
    type Output = Job;

    fn index(&self, id: &JobId) -> &Job {
        self.get(id).expect("no job for id")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::{JobClass, JobSpec, TenantId};

    fn job(id: JobId) -> Job {
        Job::new(JobSpec {
            id,
            name: format!("j{id}"),
            class: JobClass::Small,
            tenant: TenantId::default(),
            submit_time: 0.0,
            map_durations: vec![1.0],
            reduce_durations: vec![],
        })
    }

    #[test]
    fn insert_get_remove_round_trip() {
        let mut t = JobTable::new();
        assert!(t.is_empty());
        assert!(t.insert(5, job(5)).is_none());
        assert!(t.insert(1, job(1)).is_none());
        assert_eq!(t.len(), 2);
        assert!(t.contains_key(&5));
        assert_eq!(t.get(&1).unwrap().id(), 1);
        assert_eq!(t[&5].id(), 5);
        t.get_mut(&1).unwrap().maps_done = 1;
        assert_eq!(t.get(&1).unwrap().maps_done, 1);
        let removed = t.remove(&5).unwrap();
        assert_eq!(removed.id(), 5);
        assert!(t.get(&5).is_none());
        assert!(t.remove(&5).is_none());
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn iteration_is_ascending_id_order_regardless_of_insertion() {
        let mut t = JobTable::new();
        for id in [9, 2, 7, 1, 4] {
            t.insert(id, job(id));
        }
        let ids: Vec<JobId> = t.keys().collect();
        assert_eq!(ids, vec![1, 2, 4, 7, 9]);
        let via_values: Vec<JobId> = t.values().map(Job::id).collect();
        assert_eq!(via_values, ids);
        let via_iter: Vec<JobId> = t
            .iter()
            .map(|(id, j)| {
                assert_eq!(id, j.id());
                id
            })
            .collect();
        assert_eq!(via_iter, ids);
    }

    #[test]
    fn slots_are_recycled_so_the_slab_stays_bounded() {
        let mut t = JobTable::new();
        for round in 0..10u64 {
            for k in 0..4 {
                t.insert(round * 4 + k, job(round * 4 + k));
            }
            for k in 0..4 {
                t.remove(&(round * 4 + k)).unwrap();
            }
        }
        // 40 jobs passed through, but never more than 4 were live.
        assert_eq!(t.slab_capacity(), 4);
        assert!(t.is_empty());
    }

    #[test]
    fn build_and_recycle_reuse_task_vector_capacity() {
        let mut t = JobTable::new();
        let first = t.build_job(JobSpec {
            id: 1,
            name: "a".into(),
            class: JobClass::Small,
            tenant: TenantId::default(),
            submit_time: 0.0,
            map_durations: vec![1.0, 2.0, 3.0],
            reduce_durations: vec![4.0],
        });
        assert_eq!(first.maps.len(), 3);
        assert_eq!(first.reduces.len(), 1);
        assert!(first.is_untouched());
        let spec = t.recycle(first);
        assert_eq!(spec.id, 1);
        assert_eq!(t.pooled_task_vecs(), 2);
        // The next build consumes the pooled vectors and refills them
        // from its own spec — no stale tasks leak through.
        let second = t.build_job(JobSpec {
            id: 2,
            name: "b".into(),
            class: JobClass::Small,
            tenant: TenantId::default(),
            submit_time: 1.0,
            map_durations: vec![9.0],
            reduce_durations: vec![],
        });
        assert_eq!(t.pooled_task_vecs(), 0);
        assert_eq!(second.maps.len(), 1);
        assert_eq!(second.maps[0].total_work, 9.0);
        assert!(second.reduces.is_empty());
    }

    #[test]
    fn insert_replaces_existing_entry() {
        let mut t = JobTable::new();
        t.insert(3, job(3));
        let mut replacement = job(3);
        replacement.maps_done = 1;
        let old = t.insert(3, replacement).unwrap();
        assert_eq!(old.maps_done, 0);
        assert_eq!(t.get(&3).unwrap().maps_done, 1);
        assert_eq!(t.len(), 1);
    }
}
