//! Job and task model.
//!
//! A MapReduce job is two phases of tasks (MAP, then REDUCE once the map
//! output is materialized), following the Hadoop model described in §2.2 of
//! the paper. Per-task *true* durations are part of the [`JobSpec`] — they
//! are ground truth known to the simulator but **hidden from schedulers**,
//! which only observe task completions (and the Δ-progress reports used by
//! the reduce-size estimator, §3.2.1).

pub mod table;
pub mod task;

pub use table::JobTable;
pub use task::{TaskRef, TaskRuntime, TaskState};

use crate::sim::Time;

/// Job identifier (dense, assigned by the workload generator).
pub type JobId = u64;

/// MapReduce phase.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Phase {
    Map,
    Reduce,
}

impl Phase {
    pub fn name(self) -> &'static str {
        match self {
            Phase::Map => "map",
            Phase::Reduce => "reduce",
        }
    }
}

/// Job class, following the FB-dataset clustering of §4.1
/// (small: 1–2 maps; medium: 5–500 maps; large: the 6 biggest jobs).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum JobClass {
    Small,
    Medium,
    Large,
}

impl JobClass {
    pub fn name(self) -> &'static str {
        match self {
            JobClass::Small => "small",
            JobClass::Medium => "medium",
            JobClass::Large => "large",
        }
    }

    pub const ALL: [JobClass; 3] = [JobClass::Small, JobClass::Medium, JobClass::Large];
}

/// Submitting tenant of a job: the pool it was submitted through and the
/// user who submitted it. The default (`pool 0, user 0`) is the implicit
/// single-tenant world every pre-hierarchy workload generator lives in —
/// flat schedulers ignore the field entirely, so legacy runs stay
/// byte-identical.
///
/// The hierarchical scheduler routes jobs to leaf pools by `pool` (see
/// [`crate::scheduler::hierarchy`]); `user` feeds the intra-pool
/// fair-share layer and the per-tenant metrics probe
/// ([`crate::metrics::TenantProbe`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TenantId {
    /// Pool the job was submitted through.
    pub pool: u32,
    /// Submitting user within the pool.
    pub user: u32,
}

impl TenantId {
    pub fn new(pool: u32, user: u32) -> Self {
        Self { pool, user }
    }

    /// Whether this is the implicit single-tenant default.
    pub fn is_default(&self) -> bool {
        *self == TenantId::default()
    }
}

/// Immutable job description produced by the workload generator.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub id: JobId,
    pub name: String,
    pub class: JobClass,
    /// Submitting tenant (pool + user); [`TenantId::default`] for
    /// single-tenant workloads.
    pub tenant: TenantId,
    /// Submission (arrival) time, seconds.
    pub submit_time: Time,
    /// True duration of each MAP task, seconds (one HDFS block each).
    pub map_durations: Vec<f64>,
    /// True duration of each REDUCE task, seconds.
    pub reduce_durations: Vec<f64>,
}

impl JobSpec {
    pub fn n_maps(&self) -> usize {
        self.map_durations.len()
    }

    pub fn n_reduces(&self) -> usize {
        self.reduce_durations.len()
    }

    pub fn n_tasks(&self, phase: Phase) -> usize {
        match phase {
            Phase::Map => self.n_maps(),
            Phase::Reduce => self.n_reduces(),
        }
    }

    pub fn duration_of(&self, t: TaskRef) -> f64 {
        debug_assert_eq!(t.job, self.id);
        match t.phase {
            Phase::Map => self.map_durations[t.index as usize],
            Phase::Reduce => self.reduce_durations[t.index as usize],
        }
    }

    /// The paper's "serialized" job size for a phase: the **sum** of task
    /// runtimes, as if executed in series on one slot (§3.1, "The virtual
    /// cluster"). Ground-truth value, used by tests and the error-injection
    /// benchmark (Fig. 6).
    pub fn true_phase_size(&self, phase: Phase) -> f64 {
        match phase {
            Phase::Map => self.map_durations.iter().sum(),
            Phase::Reduce => self.reduce_durations.iter().sum(),
        }
    }

    /// Total serialized work over both phases.
    pub fn true_size(&self) -> f64 {
        self.true_phase_size(Phase::Map) + self.true_phase_size(Phase::Reduce)
    }
}

/// O(1) per-phase task-state counters, kept in sync by the driver on
/// every task transition (the schedulers read these on hot paths instead
/// of scanning task arrays).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PhaseCounts {
    pub pending: usize,
    pub running: usize,
    pub suspended: usize,
    pub done: usize,
}

impl PhaseCounts {
    fn new(n: usize) -> Self {
        Self {
            pending: n,
            ..Default::default()
        }
    }

    pub fn remaining(&self) -> usize {
        self.pending + self.running + self.suspended
    }

    pub fn on_launch(&mut self) {
        self.pending -= 1;
        self.running += 1;
    }
    pub fn on_suspend(&mut self) {
        self.running -= 1;
        self.suspended += 1;
    }
    pub fn on_resume(&mut self) {
        self.suspended -= 1;
        self.running += 1;
    }
    pub fn on_kill_running(&mut self) {
        self.running -= 1;
        self.pending += 1;
    }
    pub fn on_kill_suspended(&mut self) {
        self.suspended -= 1;
        self.pending += 1;
    }
    pub fn on_complete(&mut self) {
        self.running -= 1;
        self.done += 1;
    }
}

/// Runtime state of a job inside the simulator (driver-owned).
#[derive(Clone, Debug)]
pub struct Job {
    pub spec: JobSpec,
    pub maps: Vec<TaskRuntime>,
    pub reduces: Vec<TaskRuntime>,
    /// Completion counters (cached; kept in sync by the driver).
    pub maps_done: usize,
    pub reduces_done: usize,
    /// O(1) state counters per phase (driver-maintained).
    pub map_counts: PhaseCounts,
    pub reduce_counts: PhaseCounts,
    /// Set when the last task completes.
    pub finish_time: Option<Time>,
}

impl Job {
    pub fn new(spec: JobSpec) -> Self {
        Self::new_with_buffers(spec, Vec::new(), Vec::new())
    }

    /// Like [`Job::new`] but refilling caller-provided task vectors —
    /// the allocation-pooling entry point used by
    /// [`JobTable::build_job`](crate::job::JobTable::build_job).
    /// The buffers are cleared first, so recycled capacity carries no
    /// state from the previous occupant.
    pub fn new_with_buffers(
        spec: JobSpec,
        mut maps: Vec<TaskRuntime>,
        mut reduces: Vec<TaskRuntime>,
    ) -> Self {
        maps.clear();
        maps.extend(spec.map_durations.iter().map(|&d| TaskRuntime::new(d)));
        reduces.clear();
        reduces.extend(spec.reduce_durations.iter().map(|&d| TaskRuntime::new(d)));
        let map_counts = PhaseCounts::new(maps.len());
        let reduce_counts = PhaseCounts::new(reduces.len());
        Self {
            spec,
            maps,
            reduces,
            maps_done: 0,
            reduces_done: 0,
            map_counts,
            reduce_counts,
            finish_time: None,
        }
    }

    pub fn counts(&self, phase: Phase) -> &PhaseCounts {
        match phase {
            Phase::Map => &self.map_counts,
            Phase::Reduce => &self.reduce_counts,
        }
    }

    pub fn counts_mut(&mut self, phase: Phase) -> &mut PhaseCounts {
        match phase {
            Phase::Map => &mut self.map_counts,
            Phase::Reduce => &mut self.reduce_counts,
        }
    }

    pub fn id(&self) -> JobId {
        self.spec.id
    }

    pub fn task(&self, t: TaskRef) -> &TaskRuntime {
        debug_assert_eq!(t.job, self.spec.id);
        match t.phase {
            Phase::Map => &self.maps[t.index as usize],
            Phase::Reduce => &self.reduces[t.index as usize],
        }
    }

    pub fn task_mut(&mut self, t: TaskRef) -> &mut TaskRuntime {
        debug_assert_eq!(t.job, self.spec.id);
        match t.phase {
            Phase::Map => &mut self.maps[t.index as usize],
            Phase::Reduce => &mut self.reduces[t.index as usize],
        }
    }

    pub fn tasks(&self, phase: Phase) -> &[TaskRuntime] {
        match phase {
            Phase::Map => &self.maps,
            Phase::Reduce => &self.reduces,
        }
    }

    /// All map tasks have finished: reduce tasks become eligible (we model
    /// Hadoop's slowstart with α = 1: reducers are *scheduled* only when the
    /// whole intermediate output is available — the same simplification the
    /// paper's estimator makes, §3.2.1).
    pub fn map_phase_done(&self) -> bool {
        self.maps_done == self.maps.len()
    }

    pub fn is_finished(&self) -> bool {
        self.maps_done == self.maps.len() && self.reduces_done == self.reduces.len()
    }

    /// Whether no task of either phase has ever been launched: the job
    /// carries no per-shard runtime state and can move between shards
    /// (spillover / work-stealing) by shipping its spec alone.
    pub fn is_untouched(&self) -> bool {
        self.maps
            .iter()
            .chain(self.reduces.iter())
            .all(|t| t.state.is_pending() && t.attempts == 0)
    }

    /// Number of tasks of `phase` not yet launched (pending, never run or
    /// re-queued after a kill). O(1) via driver-maintained counters.
    pub fn pending_tasks(&self, phase: Phase) -> usize {
        self.counts(phase).pending
    }

    pub fn running_tasks(&self, phase: Phase) -> usize {
        self.counts(phase).running
    }

    pub fn suspended_tasks(&self, phase: Phase) -> usize {
        self.counts(phase).suspended
    }

    /// Remaining tasks (pending + running + suspended) of a phase.
    pub fn remaining_tasks(&self, phase: Phase) -> usize {
        self.counts(phase).remaining()
    }

    /// Debug validation: counters must agree with a full scan.
    #[cfg(debug_assertions)]
    pub fn validate_counts(&self) {
        for phase in [Phase::Map, Phase::Reduce] {
            let scan = |f: fn(&TaskState) -> bool| {
                self.tasks(phase).iter().filter(|t| f(&t.state)).count()
            };
            let c = self.counts(phase);
            assert_eq!(c.pending, scan(TaskState::is_pending), "pending desync");
            assert_eq!(c.running, scan(TaskState::is_running), "running desync");
            assert_eq!(c.suspended, scan(TaskState::is_suspended), "suspended desync");
            assert_eq!(c.done, scan(TaskState::is_done), "done desync");
        }
    }

    /// First pending task index of a phase, if any.
    pub fn next_pending(&self, phase: Phase) -> Option<TaskRef> {
        self.tasks(phase)
            .iter()
            .position(|t| t.state.is_pending())
            .map(|i| TaskRef {
                job: self.spec.id,
                phase,
                index: i as u32,
            })
    }

    /// Sojourn time (finish − submit), if finished.
    pub fn sojourn(&self) -> Option<f64> {
        self.finish_time.map(|f| f - self.spec.submit_time)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> JobSpec {
        JobSpec {
            id: 1,
            name: "j1".into(),
            class: JobClass::Medium,
            tenant: TenantId::default(),
            submit_time: 10.0,
            map_durations: vec![5.0, 7.0, 9.0],
            reduce_durations: vec![20.0],
        }
    }

    #[test]
    fn sizes_are_serialized_sums() {
        let s = spec();
        assert_eq!(s.n_maps(), 3);
        assert_eq!(s.n_reduces(), 1);
        assert!((s.true_phase_size(Phase::Map) - 21.0).abs() < 1e-12);
        assert!((s.true_phase_size(Phase::Reduce) - 20.0).abs() < 1e-12);
        assert!((s.true_size() - 41.0).abs() < 1e-12);
    }

    #[test]
    fn job_task_accessors() {
        let mut j = Job::new(spec());
        let t = TaskRef {
            job: 1,
            phase: Phase::Map,
            index: 2,
        };
        assert_eq!(j.spec.duration_of(t), 9.0);
        assert!(j.task(t).state.is_pending());
        j.task_mut(t).state = TaskState::Done;
        assert!(!j.task(t).state.is_pending());
    }

    #[test]
    fn phase_progression() {
        let mut j = Job::new(spec());
        assert!(!j.map_phase_done());
        assert_eq!(j.pending_tasks(Phase::Map), 3);
        for i in 0..3 {
            j.maps[i].state = TaskState::Done;
            j.maps_done += 1;
        }
        assert!(j.map_phase_done());
        assert!(!j.is_finished());
        j.reduces[0].state = TaskState::Done;
        j.reduces_done += 1;
        assert!(j.is_finished());
    }

    #[test]
    fn next_pending_scans_in_order() {
        let mut j = Job::new(spec());
        assert_eq!(j.next_pending(Phase::Map).unwrap().index, 0);
        j.maps[0].state = TaskState::Done;
        assert_eq!(j.next_pending(Phase::Map).unwrap().index, 1);
    }

    #[test]
    fn sojourn_requires_finish() {
        let mut j = Job::new(spec());
        assert_eq!(j.sojourn(), None);
        j.finish_time = Some(110.0);
        assert!((j.sojourn().unwrap() - 100.0).abs() < 1e-12);
    }
}
