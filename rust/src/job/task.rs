//! Task-level runtime state.
//!
//! HFSP's eager preemption (§3.3 of the paper) required the authors to
//! "introduce a new set of states associated to an Hadoop task"; this
//! module is the simulator's version of that extended state machine:
//!
//! ```text
//!  Pending ──launch──▶ Running ──complete──▶ Done
//!     ▲                  │  │
//!     │     (KILL)       │  │ (SUSPEND, SIGSTOP)
//!     └──────────────────┘  ▼
//!                        Suspended ──(RESUME, SIGCONT)──▶ Running
//! ```
//!
//! A suspended task remembers its node (resume must happen on the *same
//! machine*, since its spilled state lives there) and whether its context
//! was materialized to swap (which prices the resume delay).

use crate::job::{JobId, Phase};
use crate::sim::Time;

/// Globally unique reference to one task.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskRef {
    pub job: JobId,
    pub phase: Phase,
    pub index: u32,
}

impl std::fmt::Display for TaskRef {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "j{}/{}[{}]", self.job, self.phase.name(), self.index)
    }
}

/// Node identifier within the simulated cluster.
pub type NodeId = usize;

/// Task state machine.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TaskState {
    /// Not yet launched (or re-queued after a KILL).
    Pending,
    /// Occupying a slot on `node`; `started` is this attempt's launch (or
    /// resume) instant, `remaining_at_start` the work left at that instant,
    /// and `speed` the node's work rate (1 = nominal; straggler nodes run
    /// below 1, stretching the attempt's wall-clock service time).
    Running {
        node: NodeId,
        started: Time,
        remaining_at_start: f64,
        speed: f64,
    },
    /// SIGSTOPped on `node` with `remaining` seconds of work left;
    /// `swapped` records whether the OS paged the context out (resume will
    /// then pay a swap-in delay).
    Suspended {
        node: NodeId,
        remaining: f64,
        swapped: bool,
    },
    Done,
}

impl TaskState {
    pub fn is_pending(&self) -> bool {
        matches!(self, TaskState::Pending)
    }
    pub fn is_running(&self) -> bool {
        matches!(self, TaskState::Running { .. })
    }
    pub fn is_suspended(&self) -> bool {
        matches!(self, TaskState::Suspended { .. })
    }
    pub fn is_done(&self) -> bool {
        matches!(self, TaskState::Done)
    }

    pub fn node(&self) -> Option<NodeId> {
        match self {
            TaskState::Running { node, .. } | TaskState::Suspended { node, .. } => Some(*node),
            _ => None,
        }
    }
}

/// Per-task mutable runtime bookkeeping (driver-owned).
#[derive(Clone, Debug)]
pub struct TaskRuntime {
    pub state: TaskState,
    /// True total work of this task, seconds (mirrors the spec; kept here
    /// so remaining-work math never needs the spec).
    pub total_work: f64,
    /// Scheduling epoch: incremented on every launch/suspend/resume/kill.
    /// Completion events carry the epoch they were scheduled under, letting
    /// the driver discard events that became stale due to preemption.
    pub epoch: u64,
    /// Number of times this task was launched (1 + number of kills).
    pub attempts: u32,
    /// Whether the *current/last* attempt reads its block from local disk
    /// (map tasks only; reduces have no input locality, §3.1).
    pub local: bool,
    /// First launch instant (for wait-time metrics).
    pub first_launch: Option<Time>,
    /// Completion instant.
    pub finished_at: Option<Time>,
    /// Cumulative seconds spent suspended (diagnostics).
    pub suspended_secs: f64,
    /// Instant of the last suspension (to integrate `suspended_secs`).
    pub suspended_since: Option<Time>,
    /// Work rate of the current/last attempt's node (resume is pinned to
    /// the launch node, so one attempt runs at a single speed).
    pub attempt_speed: f64,
}

impl TaskRuntime {
    pub fn new(total_work: f64) -> Self {
        Self {
            state: TaskState::Pending,
            total_work,
            epoch: 0,
            attempts: 0,
            local: false,
            first_launch: None,
            finished_at: None,
            suspended_secs: 0.0,
            suspended_since: None,
            attempt_speed: 1.0,
        }
    }

    /// Work remaining at time `now` given the current state (work units,
    /// i.e. nominal-node seconds — a straggler burns them at `speed` < 1
    /// per wall second).
    pub fn remaining(&self, now: Time) -> f64 {
        match self.state {
            TaskState::Pending => self.total_work,
            TaskState::Running {
                started,
                remaining_at_start,
                speed,
                ..
            } => (remaining_at_start - (now - started) * speed).max(0.0),
            TaskState::Suspended { remaining, .. } => remaining,
            TaskState::Done => 0.0,
        }
    }

    /// Transition Pending → Running at the node's work rate `speed`
    /// (1 = nominal). Returns the wall-clock completion delay.
    pub fn launch(&mut self, node: NodeId, now: Time, local: bool, speed: f64) -> f64 {
        assert!(self.state.is_pending(), "launch of non-pending task");
        assert!(speed > 0.0, "node speed must be positive");
        self.state = TaskState::Running {
            node,
            started: now,
            remaining_at_start: self.total_work,
            speed,
        };
        self.epoch += 1;
        self.attempts += 1;
        self.local = local;
        self.attempt_speed = speed;
        if self.first_launch.is_none() {
            self.first_launch = Some(now);
        }
        self.total_work / speed
    }

    /// Transition Running → Suspended (SIGSTOP).
    pub fn suspend(&mut self, now: Time) {
        let TaskState::Running { node, .. } = self.state else {
            panic!("suspend of non-running task");
        };
        let remaining = self.remaining(now);
        self.state = TaskState::Suspended {
            node,
            remaining,
            swapped: false,
        };
        self.epoch += 1;
        self.suspended_since = Some(now);
    }

    /// Mark the suspended context as paged out to disk.
    pub fn mark_swapped(&mut self) {
        if let TaskState::Suspended { swapped, .. } = &mut self.state {
            *swapped = true;
        } else {
            panic!("mark_swapped of non-suspended task");
        }
    }

    /// Transition Suspended → Running (SIGCONT) on the same node at work
    /// rate `speed`. Returns the wall-clock completion delay **including**
    /// `swap_in_delay` (wall seconds of swap-in I/O, rate-independent) if
    /// the context was paged out.
    pub fn resume(&mut self, now: Time, swap_in_delay: f64, speed: f64) -> f64 {
        let TaskState::Suspended {
            node,
            remaining,
            swapped,
        } = self.state
        else {
            panic!("resume of non-suspended task");
        };
        assert!(speed > 0.0, "node speed must be positive");
        // Swap-in is disk I/O: its wall cost is speed-independent, so it
        // enters the work ledger pre-scaled by the rate.
        let delay_work = if swapped { swap_in_delay * speed } else { 0.0 };
        self.state = TaskState::Running {
            node,
            started: now,
            remaining_at_start: remaining + delay_work,
            speed,
        };
        self.epoch += 1;
        self.attempt_speed = speed;
        if let Some(since) = self.suspended_since.take() {
            self.suspended_secs += now - since;
        }
        (remaining + delay_work) / speed
    }

    /// Transition Running|Suspended → Pending, losing all work (KILL).
    pub fn kill(&mut self, now: Time) {
        assert!(
            self.state.is_running() || self.state.is_suspended(),
            "kill of non-active task"
        );
        if let Some(since) = self.suspended_since.take() {
            self.suspended_secs += now - since;
        }
        self.state = TaskState::Pending;
        self.epoch += 1;
    }

    /// Transition Running → Done.
    pub fn complete(&mut self, now: Time) {
        assert!(self.state.is_running(), "complete of non-running task");
        self.state = TaskState::Done;
        self.epoch += 1;
        self.finished_at = Some(now);
    }

    /// The task runtime a TaskTracker would report for the current/last
    /// attempt: the serialized work stretched by the attempt node's
    /// slowdown (what schedulers observe — straggler-stretched, swap
    /// delays excluded, exactly `total_work` at nominal speed).
    pub fn observed_duration(&self) -> f64 {
        self.total_work / self.attempt_speed
    }

    /// Work units completed by the current attempt at `now` — the amount
    /// thrown away if the attempt is killed or loses a speculative race.
    /// Clamped at 0: a freshly swap-in-resumed attempt's work ledger
    /// (`remaining_at_start = remaining + swap_delay·speed`) can briefly
    /// exceed `total_work`, and swap-in replay is not completed work.
    pub fn work_done(&self, now: Time) -> f64 {
        (self.total_work - self.remaining(now)).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn launch_run_complete() {
        let mut t = TaskRuntime::new(10.0);
        let d = t.launch(3, 100.0, true, 1.0);
        assert_eq!(d, 10.0);
        assert!(t.state.is_running());
        assert_eq!(t.state.node(), Some(3));
        assert_eq!(t.remaining(104.0), 6.0);
        t.complete(110.0);
        assert!(t.state.is_done());
        assert_eq!(t.finished_at, Some(110.0));
        assert_eq!(t.attempts, 1);
    }

    #[test]
    fn suspend_preserves_remaining_work() {
        let mut t = TaskRuntime::new(10.0);
        t.launch(0, 0.0, false, 1.0);
        t.suspend(4.0);
        assert!(t.state.is_suspended());
        assert_eq!(t.remaining(99.0), 6.0); // frozen while suspended
        let d = t.resume(50.0, 2.5, 1.0);
        assert_eq!(d, 6.0); // not swapped: no delay
        assert_eq!(t.remaining(53.0), 3.0);
        assert!((t.suspended_secs - 46.0).abs() < 1e-12);
    }

    #[test]
    fn swapped_resume_pays_delay() {
        let mut t = TaskRuntime::new(10.0);
        t.launch(0, 0.0, false, 1.0);
        t.suspend(4.0);
        t.mark_swapped();
        let d = t.resume(8.0, 2.5, 1.0);
        assert!((d - 8.5).abs() < 1e-12);
    }

    #[test]
    fn kill_resets_work() {
        let mut t = TaskRuntime::new(10.0);
        t.launch(0, 0.0, true, 1.0);
        t.kill(7.0);
        assert!(t.state.is_pending());
        assert_eq!(t.remaining(7.0), 10.0);
        t.launch(1, 8.0, false, 1.0);
        assert_eq!(t.attempts, 2);
    }

    #[test]
    fn epochs_increment_on_every_transition() {
        let mut t = TaskRuntime::new(10.0);
        assert_eq!(t.epoch, 0);
        t.launch(0, 0.0, false, 1.0);
        assert_eq!(t.epoch, 1);
        t.suspend(1.0);
        assert_eq!(t.epoch, 2);
        t.resume(2.0, 0.0, 1.0);
        assert_eq!(t.epoch, 3);
        t.complete(20.0);
        assert_eq!(t.epoch, 4);
    }

    #[test]
    #[should_panic(expected = "non-pending")]
    fn double_launch_panics() {
        let mut t = TaskRuntime::new(1.0);
        t.launch(0, 0.0, false, 1.0);
        t.launch(0, 0.0, false, 1.0);
    }

    #[test]
    fn remaining_clamps_at_zero() {
        let mut t = TaskRuntime::new(5.0);
        t.launch(0, 0.0, false, 1.0);
        assert_eq!(t.remaining(100.0), 0.0);
    }

    #[test]
    fn straggler_speed_stretches_wall_clock() {
        // 10 s of work at quarter speed: 40 s of wall time.
        let mut t = TaskRuntime::new(10.0);
        let d = t.launch(0, 0.0, false, 0.25);
        assert!((d - 40.0).abs() < 1e-12);
        // After 8 wall seconds only 2 work units are burned.
        assert!((t.remaining(8.0) - 8.0).abs() < 1e-12);
        // The scheduler observes the stretched duration.
        assert!((t.observed_duration() - 40.0).abs() < 1e-12);
        t.complete(40.0);
        assert!((t.observed_duration() - 40.0).abs() < 1e-12);
    }

    #[test]
    fn suspend_resume_preserves_work_units_under_slowdown() {
        let mut t = TaskRuntime::new(10.0);
        t.launch(0, 0.0, false, 0.5);
        t.suspend(4.0); // 2 work units done, 8 left
        assert!((t.remaining(99.0) - 8.0).abs() < 1e-12);
        let d = t.resume(50.0, 0.0, 0.5);
        assert!((d - 16.0).abs() < 1e-12, "8 work units at half speed");
    }

    #[test]
    fn swapped_resume_swap_delay_is_wall_clock() {
        // Swap-in I/O costs the same wall time regardless of CPU slowdown.
        let mut t = TaskRuntime::new(10.0);
        t.launch(0, 0.0, false, 0.5);
        t.suspend(4.0); // 8 work units left
        t.mark_swapped();
        let d = t.resume(8.0, 3.0, 0.5);
        assert!((d - (16.0 + 3.0)).abs() < 1e-12, "16 s work + 3 s swap-in");
    }

    #[test]
    fn nominal_speed_is_bit_identical_to_legacy() {
        let mut t = TaskRuntime::new(13.25);
        assert_eq!(t.launch(1, 7.5, true, 1.0), 13.25);
        assert_eq!(t.remaining(10.0), 13.25 - 2.5);
        t.suspend(10.0);
        assert_eq!(t.resume(20.0, 4.75, 1.0), 13.25 - 2.5);
        assert_eq!(t.observed_duration(), 13.25);
    }
}
