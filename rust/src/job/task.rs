//! Task-level runtime state.
//!
//! HFSP's eager preemption (§3.3 of the paper) required the authors to
//! "introduce a new set of states associated to an Hadoop task"; this
//! module is the simulator's version of that extended state machine:
//!
//! ```text
//!  Pending ──launch──▶ Running ──complete──▶ Done
//!     ▲                  │  │
//!     │     (KILL)       │  │ (SUSPEND, SIGSTOP)
//!     └──────────────────┘  ▼
//!                        Suspended ──(RESUME, SIGCONT)──▶ Running
//! ```
//!
//! A suspended task remembers its node (resume must happen on the *same
//! machine*, since its spilled state lives there) and whether its context
//! was materialized to swap (which prices the resume delay).

use crate::job::{JobId, Phase};
use crate::sim::Time;

/// Globally unique reference to one task.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskRef {
    pub job: JobId,
    pub phase: Phase,
    pub index: u32,
}

impl std::fmt::Display for TaskRef {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "j{}/{}[{}]", self.job, self.phase.name(), self.index)
    }
}

/// Node identifier within the simulated cluster.
pub type NodeId = usize;

/// Task state machine.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TaskState {
    /// Not yet launched (or re-queued after a KILL).
    Pending,
    /// Occupying a slot on `node`; `started` is this attempt's launch (or
    /// resume) instant, `remaining_at_start` the work left at that instant.
    Running {
        node: NodeId,
        started: Time,
        remaining_at_start: f64,
    },
    /// SIGSTOPped on `node` with `remaining` seconds of work left;
    /// `swapped` records whether the OS paged the context out (resume will
    /// then pay a swap-in delay).
    Suspended {
        node: NodeId,
        remaining: f64,
        swapped: bool,
    },
    Done,
}

impl TaskState {
    pub fn is_pending(&self) -> bool {
        matches!(self, TaskState::Pending)
    }
    pub fn is_running(&self) -> bool {
        matches!(self, TaskState::Running { .. })
    }
    pub fn is_suspended(&self) -> bool {
        matches!(self, TaskState::Suspended { .. })
    }
    pub fn is_done(&self) -> bool {
        matches!(self, TaskState::Done)
    }

    pub fn node(&self) -> Option<NodeId> {
        match self {
            TaskState::Running { node, .. } | TaskState::Suspended { node, .. } => Some(*node),
            _ => None,
        }
    }
}

/// Per-task mutable runtime bookkeeping (driver-owned).
#[derive(Clone, Debug)]
pub struct TaskRuntime {
    pub state: TaskState,
    /// True total work of this task, seconds (mirrors the spec; kept here
    /// so remaining-work math never needs the spec).
    pub total_work: f64,
    /// Scheduling epoch: incremented on every launch/suspend/resume/kill.
    /// Completion events carry the epoch they were scheduled under, letting
    /// the driver discard events that became stale due to preemption.
    pub epoch: u64,
    /// Number of times this task was launched (1 + number of kills).
    pub attempts: u32,
    /// Whether the *current/last* attempt reads its block from local disk
    /// (map tasks only; reduces have no input locality, §3.1).
    pub local: bool,
    /// First launch instant (for wait-time metrics).
    pub first_launch: Option<Time>,
    /// Completion instant.
    pub finished_at: Option<Time>,
    /// Cumulative seconds spent suspended (diagnostics).
    pub suspended_secs: f64,
    /// Instant of the last suspension (to integrate `suspended_secs`).
    pub suspended_since: Option<Time>,
}

impl TaskRuntime {
    pub fn new(total_work: f64) -> Self {
        Self {
            state: TaskState::Pending,
            total_work,
            epoch: 0,
            attempts: 0,
            local: false,
            first_launch: None,
            finished_at: None,
            suspended_secs: 0.0,
            suspended_since: None,
        }
    }

    /// Work remaining at time `now` given the current state.
    pub fn remaining(&self, now: Time) -> f64 {
        match self.state {
            TaskState::Pending => self.total_work,
            TaskState::Running {
                started,
                remaining_at_start,
                ..
            } => (remaining_at_start - (now - started)).max(0.0),
            TaskState::Suspended { remaining, .. } => remaining,
            TaskState::Done => 0.0,
        }
    }

    /// Transition Pending → Running. Returns the completion delay.
    pub fn launch(&mut self, node: NodeId, now: Time, local: bool) -> f64 {
        assert!(self.state.is_pending(), "launch of non-pending task");
        self.state = TaskState::Running {
            node,
            started: now,
            remaining_at_start: self.total_work,
        };
        self.epoch += 1;
        self.attempts += 1;
        self.local = local;
        if self.first_launch.is_none() {
            self.first_launch = Some(now);
        }
        self.total_work
    }

    /// Transition Running → Suspended (SIGSTOP).
    pub fn suspend(&mut self, now: Time) {
        let TaskState::Running { node, .. } = self.state else {
            panic!("suspend of non-running task");
        };
        let remaining = self.remaining(now);
        self.state = TaskState::Suspended {
            node,
            remaining,
            swapped: false,
        };
        self.epoch += 1;
        self.suspended_since = Some(now);
    }

    /// Mark the suspended context as paged out to disk.
    pub fn mark_swapped(&mut self) {
        if let TaskState::Suspended { swapped, .. } = &mut self.state {
            *swapped = true;
        } else {
            panic!("mark_swapped of non-suspended task");
        }
    }

    /// Transition Suspended → Running (SIGCONT) on the same node. Returns
    /// the completion delay **including** `swap_in_delay` if the context
    /// was paged out.
    pub fn resume(&mut self, now: Time, swap_in_delay: f64) -> f64 {
        let TaskState::Suspended {
            node,
            remaining,
            swapped,
        } = self.state
        else {
            panic!("resume of non-suspended task");
        };
        let delay = if swapped { swap_in_delay } else { 0.0 };
        self.state = TaskState::Running {
            node,
            started: now,
            remaining_at_start: remaining + delay,
        };
        self.epoch += 1;
        if let Some(since) = self.suspended_since.take() {
            self.suspended_secs += now - since;
        }
        remaining + delay
    }

    /// Transition Running|Suspended → Pending, losing all work (KILL).
    pub fn kill(&mut self, now: Time) {
        assert!(
            self.state.is_running() || self.state.is_suspended(),
            "kill of non-active task"
        );
        if let Some(since) = self.suspended_since.take() {
            self.suspended_secs += now - since;
        }
        self.state = TaskState::Pending;
        self.epoch += 1;
    }

    /// Transition Running → Done.
    pub fn complete(&mut self, now: Time) {
        assert!(self.state.is_running(), "complete of non-running task");
        self.state = TaskState::Done;
        self.epoch += 1;
        self.finished_at = Some(now);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn launch_run_complete() {
        let mut t = TaskRuntime::new(10.0);
        let d = t.launch(3, 100.0, true);
        assert_eq!(d, 10.0);
        assert!(t.state.is_running());
        assert_eq!(t.state.node(), Some(3));
        assert_eq!(t.remaining(104.0), 6.0);
        t.complete(110.0);
        assert!(t.state.is_done());
        assert_eq!(t.finished_at, Some(110.0));
        assert_eq!(t.attempts, 1);
    }

    #[test]
    fn suspend_preserves_remaining_work() {
        let mut t = TaskRuntime::new(10.0);
        t.launch(0, 0.0, false);
        t.suspend(4.0);
        assert!(t.state.is_suspended());
        assert_eq!(t.remaining(99.0), 6.0); // frozen while suspended
        let d = t.resume(50.0, 2.5);
        assert_eq!(d, 6.0); // not swapped: no delay
        assert_eq!(t.remaining(53.0), 3.0);
        assert!((t.suspended_secs - 46.0).abs() < 1e-12);
    }

    #[test]
    fn swapped_resume_pays_delay() {
        let mut t = TaskRuntime::new(10.0);
        t.launch(0, 0.0, false);
        t.suspend(4.0);
        t.mark_swapped();
        let d = t.resume(8.0, 2.5);
        assert!((d - 8.5).abs() < 1e-12);
    }

    #[test]
    fn kill_resets_work() {
        let mut t = TaskRuntime::new(10.0);
        t.launch(0, 0.0, true);
        t.kill(7.0);
        assert!(t.state.is_pending());
        assert_eq!(t.remaining(7.0), 10.0);
        t.launch(1, 8.0, false);
        assert_eq!(t.attempts, 2);
    }

    #[test]
    fn epochs_increment_on_every_transition() {
        let mut t = TaskRuntime::new(10.0);
        assert_eq!(t.epoch, 0);
        t.launch(0, 0.0, false);
        assert_eq!(t.epoch, 1);
        t.suspend(1.0);
        assert_eq!(t.epoch, 2);
        t.resume(2.0, 0.0);
        assert_eq!(t.epoch, 3);
        t.complete(20.0);
        assert_eq!(t.epoch, 4);
    }

    #[test]
    #[should_panic(expected = "non-pending")]
    fn double_launch_panics() {
        let mut t = TaskRuntime::new(1.0);
        t.launch(0, 0.0, false);
        t.launch(0, 0.0, false);
    }

    #[test]
    fn remaining_clamps_at_zero() {
        let mut t = TaskRuntime::new(5.0);
        t.launch(0, 0.0, false);
        assert_eq!(t.remaining(100.0), 0.0);
    }
}
