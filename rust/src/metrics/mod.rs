//! Measurement pipeline: sojourn statistics, locality counters, slot
//! timelines and their JSON export.

pub mod locality;
pub mod sojourn;

pub use locality::LocalityStats;
pub use sojourn::{PerJobRecord, SojournStats};
