//! Measurement pipeline: sojourn statistics, locality counters, slot
//! timelines, their JSON export — and the streaming [`Probe`] layer
//! that collects them incrementally during a session.

pub mod locality;
pub mod probe;
pub mod sojourn;
pub mod tenancy;

pub use locality::LocalityStats;
pub use probe::{
    ActionCounters, CounterProbe, FaultProbe, JobLimitProbe, KillCause, LocalityProbe, Probe,
    ProbeEvent, ProbeStack, SojournProbe, TimelineProbe,
};
pub use sojourn::{PerJobRecord, SojournStats};
pub use tenancy::{jain_index, PoolUsage, TenantProbe};
