//! Per-tenant fairness accounting.
//!
//! The hierarchical scheduler promises each pool a weighted share of
//! the cluster; this probe measures what pools *actually received*.
//! [`TenantProbe`] streams the same task-lifecycle events the timeline
//! probe uses, but attributes occupied slot-time to the submitting
//! tenant's **pool** (known from the `JobArrived` event), keeping one
//! accumulator per *observed* pool — memory scales with pools that
//! actually submitted, never with the population.
//!
//! Two summaries come out:
//!
//! * [`TenantProbe::shares`] — normalized slot-seconds per pool, the
//!   quantity the 3/2/1-weight convergence test checks against the
//!   configured weights;
//! * [`TenantProbe::jain_index`] — Jain's fairness index
//!   J = (Σx)² / (n·Σx²) over a chosen per-pool metric (1 = perfectly
//!   even, 1/n = one pool took everything).

use super::probe::{Probe, ProbeEvent};
use super::sojourn::PerJobRecord;
use crate::job::JobId;
use crate::sim::Time;
use crate::util::fxmap::FastMap;
use std::collections::BTreeMap;

/// Running slot-time and sojourn accumulators for one pool.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolUsage {
    /// Occupied slot-seconds (map + reduce), accrued on release.
    pub slot_seconds: f64,
    /// Finished jobs.
    pub jobs_done: usize,
    /// Sum of finished jobs' sojourn times.
    pub sojourn_sum_s: f64,
}

impl PoolUsage {
    pub fn mean_sojourn_s(&self) -> f64 {
        if self.jobs_done == 0 {
            0.0
        } else {
            self.sojourn_sum_s / self.jobs_done as f64
        }
    }
}

/// Jain's fairness index over a set of non-negative allocations:
/// (Σx)² / (n·Σx²); 1.0 for an even split, 1/n for a monopoly. Defined
/// as 1.0 for empty or all-zero input (nothing was shared unevenly).
pub fn jain_index(xs: &[f64]) -> f64 {
    let n = xs.len() as f64;
    let sum: f64 = xs.iter().sum();
    let sq: f64 = xs.iter().map(|x| x * x).sum();
    if n == 0.0 || sq <= 0.0 {
        1.0
    } else {
        sum * sum / (n * sq)
    }
}

/// Streaming per-pool usage probe (attach via
/// [`Simulation::probe`](crate::session::Simulation::probe)).
#[derive(Clone, Debug, Default)]
pub struct TenantProbe {
    /// job → pool, learned from `JobArrived`; entries are dropped on
    /// job completion, so this tracks *live* jobs only.
    job_pool: FastMap<JobId, u32>,
    /// task-slot occupancy start, keyed by (job, phase-ordinal, index)
    /// → (pool, start). TaskRef is Copy+Hash through its fields.
    running: FastMap<(JobId, u8, usize), (u32, Time)>,
    pools: BTreeMap<u32, PoolUsage>,
}

impl TenantProbe {
    pub fn new() -> Self {
        Self::default()
    }

    /// Per-pool accumulators, keyed by pool id, in pool order.
    pub fn pools(&self) -> &BTreeMap<u32, PoolUsage> {
        &self.pools
    }

    /// Normalized slot-second shares per pool (sums to 1 when any work
    /// ran), in pool-id order.
    pub fn shares(&self) -> Vec<(u32, f64)> {
        let total: f64 = self.pools.values().map(|p| p.slot_seconds).sum();
        self.pools
            .iter()
            .map(|(&id, p)| {
                (
                    id,
                    if total > 0.0 {
                        p.slot_seconds / total
                    } else {
                        0.0
                    },
                )
            })
            .collect()
    }

    /// Jain index over per-pool slot-seconds.
    pub fn jain_slot_seconds(&self) -> f64 {
        let xs: Vec<f64> = self.pools.values().map(|p| p.slot_seconds).collect();
        jain_index(&xs)
    }

    /// Jain index over per-pool mean sojourn times (only pools with
    /// finished jobs participate).
    pub fn jain_mean_sojourn(&self) -> f64 {
        let xs: Vec<f64> = self
            .pools
            .values()
            .filter(|p| p.jobs_done > 0)
            .map(PoolUsage::mean_sojourn_s)
            .collect();
        jain_index(&xs)
    }

    fn acquire(&mut self, key: (JobId, u8, usize), now: Time) {
        if let Some(&pool) = self.job_pool.get(&key.0) {
            self.running.insert(key, (pool, now));
        }
    }

    fn release(&mut self, key: (JobId, u8, usize), now: Time) {
        if let Some((pool, start)) = self.running.remove(&key) {
            self.pools.entry(pool).or_default().slot_seconds += now - start;
        }
    }
}

fn task_key(task: &crate::job::TaskRef) -> (JobId, u8, usize) {
    (task.job, task.phase as u8, task.index)
}

impl Probe for TenantProbe {
    fn name(&self) -> &'static str {
        "tenancy"
    }

    fn on_event(&mut self, now: Time, event: &ProbeEvent) {
        match event {
            ProbeEvent::JobArrived { job, tenant, .. } => {
                self.job_pool.insert(*job, tenant.pool);
                self.pools.entry(tenant.pool).or_default();
            }
            ProbeEvent::TaskLaunched { task, .. } | ProbeEvent::TaskResumed { task, .. } => {
                self.acquire(task_key(task), now);
            }
            ProbeEvent::TaskSuspended { task, .. }
            | ProbeEvent::TaskCompleted { task, .. }
            | ProbeEvent::TaskKilled {
                task,
                running: true,
                ..
            } => {
                self.release(task_key(task), now);
            }
            _ => {}
        }
    }

    fn on_job_done(&mut self, _now: Time, record: &PerJobRecord) {
        let pool = self
            .job_pool
            .remove(&record.job)
            .unwrap_or(record.tenant.pool);
        let p = self.pools.entry(pool).or_default();
        p.jobs_done += 1;
        p.sojourn_sum_s += record.sojourn();
    }

    fn on_finish(&mut self, now: Time) {
        // Close out any still-occupied slots (probe-halted sessions).
        let keys: Vec<_> = self.running.keys().copied().collect();
        for k in keys {
            self.release(k, now);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::{JobClass, Phase, TaskRef, TenantId};

    fn arrive(p: &mut TenantProbe, job: JobId, pool: u32) {
        p.on_event(
            0.0,
            &ProbeEvent::JobArrived {
                job,
                n_maps: 1,
                n_reduces: 0,
                tenant: TenantId::new(pool, 0),
            },
        );
    }

    fn task(job: JobId) -> TaskRef {
        TaskRef {
            job,
            phase: Phase::Map,
            index: 0,
        }
    }

    #[test]
    fn slot_seconds_accrue_to_the_submitting_pool() {
        let mut p = TenantProbe::new();
        arrive(&mut p, 1, 3);
        arrive(&mut p, 2, 7);
        p.on_event(
            10.0,
            &ProbeEvent::TaskLaunched {
                task: task(1),
                node: 0,
                local: true,
                re_execution: false,
            },
        );
        p.on_event(
            10.0,
            &ProbeEvent::TaskLaunched {
                task: task(2),
                node: 0,
                local: true,
                re_execution: false,
            },
        );
        p.on_event(
            30.0,
            &ProbeEvent::TaskCompleted {
                task: task(1),
                node: 0,
                local: true,
                observed_s: 20.0,
                speculative: false,
            },
        );
        // Job 2's task still runs at halt time 50 — on_finish closes it.
        p.on_finish(50.0);
        assert_eq!(p.pools()[&3].slot_seconds, 20.0);
        assert_eq!(p.pools()[&7].slot_seconds, 40.0);
        let shares = p.shares();
        assert_eq!(shares.len(), 2);
        assert!((shares[0].1 - 20.0 / 60.0).abs() < 1e-12);
        assert!((shares[1].1 - 40.0 / 60.0).abs() < 1e-12);
    }

    #[test]
    fn sojourns_group_by_pool_via_the_record_tenant() {
        let mut p = TenantProbe::new();
        arrive(&mut p, 1, 2);
        let rec = PerJobRecord {
            job: 1,
            class: JobClass::Small,
            tenant: TenantId::new(2, 9),
            submit: 5.0,
            finish: 25.0,
            n_maps: 1,
            n_reduces: 0,
            true_size: 10.0,
        };
        p.on_job_done(25.0, &rec);
        assert_eq!(p.pools()[&2].jobs_done, 1);
        assert!((p.pools()[&2].mean_sojourn_s() - 20.0).abs() < 1e-12);
    }

    #[test]
    fn jain_index_bounds() {
        assert_eq!(jain_index(&[]), 1.0);
        assert_eq!(jain_index(&[0.0, 0.0]), 1.0);
        assert!((jain_index(&[5.0, 5.0, 5.0]) - 1.0).abs() < 1e-12);
        assert!((jain_index(&[1.0, 0.0, 0.0, 0.0]) - 0.25).abs() < 1e-12);
        let mid = jain_index(&[3.0, 2.0, 1.0]);
        assert!(mid > 0.25 && mid < 1.0, "{mid}");
    }
}
