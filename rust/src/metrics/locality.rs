//! Data-locality accounting (§4.3 "Impact of data locality").
//!
//! Counts, over MAP tasks only, how many attempts read their block from
//! the local disk of the machine they ran on. The paper reports FAIR at
//! 98 % and HFSP at 100 % across >14 000 tasks.

use crate::util::json::Json;

#[derive(Clone, Copy, Debug, Default)]
pub struct LocalityStats {
    pub local: u64,
    pub remote: u64,
}

impl LocalityStats {
    pub fn record(&mut self, local: bool) {
        if local {
            self.local += 1;
        } else {
            self.remote += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.local + self.remote
    }

    /// Fraction of local map tasks in `[0, 1]`; NaN when empty.
    pub fn fraction_local(&self) -> f64 {
        if self.total() == 0 {
            f64::NAN
        } else {
            self.local as f64 / self.total() as f64
        }
    }

    pub fn merge(&mut self, other: &LocalityStats) {
        self.local += other.local;
        self.remote += other.remote;
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("local_map_tasks", self.local.into());
        o.set("remote_map_tasks", self.remote.into());
        o.set("fraction_local", self.fraction_local().into());
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fraction_computation() {
        let mut l = LocalityStats::default();
        for _ in 0..98 {
            l.record(true);
        }
        for _ in 0..2 {
            l.record(false);
        }
        assert_eq!(l.total(), 100);
        assert!((l.fraction_local() - 0.98).abs() < 1e-12);
    }

    #[test]
    fn empty_is_nan() {
        assert!(LocalityStats::default().fraction_local().is_nan());
    }

    #[test]
    fn merge_adds() {
        let mut a = LocalityStats { local: 3, remote: 1 };
        a.merge(&LocalityStats { local: 1, remote: 1 });
        assert_eq!(a.local, 4);
        assert_eq!(a.remote, 2);
    }
}
