//! Incremental measurement probes.
//!
//! A [`Probe`] is a streaming observer attached to a simulation session:
//! the driver pushes fine-grained [`ProbeEvent`]s and per-job completion
//! records into it *as they happen*, instead of folding everything into
//! one batch result after the run. A probe keeps whatever summary it
//! wants — O(1) for counters and moments; the built-in [`SojournProbe`]
//! keeps one compact record per finished job, the one session component
//! that grows with total job count — and the layer is the hook for custom
//! instrumentation: attach any number of user probes through
//! [`Simulation::probe`](crate::session::Simulation::probe).
//!
//! The classic batch metrics are themselves implemented as the built-in
//! probes of every session ([`SojournProbe`], [`LocalityProbe`],
//! [`TimelineProbe`], [`CounterProbe`], [`FaultProbe`]); their final
//! states are what [`SimOutcome`](crate::cluster::driver::SimOutcome)
//! carries, so the probe refactor is invisible to batch callers.
//!
//! Probes can also **end** a session: [`Probe::halt_requested`] is
//! polled after every dispatched event, and a `true` stops the event
//! loop (surfaced as `SimOutcome::halted_by_probe`). [`JobLimitProbe`]
//! is the built-in example — steady-state detectors follow the same
//! shape.
//!
//! ## Contract
//!
//! * Events arrive in simulation order; `now` is nondecreasing.
//! * [`Probe::on_job_done`] is called exactly once per finished job,
//!   *after* the `TaskCompleted` event of its last task.
//! * [`Probe::on_finish`] is called exactly once, after the event loop
//!   stops (drained, halted, or event-limit), with the final clock.
//! * Probes must not assume every job finishes: a probe-halted or
//!   truncated session ends with jobs still in flight.

use crate::faults::FaultStats;
use crate::job::task::NodeId;
use crate::job::{JobId, Phase, TaskRef, TenantId};
use crate::metrics::{LocalityStats, PerJobRecord, SojournStats};
use crate::sim::Time;
use crate::util::timeline::TimelineSet;

/// Why a task attempt was killed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KillCause {
    /// Scheduler-issued KILL preemption.
    Preemption,
    /// The hosting node crashed.
    Crash,
}

/// One fine-grained simulation observation, pushed to every probe.
///
/// Variants mirror the driver's state transitions one-to-one; the
/// built-in probes below document which variant feeds which classic
/// metric.
#[derive(Clone, Copy, Debug)]
pub enum ProbeEvent {
    /// A job entered the system.
    JobArrived {
        job: JobId,
        n_maps: usize,
        n_reduces: usize,
        /// Submitting tenant (default for single-tenant workloads).
        tenant: TenantId,
    },
    /// A pending task attempt started on `node`. `re_execution` marks
    /// attempt ≥ 2 (the task was crash-killed or KILL-preempted before).
    TaskLaunched {
        task: TaskRef,
        node: NodeId,
        local: bool,
        re_execution: bool,
    },
    /// A running task was SIGSTOPped (slot freed, context parked).
    TaskSuspended { task: TaskRef, node: NodeId },
    /// A suspended task resumed on its context node; `from_swap` means
    /// its context had been pushed to swap meanwhile.
    TaskResumed {
        task: TaskRef,
        node: NodeId,
        from_swap: bool,
    },
    /// A task attempt was killed. `running` distinguishes a running
    /// attempt (slot held) from a parked suspended context.
    TaskKilled {
        task: TaskRef,
        running: bool,
        cause: KillCause,
    },
    /// A task attempt completed. `local` is meaningful for map tasks;
    /// `speculative` marks completions produced by a winning clone.
    TaskCompleted {
        task: TaskRef,
        node: NodeId,
        local: bool,
        observed_s: f64,
        speculative: bool,
    },
    /// Serialized seconds of task progress thrown away (kills, crashes,
    /// the losing side of speculative races).
    WorkWasted { seconds: f64 },
    /// A node heartbeat was processed by the scheduler.
    Heartbeat { node: NodeId },
    /// A completion event was recognized as stale and dropped.
    StaleCompletion { task: TaskRef },
    /// The scheduler issued an invalid action (dropped; scheduler bug).
    ActionRejected { task: TaskRef },
    /// Fault plan: the node went down.
    NodeCrashed { node: NodeId, permanent: bool },
    /// Fault plan: the node came back.
    NodeRecovered { node: NodeId },
    /// A speculative clone was launched on `node`.
    SpeculativeLaunched { task: TaskRef, node: NodeId },
    /// A speculative clone beat its original.
    SpeculativeWon { task: TaskRef },
    /// Sharded execution: a still-untouched job was handed back to the
    /// coordinator because its shard had no free map slots; it will
    /// re-arrive on another shard in the next window.
    JobSpilled { job: JobId },
    /// Sharded execution: a still-untouched job was stolen from a
    /// saturated shard at the window barrier and will re-arrive on an
    /// underloaded shard in the next window (work-stealing; a superset
    /// of spillover that fires while the donor still has free slots
    /// elsewhere in the run).
    JobMigrated { job: JobId },
}

/// A streaming simulation observer. All methods have no-op defaults —
/// implement only what the probe measures.
pub trait Probe {
    /// Short label for diagnostics.
    fn name(&self) -> &'static str {
        "probe"
    }

    /// A simulation event happened at time `now`.
    fn on_event(&mut self, now: Time, event: &ProbeEvent) {
        let _ = (now, event);
    }

    /// A job finished; `record` is its complete sojourn record.
    fn on_job_done(&mut self, now: Time, record: &PerJobRecord) {
        let _ = (now, record);
    }

    /// The event loop stopped; `now` is the final simulated clock.
    fn on_finish(&mut self, now: Time) {
        let _ = now;
    }

    /// Polled after every dispatched event; returning `true` ends the
    /// session early (e.g. steady-state reached).
    fn halt_requested(&self) -> bool {
        false
    }
}

// ---------------------------------------------------------------------------
// Built-in probes
// ---------------------------------------------------------------------------

/// Counters over preemption primitives and scheduling activity.
#[derive(Clone, Copy, Debug, Default)]
pub struct ActionCounters {
    pub launches: u64,
    pub suspends: u64,
    pub resumes: u64,
    pub kills: u64,
    pub swap_ins: u64,
    pub heartbeats: u64,
    pub stale_completions: u64,
    pub rejected_actions: u64,
    /// Speculative task clones launched (fault subsystem).
    pub speculative_launches: u64,
    /// Speculative races won by the clone (original discarded).
    pub speculative_wins: u64,
    /// Sharded execution: cross-shard job spillovers (each is one job
    /// handed back to the coordinator and re-placed on another shard).
    pub spilled_jobs: u64,
    /// Sharded execution: jobs stolen from a saturated shard at a
    /// window barrier and re-placed on an underloaded one.
    pub stolen_jobs: u64,
}

impl ActionCounters {
    /// Fold another shard's counters into this one (sharded-run merge).
    pub fn merge(&mut self, other: &ActionCounters) {
        self.launches += other.launches;
        self.suspends += other.suspends;
        self.resumes += other.resumes;
        self.kills += other.kills;
        self.swap_ins += other.swap_ins;
        self.heartbeats += other.heartbeats;
        self.stale_completions += other.stale_completions;
        self.rejected_actions += other.rejected_actions;
        self.speculative_launches += other.speculative_launches;
        self.speculative_wins += other.speculative_wins;
        self.spilled_jobs += other.spilled_jobs;
        self.stolen_jobs += other.stolen_jobs;
    }
}

/// Built-in probe: per-job sojourn records ([`SojournStats`]).
#[derive(Clone, Debug, Default)]
pub struct SojournProbe {
    pub stats: SojournStats,
}

impl Probe for SojournProbe {
    fn name(&self) -> &'static str {
        "sojourn"
    }

    fn on_job_done(&mut self, _now: Time, record: &PerJobRecord) {
        self.stats.push(record.clone());
    }
}

/// Built-in probe: map-task data locality ([`LocalityStats`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct LocalityProbe {
    pub stats: LocalityStats,
}

impl Probe for LocalityProbe {
    fn name(&self) -> &'static str {
        "locality"
    }

    fn on_event(&mut self, _now: Time, event: &ProbeEvent) {
        if let ProbeEvent::TaskCompleted { task, local, .. } = event {
            // Reduces are "local" by convention and excluded (§4.3).
            if task.phase == Phase::Map {
                self.stats.record(*local);
            }
        }
    }
}

/// Built-in probe: per-job slot timelines ([`TimelineSet`]); inert
/// unless enabled (`SimConfig::record_timelines` — it costs memory on
/// large runs).
#[derive(Clone, Debug, Default)]
pub struct TimelineProbe {
    pub enabled: bool,
    pub set: TimelineSet,
}

impl TimelineProbe {
    pub fn new(enabled: bool) -> Self {
        Self {
            enabled,
            set: TimelineSet::default(),
        }
    }
}

impl Probe for TimelineProbe {
    fn name(&self) -> &'static str {
        "timelines"
    }

    fn on_event(&mut self, now: Time, event: &ProbeEvent) {
        if !self.enabled {
            return;
        }
        match event {
            ProbeEvent::TaskLaunched { task, .. } | ProbeEvent::TaskResumed { task, .. } => {
                self.set.acquire(task.job, now)
            }
            ProbeEvent::TaskSuspended { task, .. }
            | ProbeEvent::TaskCompleted { task, .. }
            | ProbeEvent::TaskKilled {
                task,
                running: true,
                ..
            } => self.set.release(task.job, now),
            _ => {}
        }
    }
}

/// Built-in probe: scheduling-activity counters ([`ActionCounters`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct CounterProbe {
    pub counters: ActionCounters,
}

impl Probe for CounterProbe {
    fn name(&self) -> &'static str {
        "counters"
    }

    fn on_event(&mut self, _now: Time, event: &ProbeEvent) {
        let c = &mut self.counters;
        match event {
            ProbeEvent::TaskLaunched { .. } => c.launches += 1,
            ProbeEvent::TaskSuspended { .. } => c.suspends += 1,
            ProbeEvent::TaskResumed { from_swap, .. } => {
                c.resumes += 1;
                if *from_swap {
                    c.swap_ins += 1;
                }
            }
            ProbeEvent::TaskKilled {
                cause: KillCause::Preemption,
                ..
            } => c.kills += 1,
            ProbeEvent::Heartbeat { .. } => c.heartbeats += 1,
            ProbeEvent::StaleCompletion { .. } => c.stale_completions += 1,
            ProbeEvent::ActionRejected { .. } => c.rejected_actions += 1,
            ProbeEvent::SpeculativeLaunched { .. } => c.speculative_launches += 1,
            ProbeEvent::SpeculativeWon { .. } => c.speculative_wins += 1,
            ProbeEvent::JobSpilled { .. } => c.spilled_jobs += 1,
            ProbeEvent::JobMigrated { .. } => c.stolen_jobs += 1,
            _ => {}
        }
    }
}

/// Built-in probe: fault & robustness statistics ([`FaultStats`]).
/// Seeded with the pre-run plan facts (straggler node count).
#[derive(Clone, Copy, Debug, Default)]
pub struct FaultProbe {
    pub stats: FaultStats,
}

impl FaultProbe {
    pub fn new(initial: FaultStats) -> Self {
        Self { stats: initial }
    }
}

impl Probe for FaultProbe {
    fn name(&self) -> &'static str {
        "faults"
    }

    fn on_event(&mut self, _now: Time, event: &ProbeEvent) {
        let f = &mut self.stats;
        match event {
            ProbeEvent::WorkWasted { seconds } => f.wasted_work_s += seconds,
            ProbeEvent::TaskLaunched {
                re_execution: true, ..
            } => f.re_executed_tasks += 1,
            ProbeEvent::TaskKilled {
                cause: KillCause::Crash,
                ..
            } => f.crash_task_kills += 1,
            ProbeEvent::NodeCrashed { permanent, .. } => {
                f.crashes += 1;
                if *permanent {
                    f.permanent_losses += 1;
                }
            }
            ProbeEvent::NodeRecovered { .. } => f.recoveries += 1,
            _ => {}
        }
    }
}

/// A probe that requests an early halt after `limit` finished jobs —
/// the template for steady-state detectors (measure a warm-up window,
/// then stop the open arrival session).
#[derive(Clone, Copy, Debug)]
pub struct JobLimitProbe {
    limit: usize,
    seen: usize,
}

impl JobLimitProbe {
    pub fn new(limit: usize) -> Self {
        Self { limit, seen: 0 }
    }

    /// Jobs observed so far.
    pub fn seen(&self) -> usize {
        self.seen
    }
}

impl Probe for JobLimitProbe {
    fn name(&self) -> &'static str {
        "job-limit"
    }

    fn on_job_done(&mut self, _now: Time, _record: &PerJobRecord) {
        self.seen += 1;
    }

    fn halt_requested(&self) -> bool {
        self.seen >= self.limit
    }
}

// ---------------------------------------------------------------------------
// Probe stack: built-ins + user probes, driven by the driver
// ---------------------------------------------------------------------------

/// The full probe complement of one session: the five built-ins plus
/// any user probes. The driver pushes every event through [`emit`] /
/// [`job_done`]; at session end [`ProbeStack::into_parts`] yields the
/// built-in results for `SimOutcome` assembly.
///
/// [`emit`]: ProbeStack::emit
/// [`job_done`]: ProbeStack::job_done
pub struct ProbeStack<'a> {
    pub sojourn: SojournProbe,
    pub locality: LocalityProbe,
    pub timelines: TimelineProbe,
    pub counters: CounterProbe,
    pub faults: FaultProbe,
    user: Vec<&'a mut dyn Probe>,
    halt: bool,
}

impl<'a> ProbeStack<'a> {
    pub fn new(
        record_timelines: bool,
        initial_faults: FaultStats,
        user: Vec<&'a mut dyn Probe>,
    ) -> Self {
        Self {
            sojourn: SojournProbe::default(),
            locality: LocalityProbe::default(),
            timelines: TimelineProbe::new(record_timelines),
            counters: CounterProbe::default(),
            faults: FaultProbe::new(initial_faults),
            user,
            halt: false,
        }
    }

    /// Dispatch one event to every probe.
    pub fn emit(&mut self, now: Time, event: &ProbeEvent) {
        self.locality.on_event(now, event);
        self.timelines.on_event(now, event);
        self.counters.on_event(now, event);
        self.faults.on_event(now, event);
        for p in &mut self.user {
            p.on_event(now, event);
            self.halt |= p.halt_requested();
        }
    }

    /// Dispatch one finished-job record to every probe.
    pub fn job_done(&mut self, now: Time, record: &PerJobRecord) {
        self.sojourn.on_job_done(now, record);
        for p in &mut self.user {
            p.on_job_done(now, record);
            self.halt |= p.halt_requested();
        }
    }

    /// Whether any user probe has requested an early halt since the
    /// last poll; resets the latch.
    pub fn take_halt(&mut self) -> bool {
        std::mem::take(&mut self.halt)
    }

    /// Final callback fan-out, then the built-in results.
    #[allow(clippy::type_complexity)]
    pub fn into_parts(
        mut self,
        now: Time,
    ) -> (
        SojournStats,
        LocalityStats,
        TimelineSet,
        ActionCounters,
        FaultStats,
    ) {
        for p in &mut self.user {
            p.on_finish(now);
        }
        (
            self.sojourn.stats,
            self.locality.stats,
            self.timelines.set,
            self.counters.counters,
            self.faults.stats,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::job::JobClass;

    fn task(job: JobId) -> TaskRef {
        TaskRef {
            job,
            phase: Phase::Map,
            index: 0,
        }
    }

    fn rec(job: JobId) -> PerJobRecord {
        PerJobRecord {
            job,
            class: JobClass::Small,
            tenant: TenantId::default(),
            submit: 0.0,
            finish: 5.0,
            n_maps: 1,
            n_reduces: 0,
            true_size: 5.0,
        }
    }

    #[test]
    fn counter_probe_mirrors_events() {
        let mut p = CounterProbe::default();
        p.on_event(
            0.0,
            &ProbeEvent::TaskLaunched {
                task: task(1),
                node: 0,
                local: true,
                re_execution: false,
            },
        );
        p.on_event(
            1.0,
            &ProbeEvent::TaskResumed {
                task: task(1),
                node: 0,
                from_swap: true,
            },
        );
        p.on_event(
            2.0,
            &ProbeEvent::TaskKilled {
                task: task(1),
                running: true,
                cause: KillCause::Preemption,
            },
        );
        p.on_event(
            2.0,
            &ProbeEvent::TaskKilled {
                task: task(1),
                running: true,
                cause: KillCause::Crash,
            },
        );
        p.on_event(3.0, &ProbeEvent::Heartbeat { node: 0 });
        assert_eq!(p.counters.launches, 1);
        assert_eq!(p.counters.resumes, 1);
        assert_eq!(p.counters.swap_ins, 1);
        assert_eq!(p.counters.kills, 1, "crash kills are not scheduler kills");
        assert_eq!(p.counters.heartbeats, 1);
    }

    #[test]
    fn fault_probe_accumulates_wasted_work_and_crashes() {
        let mut p = FaultProbe::new(FaultStats {
            straggler_nodes: 3,
            ..Default::default()
        });
        p.on_event(0.0, &ProbeEvent::WorkWasted { seconds: 2.5 });
        p.on_event(0.0, &ProbeEvent::WorkWasted { seconds: 1.5 });
        p.on_event(
            1.0,
            &ProbeEvent::NodeCrashed {
                node: 2,
                permanent: true,
            },
        );
        p.on_event(2.0, &ProbeEvent::NodeRecovered { node: 2 });
        p.on_event(
            3.0,
            &ProbeEvent::TaskKilled {
                task: task(1),
                running: false,
                cause: KillCause::Crash,
            },
        );
        p.on_event(
            4.0,
            &ProbeEvent::TaskLaunched {
                task: task(1),
                node: 0,
                local: false,
                re_execution: true,
            },
        );
        assert_eq!(p.stats.straggler_nodes, 3);
        assert!((p.stats.wasted_work_s - 4.0).abs() < 1e-12);
        assert_eq!(p.stats.crashes, 1);
        assert_eq!(p.stats.permanent_losses, 1);
        assert_eq!(p.stats.recoveries, 1);
        assert_eq!(p.stats.crash_task_kills, 1);
        assert_eq!(p.stats.re_executed_tasks, 1);
    }

    #[test]
    fn locality_probe_counts_map_completions_only() {
        let mut p = LocalityProbe::default();
        p.on_event(
            0.0,
            &ProbeEvent::TaskCompleted {
                task: task(1),
                node: 0,
                local: true,
                observed_s: 1.0,
                speculative: false,
            },
        );
        let reduce = TaskRef {
            job: 1,
            phase: Phase::Reduce,
            index: 0,
        };
        p.on_event(
            1.0,
            &ProbeEvent::TaskCompleted {
                task: reduce,
                node: 0,
                local: true,
                observed_s: 1.0,
                speculative: false,
            },
        );
        assert_eq!(p.stats.total(), 1);
        assert_eq!(p.stats.local, 1);
    }

    #[test]
    fn timeline_probe_is_inert_when_disabled() {
        let mut off = TimelineProbe::new(false);
        let mut on = TimelineProbe::new(true);
        for p in [&mut off, &mut on] {
            p.on_event(
                1.0,
                &ProbeEvent::TaskLaunched {
                    task: task(7),
                    node: 0,
                    local: true,
                    re_execution: false,
                },
            );
            p.on_event(
                3.0,
                &ProbeEvent::TaskCompleted {
                    task: task(7),
                    node: 0,
                    local: true,
                    observed_s: 2.0,
                    speculative: false,
                },
            );
        }
        assert!(off.set.job(7).is_none());
        let tl = on.set.job(7).expect("timeline recorded");
        assert!(tl.is_balanced());
        assert!((tl.slot_seconds() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn counters_count_spills_and_merge_fieldwise() {
        let mut p = CounterProbe::default();
        p.on_event(0.0, &ProbeEvent::JobSpilled { job: 4 });
        p.on_event(0.0, &ProbeEvent::Heartbeat { node: 0 });
        assert_eq!(p.counters.spilled_jobs, 1);
        let mut merged = ActionCounters {
            launches: 2,
            heartbeats: 5,
            ..Default::default()
        };
        merged.merge(&p.counters);
        assert_eq!(merged.launches, 2);
        assert_eq!(merged.heartbeats, 6);
        assert_eq!(merged.spilled_jobs, 1);
    }

    #[test]
    fn job_limit_probe_requests_halt_at_limit() {
        let mut p = JobLimitProbe::new(2);
        assert!(!p.halt_requested());
        p.on_job_done(1.0, &rec(1));
        assert!(!p.halt_requested());
        p.on_job_done(2.0, &rec(2));
        assert!(p.halt_requested());
        assert_eq!(p.seen(), 2);
    }

    #[test]
    fn stack_latches_user_halt_and_yields_parts() {
        let mut limit = JobLimitProbe::new(1);
        let mut stack = ProbeStack::new(false, FaultStats::default(), vec![&mut limit]);
        stack.emit(0.0, &ProbeEvent::Heartbeat { node: 0 });
        assert!(!stack.take_halt());
        stack.job_done(1.0, &rec(1));
        assert!(stack.take_halt());
        assert!(!stack.take_halt(), "halt latch resets");
        let (sojourn, _, _, counters, _) = stack.into_parts(1.0);
        assert_eq!(sojourn.len(), 1);
        assert_eq!(counters.heartbeats, 1);
    }
}
