//! Job sojourn-time accounting.
//!
//! The paper's headline metric is the **sojourn time**: total time a job
//! spends in the system, waiting plus service (§1, §2). This module
//! collects per-job records and per-class summaries (the clustering of
//! Fig. 3) and produces ECDF series.

use crate::job::{JobClass, JobId, TenantId};
use crate::util::json::Json;
use crate::util::stats::{Ecdf, Moments};
use std::collections::BTreeMap;

/// One finished job's outcome.
#[derive(Clone, Debug)]
pub struct PerJobRecord {
    pub job: JobId,
    pub class: JobClass,
    /// Submitting tenant (default for single-tenant workloads).
    pub tenant: TenantId,
    pub submit: f64,
    pub finish: f64,
    pub n_maps: usize,
    pub n_reduces: usize,
    /// Serialized true size (map + reduce), seconds.
    pub true_size: f64,
}

impl PerJobRecord {
    pub fn sojourn(&self) -> f64 {
        self.finish - self.submit
    }
}

/// Collection of sojourn outcomes.
#[derive(Clone, Debug, Default)]
pub struct SojournStats {
    records: Vec<PerJobRecord>,
}

impl SojournStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, rec: PerJobRecord) {
        debug_assert!(rec.finish >= rec.submit, "finish before submit");
        self.records.push(rec);
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    pub fn records(&self) -> &[PerJobRecord] {
        &self.records
    }

    /// Fold another shard's records into this collection and restore the
    /// global completion order `(finish, job)` — the order the serial
    /// driver produces, since it appends records as jobs finish and
    /// breaks completion ties by arrival (job id) order.
    pub fn merge(&mut self, other: SojournStats) {
        self.records.extend(other.records);
        self.records
            .sort_by(|a, b| a.finish.total_cmp(&b.finish).then(a.job.cmp(&b.job)));
    }

    pub fn sojourns(&self) -> Vec<f64> {
        self.records.iter().map(|r| r.sojourn()).collect()
    }

    /// Mean sojourn over all jobs.
    pub fn mean(&self) -> f64 {
        let mut m = Moments::new();
        for r in &self.records {
            m.push(r.sojourn());
        }
        m.mean()
    }

    /// Mean sojourn restricted to one class.
    pub fn mean_class(&self, class: JobClass) -> f64 {
        let mut m = Moments::new();
        for r in self.records.iter().filter(|r| r.class == class) {
            m.push(r.sojourn());
        }
        m.mean()
    }

    /// ECDF of sojourn times for a class (Fig. 3 series); `None` for the
    /// all-jobs ECDF.
    pub fn ecdf(&self, class: Option<JobClass>) -> Ecdf {
        Ecdf::new(
            self.records
                .iter()
                .filter(|r| class.map(|c| r.class == c).unwrap_or(true))
                .map(|r| r.sojourn())
                .collect(),
        )
    }

    /// Per-job sojourn, keyed by job id — used for the Fig. 4 FAIR−HFSP
    /// per-job difference.
    pub fn by_job(&self) -> BTreeMap<JobId, f64> {
        self.records.iter().map(|r| (r.job, r.sojourn())).collect()
    }

    /// Class counts (sanity checks).
    pub fn class_counts(&self) -> BTreeMap<&'static str, usize> {
        let mut m = BTreeMap::new();
        for r in &self.records {
            *m.entry(r.class.name()).or_insert(0) += 1;
        }
        m
    }

    /// JSON summary (mean / per-class means / count).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("jobs", self.len().into());
        o.set("mean_sojourn_s", self.mean().into());
        for class in JobClass::ALL {
            let m = self.mean_class(class);
            if !m.is_nan() {
                o.set(&format!("mean_sojourn_{}_s", class.name()), m.into());
            }
        }
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(job: JobId, class: JobClass, submit: f64, finish: f64) -> PerJobRecord {
        PerJobRecord {
            job,
            class,
            tenant: TenantId::default(),
            submit,
            finish,
            n_maps: 1,
            n_reduces: 0,
            true_size: 10.0,
        }
    }

    #[test]
    fn mean_and_class_means() {
        let mut s = SojournStats::new();
        s.push(rec(1, JobClass::Small, 0.0, 10.0));
        s.push(rec(2, JobClass::Small, 0.0, 20.0));
        s.push(rec(3, JobClass::Large, 0.0, 100.0));
        assert!((s.mean() - (10.0 + 20.0 + 100.0) / 3.0).abs() < 1e-12);
        assert!((s.mean_class(JobClass::Small) - 15.0).abs() < 1e-12);
        assert!((s.mean_class(JobClass::Large) - 100.0).abs() < 1e-12);
        assert!(s.mean_class(JobClass::Medium).is_nan());
    }

    #[test]
    fn ecdf_filters_class() {
        let mut s = SojournStats::new();
        s.push(rec(1, JobClass::Small, 0.0, 10.0));
        s.push(rec(2, JobClass::Large, 0.0, 100.0));
        assert_eq!(s.ecdf(Some(JobClass::Small)).len(), 1);
        assert_eq!(s.ecdf(None).len(), 2);
    }

    #[test]
    fn merge_restores_completion_order() {
        let mut a = SojournStats::new();
        a.push(rec(1, JobClass::Small, 0.0, 30.0));
        a.push(rec(4, JobClass::Small, 0.0, 50.0));
        let mut b = SojournStats::new();
        b.push(rec(2, JobClass::Small, 0.0, 10.0));
        b.push(rec(3, JobClass::Small, 0.0, 30.0));
        a.merge(b);
        let order: Vec<u64> = a.records().iter().map(|r| r.job).collect();
        // Ties on finish time fall back to job id (1 before 3 at t=30).
        assert_eq!(order, vec![2, 1, 3, 4]);
        assert_eq!(a.len(), 4);
    }

    #[test]
    fn by_job_maps_ids() {
        let mut s = SojournStats::new();
        s.push(rec(7, JobClass::Small, 5.0, 11.0));
        assert!((s.by_job()[&7] - 6.0).abs() < 1e-12);
    }

    #[test]
    fn json_summary_has_fields() {
        let mut s = SojournStats::new();
        s.push(rec(1, JobClass::Small, 0.0, 4.0));
        let j = s.to_json();
        assert_eq!(j.get("jobs").unwrap().as_u64(), Some(1));
        assert!(j.get("mean_sojourn_s").unwrap().as_f64().unwrap() > 0.0);
        assert!(j.get("mean_sojourn_small_s").is_some());
        assert!(j.get("mean_sojourn_large_s").is_none());
    }
}
