//! Cluster assembly: the set of TaskTracker nodes plus global slot math.

use super::node::{Node, NodeConfig};
use crate::job::{Phase, TaskRef};

/// Cluster-wide configuration.
///
/// Defaults mirror the paper's Amazon Cluster (§4.1): 100 m1.xlarge nodes,
/// 4 MAP + 2 REDUCE slots each, 15 GB RAM, 4 disks, 128 MB HDFS blocks
/// with replication 3, and Hadoop's 3 s heartbeat.
#[derive(Clone, Copy, Debug)]
pub struct ClusterConfig {
    pub nodes: usize,
    pub map_slots: usize,
    pub reduce_slots: usize,
    /// HDFS replication factor.
    pub replication: usize,
    /// TaskTracker heartbeat period, seconds.
    pub heartbeat_s: f64,
    /// Node RAM available to task JVMs, MB.
    pub ram_mb: f64,
    /// RAM-per-slot (child JVM context size), MB.
    pub ram_per_slot_mb: f64,
    /// Swap partition size, MB.
    pub swap_mb: f64,
    /// Aggregate disk bandwidth for swap in/out, MB/s.
    pub disk_mbps: f64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            nodes: 100,
            map_slots: 4,
            reduce_slots: 2,
            replication: 3,
            heartbeat_s: 3.0,
            // 15 GB minus ~3 GB for daemons/OS. Hadoop 0.21 child JVMs
            // default to a few hundred MB of heap (mapred.child.java.opts);
            // §5 argues suspended contexts usually stay in RAM — with
            // 600 MB contexts, 6 running tasks leave room for ~14 parked
            // contexts before the OS pages anything out.
            ram_mb: 12_000.0,
            ram_per_slot_mb: 600.0,
            swap_mb: 16_000.0,
            // 4 spindles at ~100 MB/s.
            disk_mbps: 400.0,
        }
    }
}

impl ClusterConfig {
    pub fn node_config(&self) -> NodeConfig {
        NodeConfig {
            map_slots: self.map_slots,
            reduce_slots: self.reduce_slots,
            ram_mb: self.ram_mb,
            ram_per_slot_mb: self.ram_per_slot_mb,
            swap_mb: self.swap_mb,
            disk_mbps: self.disk_mbps,
        }
    }

    pub fn total_slots(&self, phase: Phase) -> usize {
        self.nodes
            * match phase {
                Phase::Map => self.map_slots,
                Phase::Reduce => self.reduce_slots,
            }
    }
}

/// The live cluster: nodes indexed by id.
#[derive(Debug)]
pub struct Cluster {
    nodes: Vec<Node>,
    cfg: ClusterConfig,
}

impl Cluster {
    pub fn new(cfg: ClusterConfig) -> Self {
        assert!(cfg.nodes > 0, "cluster needs at least one node");
        let nodes = (0..cfg.nodes)
            .map(|id| Node::new(id, cfg.node_config()))
            .collect();
        Self { nodes, cfg }
    }

    pub fn config(&self) -> &ClusterConfig {
        &self.cfg
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn node(&self, id: usize) -> &Node {
        &self.nodes[id]
    }

    pub fn node_mut(&mut self, id: usize) -> &mut Node {
        &mut self.nodes[id]
    }

    pub fn nodes(&self) -> &[Node] {
        &self.nodes
    }

    pub fn total_slots(&self, phase: Phase) -> usize {
        self.cfg.total_slots(phase)
    }

    pub fn free_slots(&self, phase: Phase) -> usize {
        self.nodes.iter().map(|n| n.free_slots(phase)).sum()
    }

    pub fn running_tasks(&self, phase: Phase) -> usize {
        self.nodes.iter().map(|n| n.running(phase).len()).sum()
    }

    /// Locate the node on which `task` is currently running.
    pub fn node_running(&self, task: TaskRef) -> Option<usize> {
        self.nodes
            .iter()
            .find(|n| n.running(task.phase).contains(&task))
            .map(|n| n.id)
    }

    /// Locate the node holding `task`'s suspended context.
    pub fn node_suspending(&self, task: TaskRef) -> Option<usize> {
        self.nodes
            .iter()
            .find(|n| n.is_suspended_here(task))
            .map(|n| n.id)
    }

    /// Total suspended contexts cluster-wide (drives HFSP's hysteresis).
    pub fn suspended_count(&self) -> usize {
        self.nodes.iter().map(|n| n.suspended_count()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper_testbed() {
        let cfg = ClusterConfig::default();
        assert_eq!(cfg.nodes, 100);
        assert_eq!(cfg.total_slots(Phase::Map), 400);
        assert_eq!(cfg.total_slots(Phase::Reduce), 200);
        assert_eq!(cfg.replication, 3);
    }

    #[test]
    fn cluster_aggregates_slots() {
        let cfg = ClusterConfig {
            nodes: 4,
            map_slots: 2,
            reduce_slots: 1,
            ..Default::default()
        };
        let mut c = Cluster::new(cfg);
        assert_eq!(c.free_slots(Phase::Map), 8);
        let t = TaskRef {
            job: 1,
            phase: Phase::Map,
            index: 0,
        };
        c.node_mut(2).start_task(t);
        assert_eq!(c.free_slots(Phase::Map), 7);
        assert_eq!(c.running_tasks(Phase::Map), 1);
        assert_eq!(c.node_running(t), Some(2));
        assert_eq!(c.node_suspending(t), None);
    }

    #[test]
    fn suspended_count_aggregates() {
        let cfg = ClusterConfig {
            nodes: 2,
            map_slots: 1,
            reduce_slots: 1,
            ..Default::default()
        };
        let mut c = Cluster::new(cfg);
        let t = TaskRef {
            job: 1,
            phase: Phase::Map,
            index: 0,
        };
        c.node_mut(0).start_task(t);
        c.node_mut(0).suspend_task(t, 1.0);
        assert_eq!(c.suspended_count(), 1);
        assert_eq!(c.node_suspending(t), Some(0));
    }
}
