//! Cluster partitioning for sharded execution.
//!
//! A [`Partition`] splits a cluster's node id space into `S` contiguous
//! shards. Each shard **owns** its node range outright: every per-node
//! event (heartbeats, crash/recover, task completions on that node) is
//! handled by the shard's own engine, so the hot path never takes a
//! lock — cross-shard traffic moves through channels drained at window
//! boundaries (see [`crate::sim::shard`]).
//!
//! Contiguous ranges (rather than round-robin striping) keep the
//! per-shard cluster model a plain `Cluster` over `len(s)` nodes: a
//! global node id maps to `(shard, local id)` with two integer ops, and
//! the fault plan / speed tables slice cleanly.

/// A contiguous split of `nodes` node ids into `count` shards.
///
/// The first `nodes % count` shards take one extra node, so shard sizes
/// differ by at most one. `count` is clamped to `nodes` at construction
/// (a shard must own at least one node — `Cluster::new` asserts a
/// non-empty node set).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Partition {
    nodes: usize,
    count: usize,
}

impl Partition {
    /// Partition `nodes` node ids into (at most) `count` shards.
    pub fn new(nodes: usize, count: usize) -> Self {
        assert!(nodes > 0, "cannot partition an empty cluster");
        let clamped = count.clamp(1, nodes);
        if clamped != count {
            log::warn!(
                "clamping shard count {count} to {clamped} ({nodes} nodes; \
                 every shard must own at least one node)"
            );
        }
        Self {
            nodes,
            count: clamped,
        }
    }

    /// Number of shards (after clamping).
    pub fn count(&self) -> usize {
        self.count
    }

    /// Total nodes across all shards.
    pub fn nodes(&self) -> usize {
        self.nodes
    }

    /// Global node ids owned by shard `s`, as a contiguous range.
    pub fn nodes_of_shard(&self, s: usize) -> std::ops::Range<usize> {
        assert!(s < self.count, "shard {s} out of range ({})", self.count);
        let base = self.nodes / self.count;
        let extra = self.nodes % self.count;
        // Shards [0, extra) hold base+1 nodes; the rest hold base.
        let start = s * base + s.min(extra);
        let len = base + usize::from(s < extra);
        start..start + len
    }

    /// Node count of shard `s`.
    pub fn len(&self, s: usize) -> usize {
        self.nodes_of_shard(s).len()
    }

    /// Whether shard `s` owns zero nodes (never true after clamping;
    /// kept for API completeness).
    pub fn is_empty(&self, s: usize) -> bool {
        self.len(s) == 0
    }

    /// The shard owning global node id `node`.
    pub fn shard_of_node(&self, node: usize) -> usize {
        assert!(node < self.nodes, "node {node} out of range ({})", self.nodes);
        let base = self.nodes / self.count;
        let extra = self.nodes % self.count;
        // The first `extra` shards cover [0, extra*(base+1)).
        let wide = extra * (base + 1);
        if node < wide {
            node / (base + 1)
        } else {
            extra + (node - wide) / base
        }
    }

    /// Translate a global node id to its shard-local id.
    pub fn local_id(&self, node: usize) -> usize {
        let s = self.shard_of_node(node);
        node - self.nodes_of_shard(s).start
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn even_split_owns_contiguous_ranges() {
        let p = Partition::new(8, 4);
        assert_eq!(p.count(), 4);
        for s in 0..4 {
            assert_eq!(p.nodes_of_shard(s), 2 * s..2 * s + 2);
            assert_eq!(p.len(s), 2);
            assert!(!p.is_empty(s));
        }
    }

    #[test]
    fn uneven_split_front_loads_the_remainder() {
        let p = Partition::new(10, 4);
        assert_eq!(p.nodes_of_shard(0), 0..3);
        assert_eq!(p.nodes_of_shard(1), 3..6);
        assert_eq!(p.nodes_of_shard(2), 6..8);
        assert_eq!(p.nodes_of_shard(3), 8..10);
        let total: usize = (0..4).map(|s| p.len(s)).sum();
        assert_eq!(total, 10);
    }

    #[test]
    fn shard_of_node_inverts_the_ranges() {
        for (nodes, count) in [(1, 1), (5, 2), (10, 4), (100, 7), (16, 16)] {
            let p = Partition::new(nodes, count);
            for s in 0..p.count() {
                for node in p.nodes_of_shard(s) {
                    assert_eq!(p.shard_of_node(node), s, "node {node} of {nodes}/{count}");
                    let local = p.local_id(node);
                    assert_eq!(p.nodes_of_shard(s).start + local, node);
                    assert!(local < p.len(s));
                }
            }
        }
    }

    #[test]
    fn oversized_count_clamps_to_one_node_per_shard() {
        let p = Partition::new(3, 8);
        assert_eq!(p.count(), 3);
        for s in 0..3 {
            assert_eq!(p.len(s), 1);
            assert_eq!(p.nodes_of_shard(s), s..s + 1);
        }
    }

    #[test]
    fn zero_count_clamps_to_single_shard() {
        let p = Partition::new(5, 0);
        assert_eq!(p.count(), 1);
        assert_eq!(p.nodes_of_shard(0), 0..5);
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn empty_cluster_is_rejected() {
        Partition::new(0, 2);
    }
}
